"""Shared model components: norms, RoPE/M-RoPE, SwiGLU, initializers.

All models are pure-functional JAX: params are plain dict pytrees created
by ``init`` functions, and every model exposes a parallel pytree of
``PartitionSpec`` ("logical sharding") consumed by the launcher.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(np.prod([shape[a] for a in in_axis]))
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- #
# norms                                                                  #
# --------------------------------------------------------------------- #
def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# rotary embeddings                                                      #
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, theta: float, sections=(1, 1, 2)):
    """Qwen2-VL multimodal RoPE: the head dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions_thw: (3, B, S). ``sections`` are relative
    weights over hd/2 frequency slots (qwen2-vl uses 16/24/24 of 64)."""
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    cuts = [half * s // total for s in sections]
    cuts[-1] = half - sum(cuts[:-1])
    freqs = rope_freqs(hd, theta)                        # (half,)
    # per-frequency-slot position stream selection
    sel = jnp.concatenate(
        [jnp.full((c,), i, jnp.int32) for i, c in enumerate(cuts)]
    )                                                     # (half,)
    pos = positions_thw.astype(jnp.float32)              # (3, B, S)
    # gather the right stream per slot: (B, S, half)
    pos_slot = jnp.einsum("tbs,th->bsh", pos, jax.nn.one_hot(sel, 3).T)
    angles = pos_slot * freqs[None, None, :]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# FFN                                                                    #
# --------------------------------------------------------------------- #
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), 0, dtype),
        "w_up": dense_init(k2, (d_model, d_ff), 0, dtype),
        "w_down": dense_init(k3, (d_ff, d_model), 0, dtype),
    }


def swiglu_pspecs(stacked: bool):
    """FFN weights: TP-shard d_ff over "model", FSDP-shard d_model over
    "data" (2D sharding keeps the 123B config under per-chip HBM)."""
    pre = ("layers",) if stacked else ()
    return {
        "w_gate": P(*pre, "data", "model"),
        "w_up": P(*pre, "data", "model"),
        "w_down": P(*pre, "model", "data"),
    }


def shard_hint(x, spec: P):
    """with_sharding_constraint that is a no-op outside a real mesh.
    Axis names not present in the ambient mesh are dropped (e.g. "pod" on
    the single-pod mesh), and dims that don't divide their assigned axes
    are replicated instead — so model code can write one logical spec."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or mesh.size == 1:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        entries = list(spec) + [None] * (x.ndim - len(spec))
        out = []
        for dim, entry in zip(x.shape, entries):
            if entry is None:
                out.append(None)
                continue
            names = tuple(n for n in (entry if isinstance(entry, tuple) else (entry,))
                          if n in sizes)
            if not names:
                out.append(None)
                continue
            prod = 1
            for n in names:
                prod *= sizes[n]
            if dim % prod != 0:
                out.append(None)
            else:
                out.append(names if len(names) > 1 else names[0])
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, P(*out)))
    except Exception:
        return x


def residual_hint(x):
    """Sequence parallelism for the residual stream: (B, S, d) sharded
    batch->(pod,data) AND seq->model, so remat-saved per-layer residuals
    and the logits pipeline are 256-way sharded instead of 16-way. Falls
    back to batch-only sharding when S doesn't divide the model axis
    (decode steps)."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or mesh.size == 1:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        bdim = ("pod", "data") if "pod" in sizes else ("data",)
        seq_ok = (
            x.ndim >= 2
            and "model" in sizes
            and x.shape[1] % sizes["model"] == 0
            and x.shape[1] >= sizes["model"]
        )
        spec = P(bdim, "model" if seq_ok else None, *([None] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec)
        )
    except Exception:
        return x


def batch_hint(x):
    """Shard dim0 (batch) over the LARGEST divisible mesh-axis combo —
    recurrent models have no cross-batch ops, so batch can shard over the
    model axis too (B=256 over 16x16 = 1 seq/device), which divides the
    per-device recurrent state by 256 instead of 16."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or mesh.size == 1:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        combos = [("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",)]
        for combo in combos:
            names = tuple(n for n in combo if n in sizes)
            if not names:
                continue
            prod = 1
            for n in names:
                prod *= sizes[n]
            if x.shape[0] % prod == 0 and x.shape[0] >= prod:
                spec = P(names, *([None] * (x.ndim - 1)))
                return jax.lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(mesh, spec))
        return x
    except Exception:
        return x


def heads_hint(x, head_axis: int = 2):
    """Shard the (flat) head dim over "model" when divisible, else no-op."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty or mesh.size == 1:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if "model" not in sizes or x.shape[head_axis] % sizes["model"] != 0:
            return x
        bdim = ("pod", "data") if "pod" in sizes else ("data",)
        entries = [None] * x.ndim
        entries[0] = bdim
        entries[head_axis] = "model"
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, P(*entries)))
    except Exception:
        return x


def scan_layers(body, carry, xs, unroll: bool = False):
    """lax.scan over stacked layer params, or a static Python loop when
    ``unroll`` (dry-run cost probes need every layer visible in the HLO —
    XLA's cost analysis counts a while-loop body exactly once)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        sl = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if not ys or all(y is None for y in ys):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *e: jnp.stack(e), *ys)
    return carry, stacked


def cross_entropy_loss(logits, labels, vocab: int):
    """Stable softmax CE with z-loss; fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(logz ** 2)
    return ce + zloss
