"""Mixture-of-Experts layer with explicit expert parallelism (EP).

TPU adaptation: experts are sharded over the "model" mesh axis; tokens are
sharded over BOTH mesh axes entering the layer (2D token sharding keeps the
dispatch buffer ~T_loc·k·D instead of T_loc·k·D·dp). Dispatch is
capacity-based (tokens over capacity are dropped, standard top-k MoE) and
routed with two ``lax.all_to_all`` collectives inside ``jax.shard_map`` —
the collectives are explicit in the lowered HLO, which is what the
roofline's collective term measures.

Data flow per device (T = local tokens, E = experts, ep = EP degree):
  router top-k -> send buffer (ep, C, D) via capacity scatter
  all_to_all   -> recv (ep, C, D): what every peer routed to my experts
  local dispatch -> (E_loc, C2, D) -> per-expert SwiGLU einsum
  inverse gather -> (ep, C, D) -> all_to_all back -> weighted combine.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init

# version guard: shard_map graduated from jax.experimental to jax.shard_map
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # older JAX
    from jax.experimental.shard_map import shard_map as _shard_map


def _axis_size(name) -> int:
    """Version-guarded ``jax.lax.axis_size`` (older JAX spells it
    ``psum(1, name)``, which folds to the static mesh-axis size)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def init_moe(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),  # router in fp32
        "w_gate": dense_init(ks[1], (e, d, f), 1, dtype),
        "w_up": dense_init(ks[2], (e, d, f), 1, dtype),
        "w_down": dense_init(ks[3], (e, f, d), 1, dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": dense_init(k1, (d, fs), 0, dtype),
            "w_up": dense_init(k2, (d, fs), 0, dtype),
            "w_down": dense_init(k3, (fs, d), 0, dtype),
        }
    return params


def moe_pspecs(cfg, stacked: bool):
    pre = ("layers",) if stacked else ()
    specs = {
        "router": P(*pre, None, None),
        "w_gate": P(*pre, "model", "data", None),   # experts over TP, FSDP d
        "w_up": P(*pre, "model", "data", None),
        "w_down": P(*pre, "model", None, "data"),
    }
    if cfg.n_shared_experts:
        specs["shared"] = {
            "w_gate": P(*pre, "data", "model"),
            "w_up": P(*pre, "data", "model"),
            "w_down": P(*pre, "model", "data"),
        }
    return specs


def _capacity(n_tokens: int, k: int, buckets: int, factor: float) -> int:
    c = int(n_tokens * k / max(1, buckets) * factor)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for TPU-lane alignment


def _expert_ffn(w_gate, w_up, w_down, x):
    """x: (E_loc, C2, D) -> per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_shard_fn(x, router_w, w_gate, w_up, w_down, *, cfg, ep_axis="model"):
    """Body run under shard_map. x: (T_loc, D) local tokens.
    Expert weights arrive EP-sharded: (E_loc, D, F)."""
    T, D = x.shape
    E = cfg.n_experts
    k = cfg.top_k
    ep = _axis_size(ep_axis) if ep_axis else 1
    E_loc = E // ep
    my_rank = jax.lax.axis_index(ep_axis) if ep_axis else 0

    # ---- router ----
    logits = x.astype(jnp.float32) @ router_w                  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / (jnp.sum(top_p, -1, keepdims=True) + 1e-9)
    # load-balance aux loss (computed locally; caller psums)
    me = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    ce = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- first-level dispatch: (ep, C, D) send buffer ----
    C = _capacity(T, k, ep, cfg.moe_capacity_factor)
    flat_e = top_e.reshape(-1)                                 # (T*k,)
    dest = flat_e // E_loc
    oh = jax.nn.one_hot(dest, ep, dtype=jnp.int32)             # (T*k, ep)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=1)  # slot in dest
    tok = jnp.repeat(jnp.arange(T), k)
    send = jnp.zeros((ep, C, D), x.dtype).at[dest, pos].set(x[tok], mode="drop")
    send_e = jnp.full((ep, C), -1, jnp.int32).at[dest, pos].set(flat_e, mode="drop")

    if ep_axis:
        recv = jax.lax.all_to_all(send, ep_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=True)
    else:
        recv, recv_e = send, send_e

    # ---- second-level dispatch to local experts ----
    rx = recv.reshape(ep * C, D)
    re = recv_e.reshape(ep * C) - my_rank * E_loc              # local ids
    valid = (re >= 0) & (re < E_loc)
    re_c = jnp.where(valid, re, 0)
    C2 = _capacity(ep * C, 1, E_loc, cfg.moe_capacity_factor)
    oh2 = jax.nn.one_hot(re_c, E_loc, dtype=jnp.int32) * valid[:, None].astype(jnp.int32)
    pos2 = jnp.sum((jnp.cumsum(oh2, axis=0) - oh2) * oh2, axis=1)
    pos2 = jnp.where(valid, pos2, C2)                          # dropped -> OOB
    buf = jnp.zeros((E_loc, C2, D), x.dtype).at[re_c, pos2].set(rx, mode="drop")

    out_buf = _expert_ffn(w_gate, w_up, w_down, buf)           # (E_loc, C2, D)

    # ---- inverse: gather expert outputs back into recv layout ----
    back = out_buf.at[re_c, jnp.minimum(pos2, C2 - 1)].get(mode="fill", fill_value=0)
    back = jnp.where(valid[:, None], back, 0).reshape(ep, C, D)
    if ep_axis:
        ret = jax.lax.all_to_all(back, ep_axis, 0, 0, tiled=True)
    else:
        ret = back

    # ---- combine: read my tokens' results from my send slots ----
    got = ret.at[dest, jnp.minimum(pos, C - 1)].get(mode="fill", fill_value=0)
    sent_ok = pos < C
    got = jnp.where(sent_ok[:, None], got, 0).reshape(T, k, D)
    out = jnp.sum(got * top_p[..., None].astype(got.dtype), axis=1)
    return out.astype(x.dtype), aux


def moe_decode_fn(x, router_w, w_gate, w_up, w_down, *, cfg, ep_axis="model"):
    """Decode-time EP: tokens are replicated over the EP axis (a decode
    step has too few tokens to shard over 16 ranks); each rank runs only
    its local experts and the combine is a psum — one (T, D) all-reduce
    instead of two all_to_alls."""
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    ep = _axis_size(ep_axis) if ep_axis else 1
    E_loc = E // ep
    my_rank = jax.lax.axis_index(ep_axis) if ep_axis else 0

    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / (jnp.sum(top_p, -1, keepdims=True) + 1e-9)

    flat_e = top_e.reshape(-1) - my_rank * E_loc               # local ids
    valid = (flat_e >= 0) & (flat_e < E_loc)
    e_c = jnp.where(valid, flat_e, 0)
    C2 = T * k                                                  # no drops
    pos = jnp.arange(T * k)
    tok = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E_loc, C2, D), x.dtype).at[e_c, pos].set(
        jnp.where(valid[:, None], x[tok], 0)
    )
    out_buf = _expert_ffn(w_gate, w_up, w_down, buf)
    got = out_buf[e_c, pos] * valid[:, None].astype(x.dtype)
    got = got.reshape(T, k, D)
    out = jnp.sum(got * top_p[..., None].astype(got.dtype), axis=1)
    if ep_axis:
        out = jax.lax.psum(out, ep_axis)
    return out.astype(x.dtype)


def moe_forward(params, x, cfg, mesh=None, decode: bool = False):
    """x: (B, S, D) -> (B, S, D), aux_loss.

    Under a real mesh, runs the EP body in shard_map with tokens 2D-sharded
    (batch over data, seq over model). On a single device (smoke tests),
    runs the identical body with ep=1 semantics.
    """
    B, S, D = x.shape

    from jax.interpreters import pxla

    env_mesh = mesh
    if env_mesh is None:
        m = pxla.thread_resources.env.physical_mesh
        env_mesh = None if m.empty else m

    if env_mesh is not None and "model" in env_mesh.axis_names:
        all_axes = tuple(env_mesh.axis_names)
        pod = ("pod", "data") if "pod" in all_axes else ("data",)
        especs = P("model", None, None)

        if decode:
            def body_d(xt, rw, wg, wu, wd):
                out = moe_decode_fn(
                    xt.reshape(-1, D), rw, wg, wu, wd, cfg=cfg, ep_axis="model"
                )
                return out.reshape(xt.shape)

            out = _shard_map(
                body_d,
                mesh=env_mesh,
                in_specs=(P(pod, None, None), P(None, None),
                          especs, especs, especs),
                out_specs=P(pod, None, None),
            )(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])
            aux = jnp.float32(0.0)
        else:
            def body(xt, rw, wg, wu, wd):
                out, aux = moe_shard_fn(
                    xt.reshape(-1, D), rw, wg, wu, wd, cfg=cfg, ep_axis="model"
                )
                aux = jax.lax.pmean(aux, all_axes)
                return out.reshape(xt.shape), aux

            out, aux = _shard_map(
                body,
                mesh=env_mesh,
                in_specs=(P(pod, "model", None), P(None, None),
                          especs, especs, especs),
                out_specs=(P(pod, "model", None), P()),
            )(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])
    else:
        if decode:
            out = moe_decode_fn(
                x.reshape(-1, D), params["router"], params["w_gate"],
                params["w_up"], params["w_down"], cfg=cfg, ep_axis=None,
            ).reshape(B, S, D)
            aux = jnp.float32(0.0)
        else:
            out, aux = moe_shard_fn(
                x.reshape(-1, D), params["router"], params["w_gate"],
                params["w_up"], params["w_down"], cfg=cfg, ep_axis=None,
            )
            out = out.reshape(B, S, D)

    if cfg.n_shared_experts:
        sh = params["shared"]
        out = out + (jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_down"]
    return out, aux
