"""Model zoo: ``build_model(cfg)`` returns the family implementation."""
from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.dense import DecoderLM
from repro.models.encdec import EncDecLM
from repro.models.recurrent import XLSTMLM, Zamba2LM


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return DecoderLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
