"""Recurrent-family LMs with the standard model interface.

XLSTMLM  — xlstm-1.3b: groups of 7 mLSTM + 1 sLSTM blocks (paper's [7:1]).
Zamba2LM — zamba2-2.7b: Mamba2 backbone with one SHARED attention+FFN
           block applied after every ``shared_attn_every`` layers (the
           shared block has a single weight set used at all 9 sites).
Both are sub-quadratic: decode carries O(1) recurrent state, so these two
archs run the long_500k cell.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import gqa_decode, gqa_forward, gqa_pspecs, init_gqa
from repro import perf_flags
from repro.models.common import (
    batch_hint,
    residual_hint,
    scan_layers,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_swiglu,
    param_dtype,
    rms_norm,
    shard_hint,
    swiglu,
    swiglu_pspecs,
)
from repro.models.ssm import (
    init_mamba2,
    init_mlstm,
    init_slstm,
    mamba2_forward,
    mamba2_init_state,
    mamba2_pspecs,
    mamba2_state_pspecs,
    mlstm_forward,
    mlstm_init_state,
    mlstm_pspecs,
    mlstm_state_pspecs,
    slstm_forward,
    slstm_init_state,
    slstm_pspecs,
    slstm_state_pspecs,
)


def _group_structure(n_layers: int) -> Tuple[int, int]:
    """(n_groups, mlstm_per_group); one sLSTM closes each group."""
    if n_layers % 8 == 0:
        return n_layers // 8, 7
    return 1, max(1, n_layers - 1)


class XLSTMLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.n_groups, self.m_per = _group_structure(cfg.n_layers)

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = param_dtype(cfg)
        k0, k1, k2, k3 = jax.random.split(rng, 4)
        m_keys = jax.random.split(k0, self.n_groups * self.m_per).reshape(
            self.n_groups, self.m_per, -1
        )
        mlstm = jax.vmap(jax.vmap(lambda k: init_mlstm(k, cfg, dt)))(m_keys)
        slstm = jax.vmap(lambda k: init_slstm(k, cfg, dt))(
            jax.random.split(k1, self.n_groups)
        )
        return {
            "embed": embed_init(k2, (cfg.vocab_padded, cfg.d_model), dt),
            "mlstm": mlstm,
            "slstm": slstm,
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(k3, (cfg.d_model, cfg.vocab_padded), 0, dt),
        }

    def param_pspecs(self) -> Dict:
        def add(pre, tree):
            return jax.tree_util.tree_map(
                lambda s: P(*pre, *s), tree, is_leaf=lambda x: isinstance(x, P)
            )

        return {
            "embed": P("model", "data"),
            "mlstm": add((None, None), mlstm_pspecs(False)),
            "slstm": add((None,), slstm_pspecs(False)),
            "final_norm": P(None),
            "lm_head": P("data", "model"),
        }

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        m = mlstm_init_state(self.cfg, batch)
        s = slstm_init_state(self.cfg, batch)
        return {
            "mlstm": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (self.n_groups, self.m_per) + a.shape
                ),
                m,
            ),
            "slstm": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (self.n_groups,) + a.shape), s
            ),
        }

    def cache_pspecs(self):
        def add(pre, tree):
            return jax.tree_util.tree_map(
                lambda s: P(*pre, *s), tree, is_leaf=lambda x: isinstance(x, P)
            )

        return {
            "mlstm": add((None, None), mlstm_state_pspecs()),
            "slstm": add((None,), slstm_state_pspecs()),
        }

    def _stack(self, params, x, states):
        """Run all groups. states=None -> fresh states; returns states."""
        cfg = self.cfg

        def group(x, slices):
            mp, sp, mstate, sstate = slices

            def m_body(x, ms):
                lp, st = ms
                x, st2 = jax.checkpoint(
                    lambda lp_, x_, st_: mlstm_forward(lp_, x_, cfg, st_)
                )(lp, x, st)
                return x, st2

            x, mstate2 = scan_layers(m_body, x, (mp, mstate), cfg.unroll_layers)
            x, sstate2 = slstm_forward(sp, x, cfg, sstate)
            return x, (mstate2, sstate2)

        x, (mstates, sstates) = scan_layers(
            group, x, (params["mlstm"], params["slstm"],
                       states["mlstm"], states["slstm"]),
            cfg.unroll_layers,
        )
        return x, {"mlstm": mstates, "slstm": sstates}

    def forward(self, params, tokens, states=None):
        x = params["embed"][tokens]
        x = batch_hint(x) if perf_flags.BATCH_SHARD else residual_hint(x)
        if states is None:
            states = self.init_cache(tokens.shape[0], 0)
        x, states = self._stack(params, x, states)
        return rms_norm(x, params["final_norm"]), states

    def loss(self, params, batch):
        tokens = batch["tokens"]
        h, _ = self.forward(params, tokens[:, :-1])
        logits = h @ params["lm_head"]
        return cross_entropy_loss(logits, tokens[:, 1:], self.cfg.vocab_padded)

    def prefill(self, params, tokens, cache_len: int = 0):
        h, states = self.forward(params, tokens)
        logits = h[:, -1:] @ params["lm_head"]
        return logits, states

    def decode_step(self, params, cache, tokens, pos, **_):
        h, states = self.forward(params, tokens, states=cache)
        logits = h @ params["lm_head"]
        return logits, states

    def recurrence_flops_per_device(self, B: int, S: int, dp: int, tp: int) -> float:
        """Analytic FLOPs of the mLSTM time recurrence, which XLA's cost
        analysis can't see (while-loop body counted once). Heads (4) don't
        divide a 16-way model axis, so the recurrence replicates over TP:
        per-device work divides by dp only."""
        cfg = self.cfg
        di = 2 * cfg.d_model
        hd = di // cfg.n_heads
        per_step = 6.0 * cfg.n_heads * hd * hd  # C update + readout
        total = per_step * B * S * cfg.n_layers
        return total / max(1, dp)


class Zamba2LM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.period = cfg.shared_attn_every or cfg.n_layers
        self.n_groups = max(1, cfg.n_layers // self.period)

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = param_dtype(cfg)
        k0, k1, k2, k3, k4, k5 = jax.random.split(rng, 6)
        mamba = jax.vmap(jax.vmap(lambda k: init_mamba2(k, cfg, dt)))(
            jax.random.split(k0, self.n_groups * self.period).reshape(
                self.n_groups, self.period, -1
            )
        )
        return {
            "embed": embed_init(k1, (cfg.vocab_padded, cfg.d_model), dt),
            "mamba": mamba,
            "shared_attn": init_gqa(k2, cfg, dt),
            "shared_mlp": init_swiglu(k3, cfg.d_model, cfg.d_ff, dt),
            "shared_norm1": jnp.ones((cfg.d_model,), dt),
            "shared_norm2": jnp.ones((cfg.d_model,), dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(k5, (cfg.d_model, cfg.vocab_padded), 0, dt),
        }

    def param_pspecs(self) -> Dict:
        def add(pre, tree):
            return jax.tree_util.tree_map(
                lambda s: P(*pre, *s), tree, is_leaf=lambda x: isinstance(x, P)
            )

        return {
            "embed": P("model", "data"),
            "mamba": add((None, None), mamba2_pspecs(False)),
            "shared_attn": gqa_pspecs(False),
            "shared_mlp": swiglu_pspecs(False),
            "shared_norm1": P(None),
            "shared_norm2": P(None),
            "final_norm": P(None),
            "lm_head": P("data", "model"),
        }

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        m = mamba2_init_state(cfg, batch)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None, None], (self.n_groups, self.period) + a.shape
                ),
                m,
            ),
            "attn_k": jnp.zeros(
                (self.n_groups, batch, seq, cfg.n_kv_heads, cfg.hd), dtype
            ),
            "attn_v": jnp.zeros(
                (self.n_groups, batch, seq, cfg.n_kv_heads, cfg.hd), dtype
            ),
        }

    def cache_pspecs(self, batch: int = 2):
        def add(pre, tree):
            return jax.tree_util.tree_map(
                lambda s: P(*pre, *s), tree, is_leaf=lambda x: isinstance(x, P)
            )

        # batch==1 (long_500k): shard the KV-cache SEQ dim over data instead
        kv = (
            P(None, None, ("pod", "data"), "model", None)
            if batch == 1
            else P(None, ("pod", "data"), None, "model", None)
        )
        return {
            "mamba": add((None, None), mamba2_state_pspecs()),
            "attn_k": kv,
            "attn_v": kv,
        }

    def _shared_block(self, params, x):
        cfg = self.cfg
        h = rms_norm(x, params["shared_norm1"])
        attn_out, kv = gqa_forward(params["shared_attn"], h, cfg, causal=True)
        x = x + attn_out
        h = rms_norm(x, params["shared_norm2"])
        x = x + swiglu(h, params["shared_mlp"]["w_gate"],
                       params["shared_mlp"]["w_up"], params["shared_mlp"]["w_down"])
        return x, kv

    def forward(self, params, tokens, states=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = batch_hint(x) if perf_flags.BATCH_SHARD else residual_hint(x)
        if states is None:
            states = self.init_cache(tokens.shape[0], 0)

        def group(x, slices):
            mp, mstate = slices

            def m_body(x, ms):
                lp, st = ms
                x, st2 = jax.checkpoint(
                    lambda lp_, x_, st_: mamba2_forward(lp_, x_, cfg, st_)
                )(lp, x, st)
                return x, st2

            x, mstate2 = scan_layers(m_body, x, (mp, mstate), cfg.unroll_layers)
            x, kv = self._shared_block(params, x)
            return x, (mstate2, kv)

        x, (mstates, kvs) = scan_layers(
            group, x, (params["mamba"], states["mamba"]), cfg.unroll_layers
        )
        return rms_norm(x, params["final_norm"]), mstates, kvs

    def loss(self, params, batch):
        tokens = batch["tokens"]
        h, _, _ = self.forward(params, tokens[:, :-1])
        logits = h @ params["lm_head"]
        return cross_entropy_loss(logits, tokens[:, 1:], self.cfg.vocab_padded)

    def prefill(self, params, tokens, cache_len: int):
        B, S = tokens.shape
        h, mstates, (ks, vs) = self.forward(params, tokens)
        pad = cache_len - S
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) if pad > 0 else ks
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))) if pad > 0 else vs
        logits = h[:, -1:] @ params["lm_head"]
        return logits, {"mamba": mstates, "attn_k": ks, "attn_v": vs}

    def decode_step(self, params, cache, tokens, pos, **_):
        cfg = self.cfg
        x = params["embed"][tokens]

        # full caches ride in the carry (in-place per-group update) so the
        # 9x shared-attn KV cache is not duplicated by scan xs/ys buffers
        def group(carry, mp):
            x, mamba_st, ak, av, g = carry
            mstate = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, g, 0, keepdims=False),
                mamba_st,
            )

            def m_body(x, ms):
                lp, st = ms
                x, st2 = mamba2_forward(lp, x, cfg, st)
                return x, st2

            x, mstate2 = scan_layers(m_body, x, (mp, mstate), cfg.unroll_layers)
            ck = jax.lax.dynamic_index_in_dim(ak, g, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(av, g, 0, keepdims=False)
            h = rms_norm(x, params["shared_norm1"])
            attn_out, ck2, cv2 = gqa_decode(params["shared_attn"], h, ck, cv, pos, cfg)
            x = x + attn_out
            h = rms_norm(x, params["shared_norm2"])
            x = x + swiglu(h, params["shared_mlp"]["w_gate"],
                           params["shared_mlp"]["w_up"],
                           params["shared_mlp"]["w_down"])
            mamba_st = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                    a, u[None].astype(a.dtype), g, 0),
                mamba_st, mstate2,
            )
            ak = jax.lax.dynamic_update_slice_in_dim(ak, ck2[None].astype(ak.dtype), g, 0)
            av = jax.lax.dynamic_update_slice_in_dim(av, cv2[None].astype(av.dtype), g, 0)
            return (x, mamba_st, ak, av, g + 1), None

        (x, mstates, ks, vs, _), _ = scan_layers(
            group,
            (x, cache["mamba"], cache["attn_k"], cache["attn_v"], jnp.int32(0)),
            params["mamba"],
            cfg.unroll_layers,
        )
        h = rms_norm(x, params["final_norm"])
        logits = h @ params["lm_head"]
        return logits, {"mamba": mstates, "attn_k": ks, "attn_v": vs}

    def recurrence_flops_per_device(self, B: int, S: int, dp: int, tp: int) -> float:
        """Mamba2's SSD recurrence: channels (di=2d) shard cleanly over the
        model axis, so per-device work divides by dp*tp."""
        cfg = self.cfg
        di = 2 * cfg.d_model
        per_step = 5.0 * di * cfg.ssm_state
        total = per_step * B * S * cfg.n_layers
        return total / max(1, dp * tp)
