"""Decoder-only transformer LM: dense, MoE (EP), MLA, and M-RoPE variants.

One implementation covers 7 of the 10 assigned architectures:
qwen3-moe-30b-a3b, deepseek-v2-lite-16b (MLA+MoE), deepseek-67b,
phi3-medium/mini, mistral-large-123b, qwen2-vl-7b (M-RoPE backbone).

Layers are stacked (L, ...) and driven by ``lax.scan`` with
``jax.checkpoint`` per layer (remat), so the HLO stays one-layer-sized for
95-layer configs and activation memory is O(1 layer) on the backward pass.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import (
    gqa_decode,
    gqa_forward,
    gqa_pspecs,
    init_gqa,
    init_mla,
    mla_decode,
    mla_forward,
    mla_pspecs,
)
from repro.models.common import (
    residual_hint,
    scan_layers,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_swiglu,
    param_dtype,
    rms_norm,
    shard_hint,
    swiglu,
    swiglu_pspecs,
)
from repro.models.moe import init_moe, moe_forward, moe_pspecs

AUX_LOSS_WEIGHT = 1e-2


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.is_mla = cfg.kv_lora_rank > 0
        self.is_moe = cfg.n_experts > 0
        self.n_scan = cfg.n_layers - cfg.first_dense_layers

    # ------------------------------------------------------------------ #
    # params                                                             #
    # ------------------------------------------------------------------ #
    def _init_layer(self, key, moe: bool):
        cfg = self.cfg
        dt = param_dtype(cfg)
        k1, k2 = jax.random.split(key)
        attn = init_mla(k1, cfg, dt) if self.is_mla else init_gqa(k1, cfg, dt)
        if moe:
            mlp = init_moe(k2, cfg, dt)
        else:
            d_ff = cfg.d_ff if cfg.d_ff else cfg.d_ff_expert * 8
            mlp = init_swiglu(k2, cfg.d_model, d_ff, dt)
        return {
            "attn": attn,
            "mlp": mlp,
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
        }

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = param_dtype(cfg)
        keys = jax.random.split(rng, 4 + cfg.first_dense_layers)
        stacked = jax.vmap(lambda k: self._init_layer(k, self.is_moe))(
            jax.random.split(keys[0], self.n_scan)
        )
        params = {
            "embed": embed_init(keys[1], (cfg.vocab_padded, cfg.d_model), dt),
            "layers": stacked,
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(keys[2], (cfg.d_model, cfg.vocab_padded), 0, dt),
        }
        for i in range(cfg.first_dense_layers):
            params[f"dense_layer_{i}"] = self._init_layer(keys[4 + i], moe=False)
        return params

    def param_pspecs(self) -> Dict:
        cfg = self.cfg

        def layer_specs(stacked: bool, moe: bool):
            pre = ("layers",) if stacked else ()
            attn = mla_pspecs(stacked) if self.is_mla else gqa_pspecs(stacked)
            mlp = moe_pspecs(cfg, stacked) if moe else swiglu_pspecs(stacked)
            return {
                "attn": attn,
                "mlp": mlp,
                "norm1": P(*pre, None),
                "norm2": P(*pre, None),
            }

        specs = {
            "embed": P("model", "data"),        # vocab over TP, d over FSDP
            "layers": layer_specs(True, self.is_moe),
            "final_norm": P(None),
            "lm_head": P("data", "model"),
        }
        for i in range(cfg.first_dense_layers):
            specs[f"dense_layer_{i}"] = layer_specs(False, False)
        return specs

    # ------------------------------------------------------------------ #
    # forward                                                            #
    # ------------------------------------------------------------------ #
    def _layer_fwd(self, lp, x, *, moe: bool, mrope_positions=None):
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"])
        if self.is_mla:
            attn_out, _ = mla_forward(lp["attn"], h, cfg)
        else:
            attn_out, _ = gqa_forward(
                lp["attn"], h, cfg, causal=True, mrope_positions=mrope_positions
            )
        x = x + attn_out
        h = rms_norm(x, lp["norm2"])
        if moe:
            mlp_out, aux = moe_forward(lp["mlp"], h, cfg)
        else:
            mlp_out = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
            aux = jnp.float32(0.0)
        x = x + mlp_out
        x = residual_hint(x)
        return x, aux

    def forward(self, params, tokens, *, extra_embeds=None, mrope_positions=None):
        """tokens: (B, S) -> final hidden states (B, S, d) + aux loss."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if extra_embeds is not None:  # VLM stub: precomputed patch embeddings
            x = x + extra_embeds.astype(x.dtype)
        x = residual_hint(x)
        aux_total = jnp.float32(0.0)
        for i in range(cfg.first_dense_layers):
            x, _ = self._layer_fwd(params[f"dense_layer_{i}"], x, moe=False,
                                   mrope_positions=mrope_positions)

        def body(x, lp):
            x, aux = jax.checkpoint(
                lambda lp_, x_: self._layer_fwd(
                    lp_, x_, moe=self.is_moe, mrope_positions=mrope_positions
                )
            )(lp, x)
            return x, aux

        x, auxes = scan_layers(body, x, params["layers"], cfg.unroll_layers)
        if auxes is not None:  # empty when every layer is a dense prefix
            aux_total = aux_total + jnp.sum(auxes)
        return rms_norm(x, params["final_norm"]), aux_total

    def loss(self, params, batch) -> jnp.ndarray:
        """batch: {"tokens": (B, S+1) int32, [extras]}; next-token CE."""
        tokens = batch["tokens"]
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        h, aux = self.forward(
            params, inp,
            extra_embeds=batch.get("extra_embeds"),
            mrope_positions=batch.get("mrope_positions"),
        )
        logits = h @ params["lm_head"]
        logits = shard_hint(logits, P(("pod", "data"), None, "model"))
        return cross_entropy_loss(logits, labels, self.cfg.vocab_padded) \
            + AUX_LOSS_WEIGHT * aux

    # ------------------------------------------------------------------ #
    # serving                                                            #
    # ------------------------------------------------------------------ #
    def _layer_cache(self, batch: int, seq: int, dtype):
        cfg = self.cfg
        if self.is_mla:
            return {
                "ckv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, seq, cfg.rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
        }

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.n_scan,) + a.shape),
            self._layer_cache(batch, seq, dtype),
        )
        cache = {"layers": stacked}
        for i in range(cfg.first_dense_layers):
            cache[f"dense_{i}"] = self._layer_cache(batch, seq, dtype)
        return cache

    def cache_pspecs(self):
        cfg = self.cfg
        if self.is_mla:
            per = {"ckv": P(("pod", "data"), "model", None),
                   "kr": P(("pod", "data"), "model", None)}
        else:
            # batch over data; seq over model (kv-head count < TP degree)
            per = {"k": P(("pod", "data"), "model", None, None),
                   "v": P(("pod", "data"), "model", None, None)}
        add_layer = lambda spec: P(None, *spec)
        specs = {"layers": jax.tree_util.tree_map(
            add_layer, per, is_leaf=lambda x: isinstance(x, P))}
        for i in range(cfg.first_dense_layers):
            specs[f"dense_{i}"] = per
        return specs

    def _decode_attn(self, lp, x, layer_cache, pos, mrope_positions=None):
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"])
        if self.is_mla:
            attn_out, ckv, kr = mla_decode(
                lp["attn"], h, layer_cache["ckv"], layer_cache["kr"], pos, cfg
            )
            return attn_out, {"ckv": ckv, "kr": kr}
        attn_out, ck, cv = gqa_decode(
            lp["attn"], h, layer_cache["k"], layer_cache["v"], pos, cfg,
            mrope_positions=mrope_positions,
        )
        return attn_out, {"k": ck, "v": cv}

    def decode_step(self, params, cache, tokens, pos, *, mrope_positions=None):
        """tokens: (B, 1); pos: (B,) current positions. Returns logits, cache."""
        cfg = self.cfg
        x = params["embed"][tokens]
        x = residual_hint(x)
        new_cache = {}
        for i in range(cfg.first_dense_layers):
            lp = params[f"dense_layer_{i}"]
            attn_out, new_cache[f"dense_{i}"] = self._decode_attn(
                lp, x, cache[f"dense_{i}"], pos, mrope_positions
            )
            x = x + attn_out
            h = rms_norm(x, lp["norm2"])
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])

        # The full cache rides in the CARRY and is updated in place per
        # layer: scan xs/ys would keep TWO cache-sized buffers live (read
        # xs + stacked ys), doubling decode HBM.
        def body(carry, lp):
            x, full_cache, i = carry
            cache_slices = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                full_cache,
            )
            attn_out, updated = self._decode_attn(lp, x, cache_slices, pos,
                                                  mrope_positions)
            x = x + attn_out
            h = rms_norm(x, lp["norm2"])
            if self.is_moe:
                mlp_out, _ = moe_forward(lp["mlp"], h, cfg, decode=True)
            else:
                mlp_out = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                                 lp["mlp"]["w_down"])
            full_cache = jax.tree_util.tree_map(
                lambda a, u: jax.lax.dynamic_update_slice_in_dim(
                    a, u[None].astype(a.dtype), i, 0),
                full_cache, updated,
            )
            return (x + mlp_out, full_cache, i + 1), None

        (x, scanned_cache, _), _ = scan_layers(
            body, (x, cache["layers"], jnp.int32(0)), params["layers"],
            cfg.unroll_layers,
        )
        new_cache["layers"] = scanned_cache
        h = rms_norm(x, params["final_norm"])
        logits = h @ params["lm_head"]
        return logits, new_cache

    def prefill(self, params, tokens, cache_len: int):
        """Run the full prompt, return (last-token logits, filled cache)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens]
        x = residual_hint(x)
        prefix_cache = {}
        for i in range(cfg.first_dense_layers):
            lp = params[f"dense_layer_{i}"]
            h = rms_norm(x, lp["norm1"])
            if self.is_mla:
                attn_out, (ckv, kr) = mla_forward(lp["attn"], h, cfg)
                prefix_cache[f"dense_{i}"] = {"ckv": _pad_to(ckv, cache_len, 1),
                                              "kr": _pad_to(kr, cache_len, 1)}
            else:
                attn_out, (k, v) = gqa_forward(lp["attn"], h, cfg, causal=True)
                prefix_cache[f"dense_{i}"] = {"k": _pad_to(k, cache_len, 1),
                                              "v": _pad_to(v, cache_len, 1)}
            x = x + attn_out
            h = rms_norm(x, lp["norm2"])
            x = x + swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])

        def body(x, lp):
            h = rms_norm(x, lp["norm1"])
            if self.is_mla:
                attn_out, (ckv, kr) = mla_forward(lp["attn"], h, cfg)
                kv = {"ckv": _pad_to(ckv, cache_len, 1),
                      "kr": _pad_to(kr, cache_len, 1)}
            else:
                attn_out, (k, v) = gqa_forward(lp["attn"], h, cfg, causal=True)
                kv = {"k": _pad_to(k, cache_len, 1), "v": _pad_to(v, cache_len, 1)}
            x = x + attn_out
            h = rms_norm(x, lp["norm2"])
            if self.is_moe:
                mlp_out, _ = moe_forward(lp["mlp"], h, cfg)
            else:
                mlp_out = swiglu(h, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                                 lp["mlp"]["w_down"])
            return x + mlp_out, kv

        x, stacked = scan_layers(body, x, params["layers"], cfg.unroll_layers)
        cache = {"layers": stacked}
        cache.update(prefix_cache)
        h = rms_norm(x[:, -1:], params["final_norm"])
        logits = h @ params["lm_head"]
        return logits, cache


def _pad_to(x, target: int, axis: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
