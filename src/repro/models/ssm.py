"""Recurrent LMs: xLSTM (mLSTM + sLSTM blocks) and Mamba2 (SSD) blocks.

xlstm-1.3b: 48 blocks in the paper's [7:1] ratio — groups of 7 mLSTM
blocks followed by 1 sLSTM block (6 groups). mLSTM keeps a per-head
matrix memory C (hd×hd) with exponential input/forget gating and the
max-stabilizer m; sLSTM keeps scalar memories. Both are lax.scan
recurrences over time — O(1) state decode, sub-quadratic everywhere
(this is why long_500k is assigned to these archs).

Mamba2 (used by zamba2): diagonal SSD recurrence h_t = a_t h_{t-1} +
dt_t·B_t x_t with y_t = C_t·h_t + D·x_t over a state of N=64 per channel,
preceded by a short causal depthwise conv.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import perf_flags
from repro.models.common import (
    cross_entropy_loss,
    dense_init,
    embed_init,
    param_dtype,
    rms_norm,
    shard_hint,
)

TIME_CHUNK = 64  # steps per remat chunk when REPRO_PERF_OPT=ssm_chunk


def chunked_time_scan(step, carry, xs, chunk: int = TIME_CHUNK):
    """Time recurrence with gradient checkpointing at chunk boundaries.

    A plain ``lax.scan`` backward saves the carry at EVERY step — for
    mLSTM's (B, h, hd, hd) matrix state that is S x state bytes (the 3.4TB
    /device baseline). Chunked: save only n_chunks boundary states,
    recompute inside a chunk on the backward pass. Memory becomes
    (S/chunk + chunk) x state; compute pays one extra forward.
    """
    if not perf_flags.SSM_CHUNK:
        return jax.lax.scan(step, carry, xs)
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S % chunk != 0 or S <= chunk:
        return jax.lax.scan(step, carry, xs)
    n = S // chunk

    def chunk_body(c, xs_chunk):
        return jax.lax.scan(step, c, xs_chunk)

    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs
    )
    carry, ys = jax.lax.scan(jax.checkpoint(chunk_body), carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys
    )
    return carry, ys

# --------------------------------------------------------------------- #
# mLSTM                                                                  #
# --------------------------------------------------------------------- #
def init_mlstm(key, cfg, dtype, proj_factor: int = 2):
    d = cfg.d_model
    di = proj_factor * d
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, di), 0, dtype),
        "w_gate_up": dense_init(ks[1], (d, di), 0, dtype),
        "wq": dense_init(ks[2], (di, di), 0, dtype),
        "wk": dense_init(ks[3], (di, di), 0, dtype),
        "wv": dense_init(ks[4], (di, di), 0, dtype),
        "w_if": dense_init(ks[5], (di, 2 * h), 0, dtype),  # input/forget gates
        "w_down": dense_init(ks[6], (di, d), 0, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def mlstm_pspecs(stacked: bool):
    pre = ("layers",) if stacked else ()
    return {
        "w_up": P(*pre, "data", "model"),
        "w_gate_up": P(*pre, "data", "model"),
        "wq": P(*pre, "data", "model"),
        "wk": P(*pre, "data", "model"),
        "wv": P(*pre, "data", "model"),
        "w_if": P(*pre, "data", None),
        "w_down": P(*pre, "model", "data"),
        "norm": P(*pre, None),
    }


def mlstm_init_state(cfg, batch: int, proj_factor: int = 2):
    di = proj_factor * cfg.d_model
    h = cfg.n_heads
    hd = di // h
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_state_pspecs():
    return {"C": P(("pod", "data"), "model", None, None),
            "n": P(("pod", "data"), "model", None),
            "m": P(("pod", "data"), "model")}


def _mlstm_cell(state, qkvif):
    """One time step. q,k,v: (B,h,hd); i_t,f_t: (B,h) pre-activations."""
    q, k, v, ig, fg = qkvif
    C, n, m = state["C"], state["n"], state["m"]
    hd = q.shape[-1]
    # stabilized exponential gating (xLSTM eq. 15-19)
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + m - m_new)
    k_s = k / jnp.sqrt(jnp.float32(hd))
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k_s[..., None, :]
    )
    n_new = f_p[..., None] * n + i_p[..., None] * k_s
    num = jnp.einsum("bhij,bhj->bhi", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)), 1.0)
    h_t = num / den[..., None]
    return {"C": C_new, "n": n_new, "m": m_new}, h_t


def mlstm_forward(lp, x, cfg, state=None, proj_factor: int = 2):
    """x: (B, S, d). Returns (out, final_state)."""
    B, S, d = x.shape
    h = cfg.n_heads
    xi = rms_norm(x, lp["norm"])
    up = xi @ lp["w_up"]
    gate = jax.nn.silu(xi @ lp["w_gate_up"])
    di = up.shape[-1]
    hd = di // h
    q = (up @ lp["wq"]).reshape(B, S, h, hd).astype(jnp.float32)
    k = (up @ lp["wk"]).reshape(B, S, h, hd).astype(jnp.float32)
    v = (up @ lp["wv"]).reshape(B, S, h, hd).astype(jnp.float32)
    gif = (up @ lp["w_if"]).reshape(B, S, 2, h).astype(jnp.float32)
    ig, fg = gif[:, :, 0], jax.nn.log_sigmoid(gif[:, :, 1])
    if state is None:
        state = mlstm_init_state(cfg, B, proj_factor)

    def step(carry, t_in):
        return _mlstm_cell(carry, t_in)

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          ig.swapaxes(0, 1), fg.swapaxes(0, 1))
    state, hs = chunked_time_scan(step, state, xs)
    hs = hs.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    out = (hs * gate) @ lp["w_down"]
    return x + out, state


# --------------------------------------------------------------------- #
# sLSTM                                                                  #
# --------------------------------------------------------------------- #
def init_slstm(key, cfg, dtype, proj_factor: int = 2):
    d = cfg.d_model
    di = proj_factor * d
    ks = jax.random.split(key, 4)
    return {
        "w_up": dense_init(ks[0], (d, di), 0, dtype),
        "w_gates": dense_init(ks[1], (di, 4 * di), 0, dtype),  # z,i,f,o
        "w_down": dense_init(ks[2], (di, d), 0, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def slstm_pspecs(stacked: bool):
    pre = ("layers",) if stacked else ()
    return {
        "w_up": P(*pre, "data", "model"),
        "w_gates": P(*pre, "model", None),
        "w_down": P(*pre, "model", "data"),
        "norm": P(*pre, None),
    }


def slstm_init_state(cfg, batch: int, proj_factor: int = 2):
    di = proj_factor * cfg.d_model
    return {
        "c": jnp.zeros((batch, di), jnp.float32),
        "n": jnp.ones((batch, di), jnp.float32),
        "m": jnp.zeros((batch, di), jnp.float32),
    }


def slstm_state_pspecs():
    return {"c": P(("pod", "data"), "model"),
            "n": P(("pod", "data"), "model"),
            "m": P(("pod", "data"), "model")}


def slstm_forward(lp, x, cfg, state=None, proj_factor: int = 2):
    B, S, d = x.shape
    xi = rms_norm(x, lp["norm"])
    up = xi @ lp["w_up"]
    di = up.shape[-1]
    gates = (up @ lp["w_gates"]).reshape(B, S, 4, di).astype(jnp.float32)
    z, ig, fg, og = (gates[:, :, i] for i in range(4))
    if state is None:
        state = slstm_init_state(cfg, B, proj_factor)

    def step(carry, t_in):
        z_t, i_t, f_t, o_t = t_in
        c, n, m = carry["c"], carry["n"], carry["m"]
        f_l = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(f_l + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_l + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(z_t)
        n_new = f_p * n + i_p
        h_t = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
        return {"c": c_new, "n": n_new, "m": m_new}, h_t

    xs = tuple(a.swapaxes(0, 1) for a in (z, ig, fg, og))
    state, hs = chunked_time_scan(step, state, xs)
    hs = hs.swapaxes(0, 1).astype(x.dtype)
    out = hs @ lp["w_down"]
    return x + out, state


# --------------------------------------------------------------------- #
# Mamba2 (SSD)                                                           #
# --------------------------------------------------------------------- #
def init_mamba2(key, cfg, dtype, expand: int = 2):
    d = cfg.d_model
    di = expand * d
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), 0, dtype),        # x and z
        "w_bcdt": dense_init(ks[1], (di, 2 * N + 1), 0, dtype),  # B, C, dt
        "conv_w": dense_init(ks[2], (4, di), 0, dtype),          # depthwise
        "a_log": jnp.zeros((di,), jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[3], (di, d), 0, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def mamba2_pspecs(stacked: bool):
    pre = ("layers",) if stacked else ()
    return {
        "w_in": P(*pre, "data", "model"),
        "w_bcdt": P(*pre, "model", None),
        "conv_w": P(*pre, None, "model"),
        "a_log": P(*pre, "model"),
        "d_skip": P(*pre, "model"),
        "w_out": P(*pre, "model", "data"),
        "norm": P(*pre, None),
    }


def mamba2_init_state(cfg, batch: int, expand: int = 2):
    di = expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),  # last 3 inputs
    }


def mamba2_state_pspecs():
    return {"h": P(("pod", "data"), "model", None),
            "conv": P(("pod", "data"), None, "model")}


def mamba2_forward(lp, x, cfg, state=None, expand: int = 2):
    """x: (B, S, d) -> (out, state)."""
    B, S, d = x.shape
    N = cfg.ssm_state
    xi = rms_norm(x, lp["norm"])
    xz = xi @ lp["w_in"]
    di = xz.shape[-1] // 2
    u, z = xz[..., :di], jax.nn.silu(xz[..., di:])
    if state is None:
        state = mamba2_init_state(cfg, B, expand)
    # causal depthwise conv (window 4) via shifted adds
    conv_in = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
    u_c = sum(conv_in[:, 3 - j : 3 - j + S] * lp["conv_w"][3 - j] for j in range(4))
    u_c = jax.nn.silu(u_c)
    new_conv = conv_in[:, -3:].astype(jnp.float32)

    bcdt = (u_c @ lp["w_bcdt"]).astype(jnp.float32)
    Bv, Cv, dt = bcdt[..., :N], bcdt[..., N : 2 * N], jax.nn.softplus(bcdt[..., -1:])
    a = -jnp.exp(lp["a_log"])                            # (di,)
    decay = jnp.exp(a[None, None, :] * dt)               # (B,S,di)
    uf = u_c.astype(jnp.float32)

    def step(h, t_in):
        dec_t, B_t, C_t, u_t, dt_t = t_in
        h = dec_t[..., None] * h + (dt_t[:, None] * u_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (decay.swapaxes(0, 1), Bv.swapaxes(0, 1), Cv.swapaxes(0, 1),
          uf.swapaxes(0, 1), dt.swapaxes(0, 1)[..., 0])
    h_state, ys = chunked_time_scan(step, state["h"], xs)
    ys = ys.swapaxes(0, 1) + uf * lp["d_skip"][None, None, :]
    out = ((ys.astype(x.dtype)) * z) @ lp["w_out"]
    return x + out, {"h": h_state, "conv": new_conv}
