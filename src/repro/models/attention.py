"""Attention: GQA (chunked, exact), KV-cache decode, and DeepSeek MLA.

Design notes (TPU adaptation):
  * Prefill/train attention is q-chunked: scores are materialized only for
    a (chunk × S) tile, never (S × S) — this is the flash-attention memory
    shape rethought for XLA/TPU (the MXU sees aligned (chunk, hd) @ (hd, S)
    matmuls; VMEM holds one tile). Exact softmax per q row (full K range),
    so no online-softmax state is needed.
  * Decode reads the KV cache (B, S_max, Hkv, hd) and does two skinny
    matmuls — memory-bound by design; roofline's memory term covers it.
  * MLA decode uses the absorbed form: scores against the compressed
    c_kv cache (rank r), never expanding K/V to per-head tensors.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import perf_flags
from repro.models.common import (
    apply_mrope,
    apply_rope,
    dense_init,
    heads_hint,
    rms_norm,
    shard_hint,
)

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# GQA                                                                    #
# --------------------------------------------------------------------- #
def init_gqa(key, cfg, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, hq * hd), 0, dtype),
        "wk": dense_init(k2, (d, hkv * hd), 0, dtype),
        "wv": dense_init(k3, (d, hkv * hd), 0, dtype),
        "wo": dense_init(k4, (hq * hd, d), 0, dtype),
    }


def gqa_pspecs(stacked: bool):
    """Shard the head dim over "model" (TP), d_model over "data" (FSDP).
    KV projections stay replicated over "model" when Hkv < TP degree —
    the divisibility-aware launcher downgrades those specs."""
    pre = ("layers",) if stacked else ()
    return {
        "wq": P(*pre, "data", "model"),
        "wk": P(*pre, "data", "model"),
        "wv": P(*pre, "data", "model"),
        "wo": P(*pre, "model", "data"),
    }


def _chunked_attn(q, k, v, *, causal: bool, q_offset=0, chunk: int = 1024,
                  kv_len_mask: Optional[int] = None):
    """Exact attention, q-chunked. q: (B,Sq,Hq,hd) k/v: (B,Sk,Hkv,hd)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, hd)
    n_chunks = max(1, -(-Sq // chunk))
    pad = n_chunks * chunk - Sq
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qg = qg.reshape(B, n_chunks, chunk, Hkv, G, hd)

    kv_pos = jnp.arange(Sk)

    def one_chunk(c, qc):
        # qc: (B, chunk, Hkv, G, hd); c is a STATIC chunk index (python
        # loop, not lax.map: every chunk's cost is visible to the dry-run)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        q_pos = q_offset + c * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, Sk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if kv_len_mask is not None:
            mask &= (kv_pos < kv_len_mask)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if perf_flags.PV_BF16:
            # PV in the input dtype: halves HBM traffic + collective bytes
            # of the attention block (softmax stays f32) — §Perf iteration
            out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v)
        else:
            out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
        return out.astype(q.dtype)

    vd = v.shape[-1]
    outs = [one_chunk(c, qg[:, c]) for c in range(n_chunks)]
    out = jnp.stack(outs, 1).reshape(B, n_chunks * chunk, Hkv, G, vd)
    if pad:
        out = out[:, :Sq]
    return out.reshape(B, Sq, Hq, vd)


def gqa_forward(params, x, cfg, *, causal: bool = True, positions=None,
                mrope_positions=None, chunk: int = 1024):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    Perf note (§Perf iteration 2): K/V are expanded to FLAT q-head space
    and constrained head-sharded before the score einsum. Without this,
    the SP residual's seq-sharding propagates into K, and XLA partitions
    the score contraction over seq — emitting per-layer f32 all-reduces of
    (B, H, chunk, S) partial sums. Expanding + head-sharding turns that
    into small bf16 K/V reshards instead."""
    B, S, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, S, hq, hd)
    k = (x @ params["wk"]).reshape(B, S, hkv, hd)
    v = (x @ params["wv"]).reshape(B, S, hkv, hd)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kv = (k, v)
    if perf_flags.ATTN_QSEQ:
        # q seq-sharded over "model", K/V replicated (one bf16 all-gather
        # per layer) — the score contraction is fully local, so the
        # baseline's per-layer f32 (B,H,chunk,S) partial-sum all-reduces
        # disappear. Works for ANY kv-head count (no divisibility needs).
        q = shard_hint(q, P(("pod", "data"), "model", None, None))
        k = shard_hint(k, P(("pod", "data"), None, None, None))
        v = shard_hint(v, P(("pod", "data"), None, None, None))
    elif perf_flags.ATTN_TP:
        # classic TP attention: q AND k/v head-sharded; the score/PV
        # contractions are fully local per head shard. Divisibility-aware:
        # kv-head counts below the TP degree keep the baseline layout.
        q = shard_hint(q, P(("pod", "data"), None, "model", None))
        k = shard_hint(k, P(("pod", "data"), None, "model", None))
        v = shard_hint(v, P(("pod", "data"), None, "model", None))
    elif perf_flags.ATTN_FLAT:
        G = hq // hkv
        if G > 1:
            k = jnp.repeat(k, G, axis=2)   # flat-head GQA (view per shard)
            v = jnp.repeat(v, G, axis=2)
        q = heads_hint(q)
        k = heads_hint(k)
        v = heads_hint(v)
    else:
        q = shard_hint(q, P(("pod", "data"), None, "model", None))
    out = _chunked_attn(q, k, v, causal=causal, chunk=chunk)
    return out.reshape(B, S, hq * hd) @ params["wo"], kv


def gqa_decode(params, x, cache_k, cache_v, pos, cfg, *, mrope_positions=None):
    """One-token decode. x: (B,1,d); cache: (B,Smax,Hkv,hd); pos: (B,)."""
    B, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ params["wq"]).reshape(B, 1, hq, hd)
    k = (x @ params["wk"]).reshape(B, 1, hkv, hd)
    v = (x @ params["wv"]).reshape(B, 1, hkv, hd)
    if cfg.mrope and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    # masked in-place cache update at pos: elementwise, so it partitions
    # cleanly when the cache's SEQ dim is sharded (a dynamic_update_slice
    # at a traced index would force an all-gather of the shard)
    Smax_ = cache_k.shape[1]
    at_pos = (jnp.arange(Smax_)[None, :] == pos[:, None])[:, :, None, None]
    cache_k = jnp.where(at_pos, k[:, 0:1].astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(at_pos, v[:, 0:1].astype(cache_v.dtype), cache_v)
    Smax = cache_k.shape[1]
    G = hq // hkv
    qg = q.reshape(B, hkv, G, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        cache_k.astype(jnp.float32)) * (hd ** -0.5)
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]          # (B, Smax)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, hq * hd).astype(x.dtype)
    return out @ params["wo"], cache_k, cache_v


# --------------------------------------------------------------------- #
# DeepSeek MLA                                                           #
# --------------------------------------------------------------------- #
def init_mla(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rr, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_head_dim or hd
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (hd + rr)), 0, dtype),
        "w_dkv": dense_init(ks[1], (d, r), 0, dtype),          # compress
        "w_kr": dense_init(ks[2], (d, rr), 0, dtype),          # shared rope key
        "w_uk": dense_init(ks[3], (r, h * hd), 0, dtype),      # expand K
        "w_uv": dense_init(ks[4], (r, h * vd), 0, dtype),      # expand V
        "wo": dense_init(ks[5], (h * vd, d), 0, dtype),
        "norm_ckv": jnp.ones((r,), dtype),
    }


def mla_pspecs(stacked: bool):
    pre = ("layers",) if stacked else ()
    return {
        "wq": P(*pre, "data", "model"),
        "w_dkv": P(*pre, "data", None),
        "w_kr": P(*pre, "data", None),
        "w_uk": P(*pre, None, "model"),
        "w_uv": P(*pre, None, "model"),
        "wo": P(*pre, "model", "data"),
        "norm_ckv": P(*pre, None),
    }


def mla_forward(params, x, cfg, *, chunk: int = 1024):
    """Train/prefill MLA (expanded form). Returns (out, (c_kv, k_rope))."""
    B, S, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    r, rr, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_head_dim or cfg.hd
    pos = jnp.arange(S)[None, :]
    q = (x @ params["wq"]).reshape(B, S, h, hd + rr)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    c_kv = rms_norm(x @ params["w_dkv"], params["norm_ckv"])   # (B,S,r)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], pos, cfg.rope_theta)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, h, hd)
    v = (c_kv @ params["w_uv"]).reshape(B, S, h, vd)
    # fold the shared rope key into each head by concatenation
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, rr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    out = _chunked_attn(q_full, k_full, v, causal=True, chunk=chunk)
    return out.reshape(B, S, h * vd) @ params["wo"], (c_kv, k_rope[:, :, 0, :])


def mla_decode(params, x, cache_ckv, cache_kr, pos, cfg):
    """Absorbed-form decode against the compressed cache.

    cache_ckv: (B, Smax, r); cache_kr: (B, Smax, rr)."""
    B = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    r, rr, vd = cfg.kv_lora_rank, cfg.rope_head_dim, cfg.v_head_dim or cfg.hd
    q = (x @ params["wq"]).reshape(B, 1, h, hd + rr)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    c_new = rms_norm(x @ params["w_dkv"], params["norm_ckv"])  # (B,1,r)
    kr_new = apply_rope((x @ params["w_kr"])[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]
    at_pos = (jnp.arange(cache_ckv.shape[1])[None, :] == pos[:, None])[:, :, None]
    cache_ckv = jnp.where(at_pos, c_new.astype(cache_ckv.dtype), cache_ckv)
    cache_kr = jnp.where(at_pos, kr_new.astype(cache_kr.dtype), cache_kr)
    # absorb W_uk into q: q_r = q_nope @ W_uk[per head]  -> (B,h,r)
    w_uk = params["w_uk"].reshape(r, h, hd)
    q_r = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_r, cache_ckv.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                         cache_kr.astype(jnp.float32))
    scores *= (hd + rr) ** -0.5
    Smax = cache_ckv.shape[1]
    valid = jnp.arange(Smax)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_r = jnp.einsum("bhs,bsr->bhr", probs, cache_ckv.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", out_r, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, h * vd).astype(x.dtype)
    return out @ params["wo"], cache_ckv, cache_kr
