from repro.models.zoo import build_model

__all__ = ["build_model"]
