"""Whisper-style encoder-decoder backbone (conv/mel frontend is a STUB:
``input_specs`` feeds precomputed frame embeddings to the encoder).

Encoder: bidirectional attention over frames. Decoder: causal self-attn +
cross-attn to encoder states + FFN. Decode carries a self KV cache and a
static cross KV cache computed once from the encoder output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import _chunked_attn, gqa_decode, gqa_pspecs, init_gqa
from repro.models.common import (
    scan_layers,
    residual_hint,
    apply_rope,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_swiglu,
    param_dtype,
    rms_norm,
    shard_hint,
    swiglu,
    swiglu_pspecs,
)


def _init_cross(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h * hd), 0, dtype),
        "wk": dense_init(k2, (d, h * hd), 0, dtype),
        "wv": dense_init(k3, (d, h * hd), 0, dtype),
        "wo": dense_init(k4, (h * hd, d), 0, dtype),
    }


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -------------------------------------------------------------- #
    def _init_enc_layer(self, key):
        cfg = self.cfg
        dt = param_dtype(cfg)
        k1, k2 = jax.random.split(key)
        return {
            "attn": init_gqa(k1, cfg, dt),
            "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, dt),
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
        }

    def _init_dec_layer(self, key):
        cfg = self.cfg
        dt = param_dtype(cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "self_attn": init_gqa(k1, cfg, dt),
            "cross_attn": _init_cross(k2, cfg, dt),
            "mlp": init_swiglu(k3, cfg.d_model, cfg.d_ff, dt),
            "norm1": jnp.ones((cfg.d_model,), dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "norm3": jnp.ones((cfg.d_model,), dt),
        }

    def init(self, rng) -> Dict:
        cfg = self.cfg
        dt = param_dtype(cfg)
        ks = jax.random.split(rng, 5)
        return {
            "embed": embed_init(ks[0], (cfg.vocab_padded, cfg.d_model), dt),
            "enc_layers": jax.vmap(self._init_enc_layer)(
                jax.random.split(ks[1], cfg.encoder_layers)
            ),
            "dec_layers": jax.vmap(self._init_dec_layer)(
                jax.random.split(ks[2], cfg.n_layers)
            ),
            "enc_norm": jnp.ones((cfg.d_model,), dt),
            "final_norm": jnp.ones((cfg.d_model,), dt),
            "lm_head": dense_init(ks[3], (cfg.d_model, cfg.vocab_padded), 0, dt),
        }

    def param_pspecs(self) -> Dict:
        enc = {
            "attn": gqa_pspecs(True),
            "mlp": swiglu_pspecs(True),
            "norm1": P("layers", None),
            "norm2": P("layers", None),
        }
        dec = {
            "self_attn": gqa_pspecs(True),
            "cross_attn": gqa_pspecs(True),
            "mlp": swiglu_pspecs(True),
            "norm1": P("layers", None),
            "norm2": P("layers", None),
            "norm3": P("layers", None),
        }
        return {
            "embed": P("model", "data"),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_norm": P(None),
            "final_norm": P(None),
            "lm_head": P("data", "model"),
        }

    # -------------------------------------------------------------- #
    def encode(self, params, frames):
        """frames: (B, S_enc, d) precomputed embeddings (frontend stub)."""
        cfg = self.cfg
        x = frames.astype(param_dtype(cfg))
        x = residual_hint(x)

        def body(x, lp):
            def f(lp_, x_):
                h = rms_norm(x_, lp_["norm1"])
                B, S, d = h.shape
                hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
                q = (h @ lp_["attn"]["wq"]).reshape(B, S, hq, hd)
                k = (h @ lp_["attn"]["wk"]).reshape(B, S, hkv, hd)
                v = (h @ lp_["attn"]["wv"]).reshape(B, S, hkv, hd)
                pos = jnp.arange(S)[None, :]
                q = apply_rope(q, pos, cfg.rope_theta)
                k = apply_rope(k, pos, cfg.rope_theta)
                attn = _chunked_attn(q, k, v, causal=False)  # bidirectional
                x_ = x_ + attn.reshape(B, S, hq * hd) @ lp_["attn"]["wo"]
                h2 = rms_norm(x_, lp_["norm2"])
                return x_ + swiglu(h2, lp_["mlp"]["w_gate"], lp_["mlp"]["w_up"],
                                   lp_["mlp"]["w_down"])

            return jax.checkpoint(f)(lp, x), None

        x, _ = scan_layers(body, x, params["enc_layers"], cfg.unroll_layers)
        return rms_norm(x, params["enc_norm"])

    def _cross(self, lp, x, enc_out):
        cfg = self.cfg
        B, S, d = x.shape
        h, hd = cfg.n_heads, cfg.hd
        q = (x @ lp["wq"]).reshape(B, S, h, hd)
        k = (enc_out @ lp["wk"]).reshape(B, enc_out.shape[1], h, hd)
        v = (enc_out @ lp["wv"]).reshape(B, enc_out.shape[1], h, hd)
        out = _chunked_attn(q, k, v, causal=False)
        return out.reshape(B, S, h * hd) @ lp["wo"]

    def decode_stack(self, params, tokens, enc_out):
        cfg = self.cfg
        x = params["embed"][tokens]
        x = residual_hint(x)

        def body(x, lp):
            def f(lp_, x_):
                h = rms_norm(x_, lp_["norm1"])
                B, S, d = h.shape
                hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
                q = (h @ lp_["self_attn"]["wq"]).reshape(B, S, hq, hd)
                k = (h @ lp_["self_attn"]["wk"]).reshape(B, S, hkv, hd)
                v = (h @ lp_["self_attn"]["wv"]).reshape(B, S, hkv, hd)
                pos = jnp.arange(S)[None, :]
                q = apply_rope(q, pos, cfg.rope_theta)
                k = apply_rope(k, pos, cfg.rope_theta)
                attn = _chunked_attn(q, k, v, causal=True)
                x_ = x_ + attn.reshape(B, S, hq * hd) @ lp_["self_attn"]["wo"]
                h2 = rms_norm(x_, lp_["norm2"])
                x_ = x_ + self._cross(lp_["cross_attn"], h2, enc_out)
                h3 = rms_norm(x_, lp_["norm3"])
                return x_ + swiglu(h3, lp_["mlp"]["w_gate"], lp_["mlp"]["w_up"],
                                   lp_["mlp"]["w_down"])

            return jax.checkpoint(f)(lp, x), None

        x, _ = scan_layers(body, x, params["dec_layers"], cfg.unroll_layers)
        return rms_norm(x, params["final_norm"])

    def loss(self, params, batch):
        """batch: {"frames": (B,S_enc,d), "tokens": (B,S_dec+1)}."""
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        h = self.decode_stack(params, tokens[:, :-1], enc_out)
        logits = h @ params["lm_head"]
        return cross_entropy_loss(logits, tokens[:, 1:], self.cfg.vocab_padded)

    # -------------------------------------------------------------- #
    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((L, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
            "cross_k": jnp.zeros((L, batch, seq, cfg.n_heads, cfg.hd), dtype),
            "cross_v": jnp.zeros((L, batch, seq, cfg.n_heads, cfg.hd), dtype),
        }

    def cache_pspecs(self):
        kv = P(None, ("pod", "data"), "model", None, None)
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv}

    def prefill(self, params, frames, tokens, cache_len: int):
        """Encode frames, run the decoder prompt, build both caches."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        x = params["embed"][tokens]

        def body(x, lp):
            h = rms_norm(x, lp["norm1"])
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            q = (h @ lp["self_attn"]["wq"]).reshape(B, S, hq, hd)
            k = (h @ lp["self_attn"]["wk"]).reshape(B, S, hkv, hd)
            v = (h @ lp["self_attn"]["wv"]).reshape(B, S, hkv, hd)
            pos = jnp.arange(S)[None, :]
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
            attn = _chunked_attn(q, k, v, causal=True)
            x = x + attn.reshape(B, S, hq * hd) @ lp["self_attn"]["wo"]
            h2 = rms_norm(x, lp["norm2"])
            x = x + self._cross(lp["cross_attn"], h2, enc_out)
            h3 = rms_norm(x, lp["norm3"])
            x = x + swiglu(h3, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
            ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(
                B, enc_out.shape[1], cfg.n_heads, hd)
            cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(
                B, enc_out.shape[1], cfg.n_heads, hd)
            kv = {"k": _pad(k, cache_len), "v": _pad(v, cache_len),
                  "cross_k": _pad(ck, cache_len), "cross_v": _pad(cv, cache_len)}
            return x, kv

        x, cache = scan_layers(body, x, params["dec_layers"], cfg.unroll_layers)
        h = rms_norm(x[:, -1:], params["final_norm"])
        return h @ params["lm_head"], cache

    def decode_step(self, params, cache, tokens, pos, **_):
        cfg = self.cfg
        B = tokens.shape[0]
        x = params["embed"][tokens]

        def body(carry, lp):
            x, sk, sv, i = carry
            ck = jax.lax.dynamic_index_in_dim(sk, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(sv, i, 0, keepdims=False)
            xk = jax.lax.dynamic_index_in_dim(cache["cross_k"], i, 0, keepdims=False)
            xv = jax.lax.dynamic_index_in_dim(cache["cross_v"], i, 0, keepdims=False)
            h = rms_norm(x, lp["norm1"])
            attn, ck2, cv2 = gqa_decode(lp["self_attn"], h, ck, cv, pos, cfg)
            x = x + attn
            h2 = rms_norm(x, lp["norm2"])
            # cross attention against the static cross cache
            hq, hd = cfg.n_heads, cfg.hd
            q = (h2 @ lp["cross_attn"]["wq"]).reshape(B, 1, hq, hd)
            scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                                xk.astype(jnp.float32)) * (hd ** -0.5)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqs,bshd->bqhd", probs, xv.astype(jnp.float32))
            x = x + out.reshape(B, 1, hq * hd).astype(x.dtype) @ lp["cross_attn"]["wo"]
            h3 = rms_norm(x, lp["norm3"])
            x = x + swiglu(h3, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                           lp["mlp"]["w_down"])
            sk = jax.lax.dynamic_update_slice_in_dim(sk, ck2[None].astype(sk.dtype), i, 0)
            sv = jax.lax.dynamic_update_slice_in_dim(sv, cv2[None].astype(sv.dtype), i, 0)
            return (x, sk, sv, i + 1), None

        (x, sk, sv, _), _ = scan_layers(
            body, (x, cache["k"], cache["v"], jnp.int32(0)),
            params["dec_layers"], cfg.unroll_layers,
        )
        new_cache = {"k": sk, "v": sv,
                     "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
        h = rms_norm(x, params["final_norm"])
        return h @ params["lm_head"], new_cache


def _pad(x, target: int):
    pad = target - x.shape[1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[1] = (0, pad)
    return jnp.pad(x, widths)
