"""Open-loop workload generation (paper §3 "Profiling Setting", §6.1).

The paper enhances redis-benchmark to send queries *without waiting for
replies* (open-loop, [Schroeder'06, Treadmill]) so queueing delay during a
fork stall is charged to query latency. We pre-generate arrival timestamps
at a fixed rate and measure ``completion - arrival``.

Patterns mirror Memtier's: uniform random keys, Gaussian (hot center), and
Zipfian; mixes are given as SET:GET ratios (Fig 12). ``clients`` scales the
number of concurrent in-flight generators: more clients = more distinct
keys touched per unit time (Fig 13's effect on proactive-sync burstiness).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class QueryEvent:
    t: float          # scheduled (open-loop) arrival, seconds from run start
    op: str           # "set" | "get"
    rows: np.ndarray  # key rows touched by this query batch


@dataclasses.dataclass
class Workload:
    """A reproducible query stream."""

    rate_qps: float = 2000.0       # query events per second
    set_ratio: float = 1.0         # P(op == set)  (1.0 = write-only, Fig 9)
    pattern: str = "uniform"       # uniform | gaussian | zipf
    batch: int = 16                # keys per query event (vectorization unit)
    clients: int = 50              # concurrent open-loop clients (Fig 13)
    seed: int = 0

    def events(self, capacity: int, duration_s: float) -> List[QueryEvent]:
        rng = np.random.default_rng(self.seed)
        n = int(self.rate_qps * duration_s)
        # Poisson arrivals per client, merged — open-loop clients do not
        # coordinate, so bursts of up to ``clients`` queries arrive together.
        per_client = max(1, n // max(1, self.clients))
        arrivals = []
        for c in range(self.clients):
            gaps = rng.exponential(1.0 / (self.rate_qps / self.clients), per_client)
            arrivals.append(np.cumsum(gaps))
        t = np.sort(np.concatenate(arrivals))[:n]
        t = t[t < duration_s]
        ops = rng.uniform(size=t.shape[0]) < self.set_ratio
        out: List[QueryEvent] = []
        for i in range(t.shape[0]):
            rows = self._keys(rng, capacity)
            out.append(QueryEvent(float(t[i]), "set" if ops[i] else "get", rows))
        return out

    def writer_streams(
        self,
        capacity: int,
        duration_s: float,
        writers: int,
        spans: Optional[List] = None,
    ) -> List[List[QueryEvent]]:
        """Per-thread open-loop streams for the multi-writer contention
        benchmark (PR 5): ``writers`` independent generators, each confined
        to its own key span. The default carves disjoint even slices of
        the key space (K writers over N range-partitioned shards give each
        shard ~K/N dedicated writers); an explicit ``spans`` list may
        overlap — overlapping writers then contend on the same gate
        stripe and overwrite each other's keys, which is fine for a
        contention benchmark but not for tests that check per-writer
        values.

        Each stream divides this workload's aggregate ``rate_qps`` (and
        its ``clients``) evenly and draws from an independent seed, so the
        union behaves like :meth:`events` while every stream stays
        replayable on its own thread."""
        writers = max(1, int(writers))
        out: List[List[QueryEvent]] = []
        for w in range(writers):
            lo, hi = (
                spans[w] if spans is not None
                else (w * capacity // writers, (w + 1) * capacity // writers)
            )
            sub = dataclasses.replace(
                self,
                rate_qps=self.rate_qps / writers,
                clients=max(1, self.clients // writers),
                seed=self.seed + 7919 * (w + 1),
            )
            evs = sub.events(hi - lo, duration_s)
            for ev in evs:
                ev.rows = ev.rows + lo  # shift into the writer's span
            out.append(evs)
        return out

    def reader_streams(
        self,
        capacity: int,
        duration_s: float,
        readers: int,
        spans: Optional[List] = None,
    ) -> List[List[QueryEvent]]:
        """Per-thread open-loop GET streams for the multi-reader benchmark
        (spawn-db-gets style): the reader-side mirror of
        :meth:`writer_streams` — same span carving, rate/client division
        and independent seeds, but every event is a ``get``."""
        return dataclasses.replace(self, set_ratio=0.0).writer_streams(
            capacity, duration_s, readers, spans
        )

    def _keys(self, rng: np.random.Generator, capacity: int) -> np.ndarray:
        """One query = ``batch`` consecutive keys from a pattern-drawn base
        (a pipelined redis-benchmark request touches one locality region)."""
        if self.pattern == "uniform":
            base = int(rng.integers(0, capacity))
        elif self.pattern == "gaussian":
            base = int(np.clip(rng.normal(capacity / 2, capacity / 16), 0, capacity - 1))
        elif self.pattern == "zipf":
            base = int((rng.zipf(1.2) - 1) % capacity)
        else:
            raise ValueError(f"unknown pattern {self.pattern!r}")
        base = (base // self.batch) * self.batch  # slot-aligned: stable jit shapes
        return ((base + np.arange(self.batch)) % capacity).astype(np.int64)
