"""The serving engine: processes the open-loop query stream, takes BGSAVE
snapshots with a pluggable snapshotter, and records per-query latency
split into *normal* vs *snapshot* queries (paper §3 "Profiling Setting").

A sharded store (:class:`ShardedKVStore`) swaps the single snapshotter for
a :class:`ShardedSnapshotCoordinator`: BGSAVE becomes a fork barrier over
all shards and persist runs through the shared parallel pipeline, while
per-shard metrics aggregate into the same :class:`EngineReport`.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.catalog import EpochRef, SnapshotCatalog
from repro.core.coordinator import CoordinatedSnapshot, ShardedSnapshotCoordinator
from repro.core.policy import BgsavePolicy, CopierDutyController
from repro.core.sinks import NullSink, Sink
from repro.core.snapshot import SnapshotHandle, make_snapshotter
from repro.kvstore.store import CowKVStore, KVStore, ShardedKVStore
from repro.kvstore.workload import Workload


@dataclasses.dataclass
class EngineReport:
    """Latency/throughput summary (Figs 4/5/9/10/17-20)."""

    mode: str
    instance_bytes: int
    normal_lat: np.ndarray      # seconds
    snapshot_lat: np.ndarray    # queries arriving inside a snapshot window
    snapshot_metrics: List[Dict[str, float]]
    throughput_buckets: np.ndarray  # completed queries per 50 ms bucket
    duration_s: float
    n_shards: int = 1
    server_stats: Optional[Dict[str, float]] = None  # RequestServer.stats()
    duty_stats: Optional[Dict[str, float]] = None    # CopierDutyController state
    catalog_stats: Optional[Dict[str, float]] = None  # SnapshotCatalog.occupancy()
    maintenance_stats: Optional[Dict[str, float]] = None  # replicator/scrubber

    @staticmethod
    def _pct(x: np.ndarray, q: float) -> float:
        return float(np.percentile(x, q)) if x.size else float("nan")

    def _full_buckets(self) -> np.ndarray:
        """Throughput buckets excluding the trailing one: the measured
        run duration virtually never lands on an exact 50 ms boundary, so
        the final bucket covers a partial interval whose low count would
        bias ``min_tput_qps`` toward zero."""
        b = self.throughput_buckets
        return b[:-1] if b.size > 1 else b

    def summary(self) -> Dict[str, float]:
        tput = self._full_buckets()
        # Per-snapshot summaries may report heterogeneous keys: under a
        # BgsavePolicy, shards that skipped an epoch contribute minimal
        # zero-copy records, so every roll-up merges with defaults instead
        # of assuming a uniform schema (a skip must never KeyError here).
        mets = self.snapshot_metrics
        return {
            "normal_p99_ms": self._pct(self.normal_lat, 99) * 1e3,
            "normal_max_ms": float(self.normal_lat.max() * 1e3) if self.normal_lat.size else float("nan"),
            "snap_p99_ms": self._pct(self.snapshot_lat, 99) * 1e3,
            "snap_max_ms": float(self.snapshot_lat.max() * 1e3) if self.snapshot_lat.size else float("nan"),
            "min_tput_qps": float(tput.min() / 0.05) if tput.size else float("nan"),
            "interruptions": float(sum(m.get("interruptions", 0.0) for m in mets)),
            "out_of_service_ms": float(sum(m.get("out_of_service_ms", 0.0) for m in mets)),
            "gate_wait_us": float(sum(m.get("gate_wait_us", 0.0) for m in mets)),
            "read_retries": float(sum(m.get("read_retries", 0.0) for m in mets)),
            "shared_wait_us": float(sum(m.get("shared_wait_us", 0.0) for m in mets)),
            "persist_retries": float(sum(m.get("persist_retries", 0.0) for m in mets)),
            "persist_aborts": float(sum(m.get("persist_aborts", 0.0) for m in mets)),
            "server_queue_depth": float(
                (self.server_stats or {}).get("queue_depth_max", 0.0)
            ),
            "fork_ms": float(np.mean([m.get("fork_ms", 0.0) for m in mets])) if mets else float("nan"),
            "copy_window_ms": float(np.mean([m.get("copy_window_ms", 0.0) for m in mets])) if mets else float("nan"),
            "stage_ms": float(sum(m.get("stage_ms", 0.0) for m in mets)),
            "write_busy_ms": float(sum(m.get("write_busy_ms", 0.0) for m in mets)),
            "overlap_frac": float(np.mean([m.get("overlap_frac", 0.0) for m in mets])) if mets else float("nan"),
            "copier_duty": float((self.duty_stats or {}).get("copier_duty", float("nan"))),
            "duty_adjustments": float((self.duty_stats or {}).get("duty_adjustments", 0.0)),
            "skipped_shards": float(sum(m.get("skipped_shards", 0.0) for m in mets)),
            "chain_depth_max": float(max(
                (m.get("chain_depth_max", 0.0) for m in mets), default=0.0
            )),
            "aliased_dirs": float(sum(m.get("aliased_dirs", 0.0) for m in mets)),
            "shards": float(self.n_shards),
            # catalog occupancy (prefixed: chain_depth_max above is the
            # per-epoch write-path roll-up, this is the on-disk product)
            "catalog_dirs": float((self.catalog_stats or {}).get("dirs", 0.0)),
            "catalog_bytes": float((self.catalog_stats or {}).get("bytes", 0.0)),
            "catalog_chain_max": float(
                (self.catalog_stats or {}).get("chain_depth_max", 0.0)),
            "catalog_chain_mean": float(
                (self.catalog_stats or {}).get("chain_depth_mean", 0.0)),
            "catalog_quarantined": float(
                (self.catalog_stats or {}).get("quarantined", 0.0)),
            # maintenance plane (replication lag / scrub coverage)
            "replication_lag": float(
                (self.maintenance_stats or {}).get("replication_lag", 0.0)),
            "epochs_shipped": float(
                (self.maintenance_stats or {}).get("epochs_shipped", 0.0)),
            "bytes_shipped": float(
                (self.maintenance_stats or {}).get("bytes_shipped", 0.0)),
            "dirs_scrubbed": float(
                (self.maintenance_stats or {}).get("dirs_scrubbed", 0.0)),
            "corrupt_found": float(
                (self.maintenance_stats or {}).get("corrupt_found", 0.0)),
            "repaired_dirs": float(
                (self.maintenance_stats or {}).get("repaired", 0.0)),
        }


class KVEngine:
    """Single-threaded parent process: queries + BGSAVE forks."""

    def __init__(
        self,
        store: Union[KVStore, ShardedKVStore],
        mode: str = "asyncfork",
        copier_threads: int = 8,
        persist_bandwidth: Optional[float] = 2e9,
        copier_duty: Optional[float] = None,
        backend: str = "host",
        incremental: bool = False,
        persist_workers: Optional[int] = None,
        policy: Optional[BgsavePolicy] = None,
        striped_gates: bool = True,
        catalog: Optional[SnapshotCatalog] = None,
    ):
        """``backend`` selects the staging substrate ("host" numpy or
        "device" Pallas-kernel staging); ``incremental=True`` makes every
        BGSAVE after the first a dirty-block delta against the previous
        epoch's retained T0 image (high-frequency, low-cost BGSAVE).

        A :class:`ShardedKVStore` routes everything through a
        :class:`ShardedSnapshotCoordinator`; ``persist_workers`` sizes its
        shared persist pool (default: one per shard). ``policy`` (a
        :class:`BgsavePolicy`, sharded stores only) replaces the global
        ``incremental`` flag with per-shard full/delta/skip decisions.
        ``striped_gates=False`` aliases every write-gate stripe to one
        global lock (the pre-PR-5 behavior, kept as the contention
        benchmark's baseline arm). ``catalog`` shares a
        :class:`SnapshotCatalog` across engines (a branched child engine
        registers its epochs in its parent's catalog)."""
        self.store = store
        self.mode = mode
        self._backend = backend
        self.branch_ref: Optional[EpochRef] = None
        self._copier_threads = max(1, copier_threads)
        self._auto_duty = copier_duty is None
        if copier_duty is None:
            # single-core host: cap child-side core steal at ~30% for one
            # shard, split across that shard's threads (each added thread
            # shortens the window near-linearly, as the paper's §5.1 kernel
            # threads do). In the cluster model every shard emulates its
            # own host; a full 30% per shard would saturate this one real
            # core by N=4 and flatten the window curve, so the per-shard
            # budget decays as 1/sqrt(N): aggregate steal 0.3*sqrt(N) stays
            # under a core through 8 shards while each shard still gets a
            # bigger slice than a 1/N split — the copy window shrinks
            # ~1/sqrt(N) with shard count. Set copier_duty explicitly on
            # real multi-core hosts.
            copier_duty = 0.3 / max(1, copier_threads) / math.sqrt(max(1, self.n_shards))
        # copy granularity == the store's physical block (one leaf = one
        # "PMD + 512-PTE table"), so block_bytes just needs to cover a leaf
        self.incremental = bool(incremental)
        self.persist_bandwidth = persist_bandwidth
        self._snaps: List[Union[SnapshotHandle, CoordinatedSnapshot]] = []
        snapshotter_kw = dict(
            block_bytes=store.block_nbytes,
            copier_threads=copier_threads,
            copier_duty=copier_duty,
            backend=backend,
            retain_images=self.incremental or policy is not None,
        )
        if isinstance(store, ShardedKVStore):
            self.snapshotter = None
            self.coordinator = ShardedSnapshotCoordinator(
                store.providers, mode=mode,
                persist_workers=persist_workers,
                layout=getattr(store, "layout", None),
                policy=policy, striped_gates=striped_gates,
                catalog=catalog,
                **snapshotter_kw,
            )
            self._write_hook = (
                lambda shard_id, leaf_id, rows=None:
                self.coordinator.before_write(shard_id, leaf_id, rows)
            )
            self._gate_wait_hook = (
                lambda shard_id, wait_s:
                self.coordinator.note_gate_wait(shard_id, wait_s)
            )
            self._read_event_hook = (
                lambda shard_id, retries, shared_wait_s:
                self.coordinator.note_read_event(shard_id, retries,
                                                 shared_wait_s)
            )
        else:
            if policy is not None:
                raise ValueError("BgsavePolicy needs a ShardedKVStore")
            self.coordinator = None
            self.snapshotter = make_snapshotter(
                mode, store.provider,
                persist_workers=persist_workers if persist_workers is not None else 1,
                **snapshotter_kw,
            )
            self._write_hook = (
                lambda leaf_id, rows=None:
                self.snapshotter.before_write(leaf_id, rows)
            )
            self._gate_wait_hook = None
            self._read_event_hook = None
        # Feedback duty loop (DESIGN.md §13): when the duty was auto-derived
        # (not pinned by the caller) and there is a coordinator to steer,
        # each persisted epoch's signals nudge the duty for the next one.
        self._duty_mu = threading.Lock()
        self.duty_controller: Optional[CopierDutyController] = (
            CopierDutyController(copier_duty)
            if self._auto_duty and self.coordinator is not None else None
        )
        # maintenance plane (DESIGN.md §14): attach_maintenance wires a
        # standby-pool shipper and/or background scrubber so their
        # counters land in EngineReport and the catalog can re-fetch
        self.replicator = None
        self.scrubber = None

    @property
    def n_shards(self) -> int:
        """Shard count under the store's CURRENT layout (resharding moves
        it mid-run, so nothing caches it)."""
        return getattr(self.store, "n_shards", 1)

    @property
    def _gate(self):
        """LIVE write-gate accessor. Never cache the coordinator's gate
        object on the engine: a layout swap replaces stripes inside the
        :class:`~repro.core.gates.GateSet` (and a future coordinator swap
        would replace the set wholesale) — the pre-PR-5 engine cached the
        construction-time gate and would have committed writes under a
        stale gate after any such swap."""
        return None if self.coordinator is None else self.coordinator.gates

    # -- snapshot reads & branches (DESIGN.md §11) ------------------------
    @property
    def catalog(self) -> SnapshotCatalog:
        """The coordinator's :class:`SnapshotCatalog` (epoch registry)."""
        if self.coordinator is None:
            raise ValueError("the snapshot catalog needs a ShardedKVStore "
                             "engine")
        return self.coordinator.catalog

    def attach_maintenance(self, replicator=None, scrubber=None) -> None:
        """Wire the maintenance plane: an
        :class:`~repro.core.replicate.EpochReplicator` (also registered
        as the catalog's re-fetch source) and/or an
        :class:`~repro.core.scrub.EpochScrubber`. Their counters are
        merged into :meth:`run`'s ``EngineReport``."""
        if replicator is not None:
            self.replicator = replicator
            self.catalog.attach_replica(replicator)
        if scrubber is not None:
            self.scrubber = scrubber

    def _maintenance_stats(self) -> Optional[Dict[str, float]]:
        """Summed replicator+scrubber counters (they may share one
        :class:`MaintenanceMetrics` or carry their own), plus the live
        replication lag; None when nothing is attached."""
        if self.replicator is None and self.scrubber is None:
            return None
        out: Dict[str, float] = {}
        seen = []
        for worker in (self.replicator, self.scrubber):
            if worker is None or any(worker.metrics is m for m in seen):
                continue
            seen.append(worker.metrics)
            for k, v in worker.metrics.summary().items():
                out[k] = out.get(k, 0.0) + v
        if self.replicator is not None:
            out["replication_lag"] = float(self.replicator.lag())
        return out

    def get_at(self, rows, epoch: Union[int, EpochRef]) -> np.ndarray:
        """Point-in-time read: gather ``rows`` as they were at ``epoch``.

        Accepts either a pinned :class:`EpochRef` (the caller controls
        the pin lifetime — amortize it over many reads) or a bare epoch
        id (pinned transiently for exactly this call). The gather routes
        under the EPOCH's frozen layout and never touches the live read
        plane, so it needs no gate, seqlock, or retry discipline and
        cannot perturb live traffic (beyond sharing cores)."""
        if self.coordinator is None:
            raise ValueError("get_at() needs a ShardedKVStore engine")
        rows = np.asarray(rows)
        if isinstance(epoch, EpochRef):
            return self.store.get_at(rows, epoch)
        ref = self.catalog.pin(int(epoch))
        try:
            return self.store.get_at(rows, ref)
        finally:
            ref.release()

    def branch(self, epoch: Union[int, EpochRef]) -> "KVEngine":
        """Fork a writable child engine off a cataloged epoch, zero-copy.

        The child's shards are :class:`CowKVStore` instances wrapping the
        epoch's immutable block images directly (``KVStore.from_blocks``
        machinery — no bytes move at fork time); the first write to a
        block pays one host-to-device materialization (a COW fault) and
        from then on the block lives in the child. The parent's images
        are never written — branch and parent diverge freely. The child
        holds its OWN pin on the epoch (``child.branch_ref``): release it
        when the branch is torn down, or the epoch's dirs stay pinned.
        The child registers snapshots in the parent's catalog, so branch
        epochs participate in the same refcount/GC graph."""
        if self.coordinator is None:
            raise ValueError("branch() needs a ShardedKVStore engine")
        eid = epoch.epoch_id if isinstance(epoch, EpochRef) else int(epoch)
        ref = self.catalog.pin(eid)  # the child's own pin
        try:
            layout = ref.layout
            n = layout.n_shards if layout is not None else self.n_shards
            shards = [
                CowKVStore.from_frozen_blocks(
                    ref.shard_blocks(k),
                    self.store.row_width, self.store.block_rows,
                )
                for k in range(n)
            ]
        except BaseException:
            ref.release()
            raise
        child_store = ShardedKVStore.from_shards(
            shards, self.store.row_width, self.store.block_rows, layout
        )
        child = KVEngine(
            child_store, mode=self.mode,
            copier_threads=self._copier_threads,
            persist_bandwidth=self.persist_bandwidth,
            backend=self._backend,
            incremental=self.incremental,
            catalog=self.catalog,
        )
        child.branch_ref = ref
        return child

    # -- online resharding ------------------------------------------------
    def split(self, shard_id: int, at_block: Optional[int] = None):
        """Split a shard online: store split + coordinator layout swap as
        one atomic step under the write gate, so a concurrent BGSAVE
        barrier either completes first or sees the new layout whole —
        never a half-swapped one (DESIGN.md §8). Queries stall for at most
        one gate interval. Must run on the serving thread (the paper's
        single-threaded parent; ``run(actions=...)`` fires it there) — the
        gate serializes against barriers, not against a query batch whose
        routing was already resolved. Returns the successor layout."""
        if self.coordinator is None:
            raise ValueError("resharding needs a ShardedKVStore engine")
        with self.coordinator.write_gate:
            layout = self.store.split(shard_id, at_block)
            self.coordinator.set_layout(self.store.providers, layout)
            self._retune_duty()
        return layout

    def merge(self, shard_id: int, other: int):
        """Merge adjacent shards online (same gate discipline as split)."""
        if self.coordinator is None:
            raise ValueError("resharding needs a ShardedKVStore engine")
        with self.coordinator.write_gate:
            layout = self.store.merge(shard_id, other)
            self.coordinator.set_layout(self.store.providers, layout)
            self._retune_duty()
        return layout

    def _retune_duty(self) -> None:
        """After a reshard, re-derive the default 1/sqrt(N) per-shard
        copier budget for the NEW shard count — snapshotters created by
        the layout swap would otherwise inherit the construction-time
        duty and overshoot the aggregate core-steal budget. A caller who
        pinned ``copier_duty`` explicitly keeps their value. With the
        feedback controller active this RESEEDS it (the shard count its
        old operating point was learned under no longer exists)."""
        if self._auto_duty:
            duty = 0.3 / self._copier_threads / math.sqrt(max(1, self.n_shards))
            if self.duty_controller is not None:
                with self._duty_mu:
                    duty = self.duty_controller.reseed(duty)
            self.coordinator.set_copier_duty(duty)

    def _feed_duty_controller(self, snap) -> None:
        """Observe one epoch for the feedback loop: a small daemon waits
        for the epoch to persist, folds its metered signals into the
        controller, and pushes the adjusted duty onto the live
        snapshotters for the NEXT epoch. Runs off the serving thread —
        the whole point is never to stall queries on the persist tail."""
        ctl = self.duty_controller
        if ctl is None:
            return

        def _observe():
            try:
                snap.wait_persisted(120)
            except Exception:
                return  # aborted epoch: no trustworthy signals
            s = snap.metrics.summary()
            with self._duty_mu:
                prev = ctl.duty
                new = ctl.update(
                    gate_wait_us=s.get("gate_wait_us", 0.0),
                    stage_s=s.get("stage_ms", 0.0) / 1e3,
                    sink_write_s=s.get("sink_write_ms", 0.0) / 1e3,
                    copy_window_s=s.get("copy_window_ms", 0.0) / 1e3,
                    dirty_frac=s.get("dirty_frac_mean",
                                     s.get("dirty_frac", float("nan"))),
                )
            if new != prev:
                self.coordinator.set_copier_duty(new)

        threading.Thread(target=_observe, daemon=True).start()

    def load(self, directory: str) -> None:
        """Restore a snapshot into the store's current layout, safely.

        The raw ``ShardedKVStore.load`` rebinds blocks WITHOUT routing
        through ``before_write``, which would silently break the policy's
        zero-write skip proof, any retained dirty-diff base, AND any
        in-flight epoch's point-in-time cut — so this wrapper refuses to
        run while epochs are active (``wait_all()`` first), then holds
        the write gate and invalidates every retained base: the next
        epoch per shard is a full snapshot."""
        if self.coordinator is None:
            raise ValueError("load() needs a ShardedKVStore engine")
        with self.coordinator.write_gate:
            if self.coordinator.has_active_epochs():
                raise RuntimeError(
                    "cannot load() with snapshot epochs in flight — their "
                    "point-in-time cut would mix pre- and post-load bytes; "
                    "call coordinator.wait_all() first"
                )
            self.store.load(directory)
            self.coordinator.invalidate_bases()

    def _default_sinks(self):
        """One paced NullSink per shard — the cluster model gives each
        shard its own disk stream, so bandwidth is per-shard."""
        return [NullSink(bandwidth=self.persist_bandwidth)
                for _ in range(self.n_shards)]

    def bgsave(self, sink: Optional[Sink] = None, sinks=None):
        if self.coordinator is not None:
            if sink is not None:
                raise ValueError("sharded engine takes per-shard `sinks`")
            if sinks is None:
                sinks = self._default_sinks()
            snap = self.coordinator.bgsave(sinks=sinks, incremental=self.incremental)
        else:
            if sink is None:
                sink = NullSink(bandwidth=self.persist_bandwidth)
            snap = self.snapshotter.fork(sink, incremental=self.incremental)
        self._snaps.append(snap)
        self._feed_duty_controller(snap)
        return snap

    def _bgsave_from_factory(self, sink_factory):
        """``sink_factory`` takes the shard id when sharded, nothing when
        single-shard (matching ``run``'s public contract)."""
        if sink_factory is None:
            return self.bgsave()
        if self.coordinator is not None:
            return self.bgsave(sinks=[sink_factory(k) for k in range(self.n_shards)])
        return self.bgsave(sink=sink_factory())

    def run(
        self,
        workload: Workload,
        duration_s: float,
        bgsave_at: Tuple[float, ...] = (0.25,),
        sink_factory=None,
        actions: Optional[Sequence[Tuple[float, Callable[[], None]]]] = None,
    ) -> EngineReport:
        """Drive the open-loop stream; BGSAVE at given fractions of the run.

        For a sharded engine ``sink_factory`` takes the shard id and is
        called once per shard per BGSAVE. ``actions`` are extra inline
        ``(fraction, callable)`` triggers on the serving thread — e.g. a
        reshard (``lambda: self.split(0)``) landing mid-snapshot; like the
        paper's fork they stall the parent for exactly their own duration.
        """
        store = self.store
        store.warmup(batch=workload.batch)
        events = workload.events(store.capacity, duration_s)
        vals_pool = np.random.rand(64, workload.batch, store.row_width).astype(np.float32)
        bgsave_times = sorted(f * duration_s for f in bgsave_at)
        pending_actions = sorted(
            [(f * duration_s, fn) for f, fn in (actions or [])],
            key=lambda t: t[0],  # callables don't order
        )
        windows: List[Union[SnapshotHandle, CoordinatedSnapshot]] = []

        lat: List[Tuple[float, float]] = []  # (arrival, latency)
        t0 = time.perf_counter()
        bg_i = 0
        for i, ev in enumerate(events):
            now = time.perf_counter() - t0
            # BGSAVE trigger (the parent invokes fork inline — it stalls here)
            while bg_i < len(bgsave_times) and now >= bgsave_times[bg_i]:
                windows.append(self._bgsave_from_factory(sink_factory))
                bg_i += 1
                now = time.perf_counter() - t0
            while pending_actions and now >= pending_actions[0][0]:
                _, fn = pending_actions.pop(0)
                fn()
                now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            if ev.op == "set":
                if self.coordinator is not None:
                    store.set(ev.rows, vals_pool[i % 64],
                              before_write=self._write_hook, gate=self._gate,
                              on_gate_wait=self._gate_wait_hook)
                else:
                    store.set(ev.rows, vals_pool[i % 64],
                              before_write=self._write_hook, gate=self._gate)
            elif self.coordinator is not None:
                # the concurrent-safe read plane: other threads (a
                # RequestServer's readers) may be gathering alongside this
                # serving loop, and its own reads must survive a reshard
                # action or a racing reader-triggered retry identically
                store.get_concurrent(ev.rows, gate=self._gate,
                                     on_read_event=self._read_event_hook)
            else:
                store.get(ev.rows)
            lat.append((ev.t, (time.perf_counter() - t0) - ev.t))
        # actions scheduled at/after the last event arrival must still
        # fire (a silent no-op would fake e.g. a reshard measurement)
        for t_act, fn in pending_actions:
            now = time.perf_counter() - t0
            if t_act > now:
                time.sleep(t_act - now)
            fn()
        run_end = time.perf_counter() - t0

        # classify: snapshot queries arrive in [fork_start, persist_done].
        # The span anchors at the REAL fork timestamp the snapshotter
        # stamped on the handle — not the scheduled bgsave time — so
        # queries served between schedule and actual fork stay "normal".
        spans = []
        for snap in windows:
            snap.wait_persisted(120)
            lo = snap.fork_start - t0
            hi = (snap.t0 - t0) + snap.metrics.persist_s
            spans.append((lo, hi))
        normal, snapq = [], []
        for t_a, l in lat:
            if any(lo <= t_a <= hi for lo, hi in spans):
                snapq.append(l)
            else:
                normal.append(l)
        compl = np.sort(np.array([t + l for t, l in lat]))
        buckets = np.bincount((compl / 0.05).astype(int)) if compl.size else np.array([0])
        return EngineReport(
            mode=self.mode,
            instance_bytes=store.nbytes,
            normal_lat=np.array(normal),
            snapshot_lat=np.array(snapq),
            snapshot_metrics=[s.metrics.summary() for s in windows],
            throughput_buckets=buckets,
            duration_s=run_end,
            n_shards=self.n_shards,
            duty_stats=(
                {
                    "copier_duty": self.duty_controller.duty,
                    "duty_adjustments": float(self.duty_controller.adjustments),
                }
                if self.duty_controller is not None else None
            ),
            catalog_stats=(
                self.coordinator.catalog.occupancy()
                if self.coordinator is not None else None
            ),
            maintenance_stats=self._maintenance_stats(),
        )
