"""A Redis-like in-memory KV store built on JAX.

The store is the paper's "parent process": a value table of ``capacity``
rows × ``row_width`` float32 (1 KiB values at width 256 — the paper's
benchmark value size), **physically blocked** into per-block device arrays
of ``block_rows`` rows. A SET donates only the touched block's buffer —
the analogue of a PMD-granular write — so the snapshot core can protect
exactly the about-to-die block (proactive synchronization) while the
copier reads every other block race-free. Keys address rows directly, as
redis-benchmark's integer key space does.
"""
from __future__ import annotations

import re
import time
from functools import partial
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gates import GateRetired, GateSet
from repro.core.layout import ShardLayout
from repro.core.provider import PyTreeProvider
from repro.core.sinks import read_file_snapshot, read_snapshot_layout


@partial(jax.jit, donate_argnums=(0,))
def _scatter_set(block, rows, vals):
    return block.at[rows].set(vals)


@jax.jit
def _gather_get(block, rows):
    return block[rows]


def _consecutive_runs(groups):
    """Yield slices of ``groups`` (tuples whose first element is a block
    id, in ascending order) covering maximal runs of consecutive blocks —
    the read/write analogue of the persist path's run unit."""
    i = 0
    while i < len(groups):
        j = i + 1
        while j < len(groups) and groups[j][0] == groups[j - 1][0] + 1:
            j += 1
        yield groups[i:j]
        i = j


class KVStore:
    """Blocked value table + provider integration for the snapshot core."""

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
    ):
        self.block_rows = int(block_rows)
        # round capacity up to a whole number of blocks (uniform jit shapes)
        self.n_blocks = max(1, -(-int(capacity) // self.block_rows))
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        key = jax.random.PRNGKey(seed)
        blocks = []
        for b in range(self.n_blocks):
            key, sub = jax.random.split(key)
            blocks.append(
                jax.random.uniform(sub, (self.block_rows, self.row_width), jnp.float32)
            )
        # list pytree: leaf b <-> block b (one "PMD + PTE table" per leaf)
        self.provider = PyTreeProvider({"blocks": blocks})

    @classmethod
    def from_blocks(
        cls, blocks: Sequence, row_width: int, block_rows: int
    ) -> "KVStore":
        """Wrap EXISTING device blocks in a new store (zero data movement).

        The reshard primitive: a split hands each child the same
        ``jax.Array`` objects the parent shard held, under a fresh
        provider — in-flight snapshot epochs keep reading the buffers
        through the old provider while new writes route (and donate)
        through this one, protected by the same proactive-sync contract.
        """
        self = cls.__new__(cls)
        self.block_rows = int(block_rows)
        self.n_blocks = len(blocks)
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        self.provider = PyTreeProvider({"blocks": list(blocks)})
        return self

    def blocks_list(self) -> List:
        """The live device blocks, in block order."""
        return [self.provider.leaf(b) for b in range(self.n_blocks)]

    @property
    def block_nbytes(self) -> int:
        return self.block_rows * self.row_width * 4

    @property
    def nbytes(self) -> int:
        return self.capacity * self.row_width * 4

    def _split(self, rows: np.ndarray):
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            yield int(b), rows[bids == b] - b * self.block_rows

    def set(
        self,
        rows: np.ndarray,
        vals: np.ndarray,
        before_write: Optional[Callable[[int, np.ndarray], None]] = None,
        gate=None,
    ) -> None:
        """Donated scatter write; ``before_write(leaf_id, local_rows)`` is
        the proactive synchronization hook invoked before each touched
        block dies. The hook receives the leaf-local row indices so a
        multi-block leaf syncs only the blocks the write will actually kill
        (row→block-precise, DESIGN.md §2) instead of the whole leaf.

        ``gate`` (a lock/context manager) is held ONCE across the whole
        batch's sync → donated commits (one acquisition per call, not one
        per block as before PR 5), so a concurrent snapshot fork barrier
        can never land between a write's proactive sync and its buffer
        swap — and a single-shard batch is atomic w.r.t. the barrier."""
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        if gate is None:
            self._commit(rows, vals, before_write)
        else:  # locks and context managers alike support `with`
            with gate:
                self._commit(rows, vals, before_write)

    def _commit(
        self,
        rows: np.ndarray,
        vals: np.ndarray,
        before_write: Optional[Callable[[int, np.ndarray], None]] = None,
    ) -> None:
        """Batched scatter commit — caller holds the write gate (or runs
        ungated, the paper's single-threaded parent).

        Touched blocks are grouped once, adjacent block ids coalesce into
        runs (the same unit the persist path moves, DESIGN.md §7), and
        each run commits with ONE device conversion of the batch values
        and ONE ``block_until_ready`` instead of per-block round trips.
        Within a run every block's proactive sync happens before ANY of
        the run's buffers is donated, so the §4.2 protect-before-kill
        contract holds block-for-block."""
        bids = rows // self.block_rows
        groups = []
        for b in np.unique(bids):
            pos = np.nonzero(bids == b)[0]
            groups.append((int(b), rows[pos] - int(b) * self.block_rows, pos))
        vals_dev = None
        for run in _consecutive_runs(groups):
            if before_write is not None:
                for b, local, _ in run:
                    # sync the block's touched rows in all active snapshots
                    before_write(b, local)
            if vals_dev is None:
                vals_dev = jnp.asarray(vals)  # one H2D for the whole batch
            staged = []
            for b, local, pos in run:
                v = vals_dev if len(pos) == rows.shape[0] \
                    else vals_dev[jnp.asarray(pos)]
                staged.append(
                    (b, _scatter_set(self.provider.leaf(b), jnp.asarray(local), v))
                )
            jax.block_until_ready([a for _, a in staged])
            for b, new in staged:
                self.provider.update_leaf(b, new)  # old was donated by XLA

    def get(self, rows: np.ndarray) -> np.ndarray:
        """Gather read. Contiguous touched-block runs are serviced with
        one gather concatenation and ONE device-to-host transfer per run
        (mirroring the persist path's run-writes) instead of one D2H per
        block."""
        outs = []
        for run in _consecutive_runs(list(self._split(rows))):
            parts = [
                _gather_get(self.provider.leaf(b), jnp.asarray(local))
                for b, local in run
            ]
            merged = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            outs.append(np.asarray(merged))  # one D2H per contiguous run
        return np.concatenate(outs) if outs else np.empty((0, self.row_width), np.float32)

    def read_all(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.provider.leaf(b)) for b in range(self.n_blocks)]
        )

    def warmup(self, batch: int = 4) -> None:
        """Trigger jit compiles outside the measured window."""
        rows = np.arange(batch, dtype=np.int64)
        vals = np.zeros((batch, self.row_width), np.float32)
        self.set(rows, vals)
        self.get(rows)


class CowKVStore(KVStore):
    """A writable branch shard wrapping a parent epoch's IMMUTABLE block
    images (host numpy: staging buffers while the epoch is live, memmaps
    off its manifests otherwise) and copy-on-writing only dirtied blocks.

    The fork itself is O(metadata): no block is copied until written.
    The donated-scatter hot path cannot donate a numpy buffer (and must
    never mutate the parent's image), so :meth:`_commit` first
    **materializes** each touched numpy leaf as a fresh device array (the
    COW fault — one H2D per block, paid once) and then commits through
    the normal donation path; the parent's buffers are never written.
    ``cow_faults`` counts materialized blocks.
    """

    cow_faults = 0

    @classmethod
    def from_frozen_blocks(
        cls, blocks: Sequence[np.ndarray], row_width: int, block_rows: int
    ) -> "CowKVStore":
        self = cls.from_blocks(list(blocks), row_width, block_rows)
        self.cow_faults = 0
        return self

    def _commit(self, rows, vals, before_write=None):
        bids = np.unique(np.asarray(rows) // self.block_rows)
        for b in bids:
            leaf = self.provider.leaf(int(b))
            if isinstance(leaf, np.ndarray):
                # COW fault: replace the shared parent view with a private
                # device copy; update_leaf never touches the old buffer
                self.provider.update_leaf(int(b), jnp.asarray(leaf))
                self.cow_faults += 1
        super()._commit(rows, vals, before_write)


_SHARD_LEAF_RE = re.compile(r"^shard(\d+)/blocks/(\d+)$")


class RoutingView(NamedTuple):
    """One immutable snapshot of the sharded store's routing state.

    ``layout`` (the versioned block partition), ``row_bounds`` (its row
    prefix sums) and ``stores`` (the shard stores, as a tuple) are
    published TOGETHER as a single attribute store — a reader that
    snapshots the view can never route new-layout rows against old-layout
    bounds or old-layout stores (the pre-PR-6 store published
    ``_row_bounds`` and ``layout`` as two separate attributes, leaving
    exactly that window open between the two stores)."""

    layout: ShardLayout
    row_bounds: np.ndarray
    stores: Tuple[KVStore, ...]


def _gather_ordered(store: KVStore, local: np.ndarray) -> np.ndarray:
    """:meth:`KVStore.get` returns rows grouped by block (run-coalesced);
    undo that permutation so the caller gets INPUT order back. The
    grouping is exactly a stable sort by block id, so its inverse is one
    scatter — the run-coalesced D2H hot path is untouched."""
    res = store.get(local)
    perm = np.argsort(np.asarray(local) // store.block_rows, kind="stable")
    out = np.empty_like(res)
    out[perm] = res
    return out


def _is_deleted_buffer_error(exc: BaseException) -> bool:
    """A gather raced a donated commit: the block's old buffer died
    between the reader's leaf fetch and its device dispatch. JAX surfaces
    this as a RuntimeError/ValueError naming the deleted/donated array;
    anything else is a real error and must propagate."""
    msg = str(exc).lower()
    return "delet" in msg or "donat" in msg


class ShardedKVStore:
    """Range-partitioned union of N independent :class:`KVStore` shards
    under a versioned :class:`~repro.core.layout.ShardLayout`.

    The cluster analogue of the paper's single instance: shard k owns the
    global row range ``[layout.bounds[k], layout.bounds[k+1]) *
    block_rows``, each with its own blocked value table and provider, so
    the snapshot coordinator can give every shard its own block table,
    copiers, and persist stream. Routing is one vectorized
    ``np.searchsorted`` over the layout's row boundaries (redis-cluster's
    hash slots collapse to ranges under the integer key space
    redis-benchmark uses), grouping a whole query batch per shard in one
    pass.

    :meth:`split` / :meth:`merge` reshard ONLINE with zero data movement:
    child shards wrap the parent's device blocks under fresh providers and
    the layout advances one epoch. Concurrency contract: with a striped
    :class:`~repro.core.gates.GateSet` as the ``gate``, :meth:`set` is
    safe against a reshard landing mid-batch from another thread — each
    shard group commits under its stripe and REVALIDATES the layout after
    acquiring (a swap needs all stripes, so holding one excludes it); a
    stale group re-routes its uncommitted tail under the successor layout
    instead of writing through the retired parent store. With a plain
    lock (or ungated), the pre-PR-5 contract stands: issue reshards from
    the serving thread itself (``KVEngine.run(actions=...)`` does) or
    quiesce writers first.

    ``before_write`` hooks gain a leading ``shard_id``:
    ``before_write(shard_id, leaf_id, local_rows)``; indices are under the
    CURRENT layout (the coordinator translates for retired layouts).
    """

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
        shards: int = 2,
    ):
        n_shards = max(1, int(shards))
        per = -(-int(capacity) // n_shards)
        self.shards: List[KVStore] = [
            KVStore(per, row_width=row_width, block_rows=block_rows, seed=seed + k)
            for k in range(n_shards)
        ]
        self.row_width = int(row_width)
        self.block_rows = int(block_rows)
        # seqlock over the routing view: EVEN = stable, ODD = a reshard is
        # mid-swap. Readers snapshot (_seq, _view), gather, and re-check
        # _seq — a changed counter means a reshard landed mid-read.
        self._seq = 0
        self._apply_layout(ShardLayout.uniform([s.n_blocks for s in self.shards]))

    @classmethod
    def from_shards(
        cls,
        shards: Sequence[KVStore],
        row_width: int,
        block_rows: int,
        layout: Optional[ShardLayout] = None,
    ) -> "ShardedKVStore":
        """Wrap EXISTING shard stores (zero data movement) — the branch
        primitive: ``KVEngine.branch`` builds per-shard
        :class:`CowKVStore` wrappers over a pinned epoch's images and
        assembles them here under that epoch's frozen layout."""
        self = cls.__new__(cls)
        self.shards = list(shards)
        self.row_width = int(row_width)
        self.block_rows = int(block_rows)
        self._seq = 0
        self._apply_layout(
            layout if layout is not None
            else ShardLayout.uniform([s.n_blocks for s in self.shards])
        )
        return self

    def _apply_layout(self, layout: ShardLayout) -> None:
        """Install a layout by publishing ONE immutable
        :class:`RoutingView` with a single attribute store. Striped
        writers route outside the gate and validate the view's object
        identity after acquiring their stripe; seqlock readers snapshot
        it ungated — one atomic publish makes both checks sufficient (a
        thread that saw the new view sees the new layout, bounds and
        shard stores together, never a mix)."""
        self._view = RoutingView(
            layout, layout.row_bounds(self.block_rows), tuple(self.shards)
        )

    # -- routing-view accessors (all derive from the ONE published view) --
    @property
    def layout(self) -> ShardLayout:
        return self._view.layout

    @property
    def _row_bounds(self) -> np.ndarray:
        return self._view.row_bounds

    @property
    def capacity(self) -> int:
        return int(self._view.row_bounds[-1])

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def block_nbytes(self) -> int:
        return self.shards[0].block_nbytes

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    @property
    def providers(self):
        return [s.provider for s in self.shards]

    # -- routing (vectorized over the layout boundaries) -----------------
    def _route(self, rows: np.ndarray, view: Optional[RoutingView] = None):
        """Yield ``(shard_id, local_rows, positions)`` per touched shard —
        one ``searchsorted`` + one stable argsort for the whole batch
        instead of a Python-level scan per row. ``view`` pins the routing
        view; concurrent callers pass the snapshot they validated so every
        group routes against ONE consistent (layout, bounds, stores)."""
        rows = np.asarray(rows)
        if rows.size == 0:
            return
        row_bounds = (view or self._view).row_bounds
        sids = np.searchsorted(row_bounds, rows, side="right") - 1
        order = np.argsort(sids, kind="stable")
        sorted_sids = sids[order]
        uniq, starts = np.unique(sorted_sids, return_index=True)
        bounds = np.append(starts[1:], rows.shape[0])
        for u, s, e in zip(uniq, starts, bounds):
            pos = order[s:e]
            yield int(u), rows[pos] - int(row_bounds[u]), pos

    def set(self, rows, vals, before_write=None, gate=None,
            on_gate_wait=None) -> None:
        """Routed scatter write, one gate acquisition per (shard, batch).

        With a :class:`GateSet` the acquisition is the touched shard's
        STRIPE: writes to different shards commit concurrently, and
        ``on_gate_wait(shard_id, wait_s)`` reports each acquisition's
        contended wait (the engine feeds it into the epoch metrics). At
        most one stripe is held at a time — shard groups commit in
        ascending shard order and release between groups — so writers can
        never deadlock against the ordered all-gate barrier."""
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        if not isinstance(gate, GateSet):
            # legacy path: one shared lock (or none) for every shard
            for k, local, pos in self._route(rows):
                hook = None
                if before_write is not None:
                    hook = (lambda leaf_id, lrows, _k=k:
                            before_write(_k, leaf_id, lrows))
                self.shards[k].set(local, vals[pos], before_write=hook, gate=gate)
            return
        while rows.size:
            view = self._view
            groups = list(self._route(rows, view))
            rerouted = False
            for i, (k, local, pos) in enumerate(groups):
                try:
                    g, wait = gate.acquire(k)
                except GateRetired:
                    g = None  # layout shrank under us: re-route the tail
                if g is None or self._view is not view:
                    # a reshard swapped the view between routing and this
                    # stripe: the uncommitted tail (this group onward) must
                    # re-route, or it would write through a retired store
                    if g is not None:
                        g.release()
                    rest = np.concatenate([p for _, _, p in groups[i:]])
                    rows, vals = rows[rest], vals[rest]
                    rerouted = True
                    break
                try:
                    if on_gate_wait is not None:
                        on_gate_wait(k, wait)
                    hook = None
                    if before_write is not None:
                        hook = (lambda leaf_id, lrows, _k=k:
                                before_write(_k, leaf_id, lrows))
                    view.stores[k]._commit(local, vals[pos], hook)
                finally:
                    g.release()
            if not rerouted:
                return

    def get(self, rows) -> np.ndarray:
        """Serial gather — the paper's single-threaded parent. Safe only
        on the thread that also issues the writes (or with writers
        quiesced): a concurrent donated commit can kill a block buffer
        mid-gather. Concurrent readers use :meth:`get_concurrent`.

        NOTE: rows crossing shard boundaries come back grouped by shard
        (historical behavior, callers sort); ``get_concurrent`` returns
        input order."""
        outs = [self.shards[k].get(local) for k, local, _ in self._route(rows)]
        return (np.concatenate(outs) if outs
                else np.empty((0, self.row_width), np.float32))

    def get_concurrent(
        self,
        rows,
        gate: Optional[GateSet] = None,
        max_retries: int = 8,
        donation_retries: int = 64,
        on_read_event: Optional[Callable[[int, int, float], None]] = None,
    ) -> np.ndarray:
        """Concurrent-safe gather, lock-free on the uncontended path.

        Seqlock fast path: snapshot ``(_seq, _view)``, gather through the
        view's stores, re-validate ``_seq`` — when no reshard landed
        mid-read (the overwhelmingly common case) the read takes NO lock
        and never blocks a writer anywhere. The two failure modes retry
        on different budgets: layout CHURN (odd counter / failed seq
        validation — a reshard mid-swap) spends ``max_retries`` spinning
        attempts, then falls back to SHARED stripe acquisition
        (``gate.acquire_shared``), which serializes against the swap.
        A DONATION race (the touched block's old buffer died under a
        mid-commit write) instead backs off ~1ms and re-reads, up to
        ``donation_retries`` — the writer publishes the replacement
        buffer within one commit, so grabbing stripes here would only
        convoy every reader behind every writer; the generous budget
        still bounds the spin, and exhausting it takes the shared
        fallback too (excluding the shard's writer excludes the race),
        so progress is guaranteed either way (no livelock).

        Returns rows in INPUT order (unlike :meth:`get`).
        ``on_read_event(shard_id, retries, shared_wait_s)`` fires once per
        call that retried or fell back, so the engine can charge read-side
        churn to the in-flight epoch next to ``gate_wait_us``."""
        rows = np.asarray(rows)
        out = np.empty((rows.shape[0], self.row_width), np.float32)
        if rows.size == 0:
            return out
        retries = 0
        shared_wait = 0.0
        first_shard = 0
        try:
            churn = races = 0
            while churn < max_retries and races < donation_retries:
                seq0 = self._seq
                view = self._view
                if seq0 & 1:  # reshard mid-swap: the view may be stale
                    churn += 1
                    retries += 1
                    continue
                try:
                    for k, local, pos in self._route(rows, view):
                        first_shard = k
                        out[pos] = _gather_ordered(view.stores[k], local)
                except (RuntimeError, ValueError) as exc:
                    if not _is_deleted_buffer_error(exc):
                        raise
                    races += 1
                    retries += 1
                    time.sleep(1e-3)  # one commit republishes the buffer
                    continue
                if self._seq == seq0:
                    return out
                churn += 1
                retries += 1  # a reshard landed mid-gather: re-read
            # -- bounded fallback: shared stripes exclude the writers ----
            remaining = rows
            positions = np.arange(rows.shape[0])
            while remaining.size:
                view = self._view
                groups = list(self._route(remaining, view))
                rerouted = False
                for i, (k, local, pos) in enumerate(groups):
                    first_shard = k
                    if gate is None:
                        # store-only use (no coordinator): best effort —
                        # re-gather through the freshest view until the
                        # buffers stop dying under us
                        try:
                            out[positions[pos]] = _gather_ordered(view.stores[k], local)
                            continue
                        except (RuntimeError, ValueError) as exc:
                            if not _is_deleted_buffer_error(exc):
                                raise
                            retries += 1
                            rerouted = True
                    else:
                        try:
                            g, wait = gate.acquire_shared(k)
                        except GateRetired:
                            g = None  # layout shrank: re-route the tail
                        if g is not None and self._view is not view:
                            g.release_shared()
                            g = None
                        if g is None:
                            retries += 1
                            rerouted = True
                        else:
                            try:
                                shared_wait += wait
                                out[positions[pos]] = _gather_ordered(view.stores[k], local)
                                continue
                            finally:
                                g.release_shared()
                    # stale view/stripe: re-route this group onward
                    rest = np.concatenate([p for _, _, p in groups[i:]])
                    remaining = remaining[rest]
                    positions = positions[rest]
                    break
                if not rerouted:
                    break
            return out
        finally:
            if on_read_event is not None and (retries or shared_wait):
                on_read_event(first_shard, retries, shared_wait)

    def get_at(self, rows, epoch) -> np.ndarray:
        """Point-in-time gather against a pinned epoch
        (:class:`~repro.core.catalog.EpochRef`), in INPUT order.

        Routing uses the EPOCH's frozen layout, not the live view — the
        store may have resharded since the barrier, but the epoch's shard
        images are indexed under the layout its barrier stamped. The
        gather touches only the epoch's immutable images (retained
        staging buffers or memmapped manifests), so it needs no gate, no
        seqlock and no retries: live writers donate PROVIDER buffers,
        never a frozen image."""
        rows = np.asarray(rows)
        out = np.empty((rows.shape[0], self.row_width), np.float32)
        if rows.size == 0:
            return out
        layout = getattr(epoch, "layout", None)
        if layout is None:
            layout = self.layout
        view = RoutingView(layout, layout.row_bounds(self.block_rows), ())
        for k, local, pos in self._route(rows, view):
            out[pos] = epoch.shard_rows(k, local)
        return out

    def read_all(self) -> np.ndarray:
        return np.concatenate([s.read_all() for s in self.shards])

    def warmup(self, batch: int = 4) -> None:
        for s in self.shards:
            s.warmup(batch)

    # -- online resharding ------------------------------------------------
    def split(self, shard_id: int, at_block: Optional[int] = None) -> ShardLayout:
        """Split shard ``shard_id`` at a block boundary (default midpoint).

        Zero-copy: both children wrap the parent's device blocks. Returns
        the successor layout (``epoch + 1``). Callers running snapshots
        must swap the coordinator too (``coordinator.set_layout``) under
        the write gate — ``KVEngine.split`` packages both."""
        src = self.shards[shard_id]
        new_layout = self.layout.split(shard_id, at_block)  # validates
        at = new_layout.bounds[shard_id + 1] - new_layout.bounds[shard_id]
        blocks = src.blocks_list()
        left = KVStore.from_blocks(blocks[:at], self.row_width, self.block_rows)
        right = KVStore.from_blocks(blocks[at:], self.row_width, self.block_rows)
        self._seq += 1  # odd: readers that snapshot now will retry
        try:
            self.shards[shard_id: shard_id + 1] = [left, right]
            self._apply_layout(new_layout)
        finally:
            self._seq += 1  # even: new view published, reads validate
        return self.layout

    def merge(self, shard_id: int, other: int) -> ShardLayout:
        """Merge ADJACENT shards ``shard_id`` and ``other == shard_id+1``
        into one (zero-copy). Returns the successor layout."""
        new_layout = self.layout.merge(shard_id, other)  # validates
        blocks = self.shards[shard_id].blocks_list() + \
            self.shards[other].blocks_list()
        merged = KVStore.from_blocks(blocks, self.row_width, self.block_rows)
        self._seq += 1  # odd: readers that snapshot now will retry
        try:
            self.shards[shard_id: other + 1] = [merged]
            self._apply_layout(new_layout)
        finally:
            self._seq += 1  # even: new view published, reads validate
        return self.layout

    # -- cross-layout restore ---------------------------------------------
    def load(self, directory: str) -> None:
        """Restore a composite snapshot written under ANY historical
        layout into the CURRENT one (re-split/re-merge on restore).

        The snapshot's shard ranges are contiguous and ordered, so its
        ``shard{k}/blocks/{b}`` leaves concatenate to the global block
        sequence; the manifest's layout record (when present) validates
        the geometry. Blocks are rebound into the current shards' live
        providers (plain rebinds, no donation) — do not call while a
        snapshot epoch is in flight over this store, and note the rebinds
        do NOT route through ``before_write``: a coordinator's write
        counters and retained dirty-diff bases become stale, so policy
        users must go through ``KVEngine.load`` (gate + base
        invalidation) instead of calling this directly.
        """
        flat = read_file_snapshot(directory)
        keyed = {}
        for path, arr in flat.items():
            m = _SHARD_LEAF_RE.match(path)
            if m:
                keyed[(int(m.group(1)), int(m.group(2)))] = arr
        if not keyed:
            raise ValueError(
                f"snapshot {directory!r} holds no shard{{k}}/blocks/{{b}} "
                "leaves; not a sharded KV snapshot"
            )
        record = read_snapshot_layout(directory)
        if record is not None and record.get("kind") == "range":
            saved = ShardLayout.from_record(record)
            if saved.n_blocks != self.layout.n_blocks:
                raise ValueError(
                    f"snapshot covers {saved.n_blocks} blocks, store has "
                    f"{self.layout.n_blocks}"
                )
        # global block order = (shard, local block) lexicographic
        global_blocks = [keyed[key] for key in sorted(keyed)]
        if len(global_blocks) != self.layout.n_blocks:
            raise ValueError(
                f"snapshot holds {len(global_blocks)} blocks, store needs "
                f"{self.layout.n_blocks}"
            )
        g = 0
        for store in self.shards:
            for b in range(store.n_blocks):
                arr = global_blocks[g]
                if arr.shape != (self.block_rows, self.row_width):
                    raise ValueError(
                        f"block {g} has shape {arr.shape}, expected "
                        f"{(self.block_rows, self.row_width)}"
                    )
                store.provider.update_leaf(b, jnp.asarray(arr))
                g += 1
