"""A Redis-like in-memory KV store built on JAX.

The store is the paper's "parent process": a value table of ``capacity``
rows × ``row_width`` float32 (1 KiB values at width 256 — the paper's
benchmark value size), **physically blocked** into per-block device arrays
of ``block_rows`` rows. A SET donates only the touched block's buffer —
the analogue of a PMD-granular write — so the snapshot core can protect
exactly the about-to-die block (proactive synchronization) while the
copier reads every other block race-free. Keys address rows directly, as
redis-benchmark's integer key space does.
"""
from __future__ import annotations

import contextlib
import re
from functools import partial
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layout import ShardLayout
from repro.core.provider import PyTreeProvider
from repro.core.sinks import read_file_snapshot, read_snapshot_layout

_NO_GATE = contextlib.nullcontext()


@partial(jax.jit, donate_argnums=(0,))
def _scatter_set(block, rows, vals):
    return block.at[rows].set(vals)


@jax.jit
def _gather_get(block, rows):
    return block[rows]


class KVStore:
    """Blocked value table + provider integration for the snapshot core."""

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
    ):
        self.block_rows = int(block_rows)
        # round capacity up to a whole number of blocks (uniform jit shapes)
        self.n_blocks = max(1, -(-int(capacity) // self.block_rows))
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        key = jax.random.PRNGKey(seed)
        blocks = []
        for b in range(self.n_blocks):
            key, sub = jax.random.split(key)
            blocks.append(
                jax.random.uniform(sub, (self.block_rows, self.row_width), jnp.float32)
            )
        # list pytree: leaf b <-> block b (one "PMD + PTE table" per leaf)
        self.provider = PyTreeProvider({"blocks": blocks})

    @classmethod
    def from_blocks(
        cls, blocks: Sequence, row_width: int, block_rows: int
    ) -> "KVStore":
        """Wrap EXISTING device blocks in a new store (zero data movement).

        The reshard primitive: a split hands each child the same
        ``jax.Array`` objects the parent shard held, under a fresh
        provider — in-flight snapshot epochs keep reading the buffers
        through the old provider while new writes route (and donate)
        through this one, protected by the same proactive-sync contract.
        """
        self = cls.__new__(cls)
        self.block_rows = int(block_rows)
        self.n_blocks = len(blocks)
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        self.provider = PyTreeProvider({"blocks": list(blocks)})
        return self

    def blocks_list(self) -> List:
        """The live device blocks, in block order."""
        return [self.provider.leaf(b) for b in range(self.n_blocks)]

    @property
    def block_nbytes(self) -> int:
        return self.block_rows * self.row_width * 4

    @property
    def nbytes(self) -> int:
        return self.capacity * self.row_width * 4

    def _split(self, rows: np.ndarray):
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            yield int(b), rows[bids == b] - b * self.block_rows

    def set(
        self,
        rows: np.ndarray,
        vals: np.ndarray,
        before_write: Optional[Callable[[int, np.ndarray], None]] = None,
        gate=None,
    ) -> None:
        """Donated scatter write; ``before_write(leaf_id, local_rows)`` is
        the proactive synchronization hook invoked before each touched
        block dies. The hook receives the leaf-local row indices so a
        multi-block leaf syncs only the blocks the write will actually kill
        (row→block-precise, DESIGN.md §2) instead of the whole leaf.

        ``gate`` (a lock/context manager) is held across sync → donated
        commit per block, so a concurrent snapshot fork barrier can never
        land between a write's proactive sync and its buffer swap."""
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            mask = bids == b
            local = rows[mask] - b * self.block_rows
            with gate if gate is not None else _NO_GATE:
                if before_write is not None:
                    # sync THIS block's touched rows in all active snapshots
                    before_write(int(b), local)
                old = self.provider.leaf(int(b))
                new = _scatter_set(old, jnp.asarray(local), jnp.asarray(vals[mask]))
                new.block_until_ready()
                self.provider.update_leaf(int(b), new)  # old was donated by XLA

    def get(self, rows: np.ndarray) -> np.ndarray:
        outs = []
        for b, local in self._split(rows):
            out = _gather_get(self.provider.leaf(b), jnp.asarray(local))
            outs.append(np.asarray(out))
        return np.concatenate(outs) if outs else np.empty((0, self.row_width), np.float32)

    def read_all(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.provider.leaf(b)) for b in range(self.n_blocks)]
        )

    def warmup(self, batch: int = 4) -> None:
        """Trigger jit compiles outside the measured window."""
        rows = np.arange(batch, dtype=np.int64)
        vals = np.zeros((batch, self.row_width), np.float32)
        self.set(rows, vals)
        self.get(rows)


_SHARD_LEAF_RE = re.compile(r"^shard(\d+)/blocks/(\d+)$")


class ShardedKVStore:
    """Range-partitioned union of N independent :class:`KVStore` shards
    under a versioned :class:`~repro.core.layout.ShardLayout`.

    The cluster analogue of the paper's single instance: shard k owns the
    global row range ``[layout.bounds[k], layout.bounds[k+1]) *
    block_rows``, each with its own blocked value table and provider, so
    the snapshot coordinator can give every shard its own block table,
    copiers, and persist stream. Routing is one vectorized
    ``np.searchsorted`` over the layout's row boundaries (redis-cluster's
    hash slots collapse to ranges under the integer key space
    redis-benchmark uses), grouping a whole query batch per shard in one
    pass.

    :meth:`split` / :meth:`merge` reshard ONLINE with zero data movement:
    child shards wrap the parent's device blocks under fresh providers and
    the layout advances one epoch. Concurrency contract: the write gate
    serializes a reshard against snapshot BARRIERS only — ``set``/``get``
    route and resolve shard objects outside the gate (they take it per
    block), so a reshard must additionally be serialized against writers:
    issue it from the serving thread itself (the paper's single-threaded
    parent model; ``KVEngine.run(actions=...)`` does exactly this) or
    quiesce writers first. A reshard landing mid-batch on another thread
    would let the batch's tail write through the retired parent store.

    ``before_write`` hooks gain a leading ``shard_id``:
    ``before_write(shard_id, leaf_id, local_rows)``; indices are under the
    CURRENT layout (the coordinator translates for retired layouts).
    """

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
        shards: int = 2,
    ):
        n_shards = max(1, int(shards))
        per = -(-int(capacity) // n_shards)
        self.shards: List[KVStore] = [
            KVStore(per, row_width=row_width, block_rows=block_rows, seed=seed + k)
            for k in range(n_shards)
        ]
        self.row_width = int(row_width)
        self.block_rows = int(block_rows)
        self.layout = ShardLayout.uniform([s.n_blocks for s in self.shards])
        self._refresh_bounds()

    def _refresh_bounds(self) -> None:
        self._row_bounds = self.layout.row_bounds(self.block_rows)
        self.capacity = int(self._row_bounds[-1])

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def block_nbytes(self) -> int:
        return self.shards[0].block_nbytes

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    @property
    def providers(self):
        return [s.provider for s in self.shards]

    # -- routing (vectorized over the layout boundaries) -----------------
    def _route(self, rows: np.ndarray):
        """Yield ``(shard_id, local_rows, positions)`` per touched shard —
        one ``searchsorted`` + one stable argsort for the whole batch
        instead of a Python-level scan per row."""
        rows = np.asarray(rows)
        if rows.size == 0:
            return
        sids = np.searchsorted(self._row_bounds, rows, side="right") - 1
        order = np.argsort(sids, kind="stable")
        sorted_sids = sids[order]
        uniq, starts = np.unique(sorted_sids, return_index=True)
        bounds = np.append(starts[1:], rows.shape[0])
        for u, s, e in zip(uniq, starts, bounds):
            pos = order[s:e]
            yield int(u), rows[pos] - int(self._row_bounds[u]), pos

    def set(self, rows, vals, before_write=None, gate=None) -> None:
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        for k, local, pos in self._route(rows):
            hook = None
            if before_write is not None:
                hook = (lambda leaf_id, lrows, _k=k:
                        before_write(_k, leaf_id, lrows))
            self.shards[k].set(local, vals[pos], before_write=hook, gate=gate)

    def get(self, rows) -> np.ndarray:
        outs = [self.shards[k].get(local) for k, local, _ in self._route(rows)]
        return (np.concatenate(outs) if outs
                else np.empty((0, self.row_width), np.float32))

    def read_all(self) -> np.ndarray:
        return np.concatenate([s.read_all() for s in self.shards])

    def warmup(self, batch: int = 4) -> None:
        for s in self.shards:
            s.warmup(batch)

    # -- online resharding ------------------------------------------------
    def split(self, shard_id: int, at_block: Optional[int] = None) -> ShardLayout:
        """Split shard ``shard_id`` at a block boundary (default midpoint).

        Zero-copy: both children wrap the parent's device blocks. Returns
        the successor layout (``epoch + 1``). Callers running snapshots
        must swap the coordinator too (``coordinator.set_layout``) under
        the write gate — ``KVEngine.split`` packages both."""
        src = self.shards[shard_id]
        new_layout = self.layout.split(shard_id, at_block)  # validates
        at = new_layout.bounds[shard_id + 1] - new_layout.bounds[shard_id]
        blocks = src.blocks_list()
        left = KVStore.from_blocks(blocks[:at], self.row_width, self.block_rows)
        right = KVStore.from_blocks(blocks[at:], self.row_width, self.block_rows)
        self.shards[shard_id: shard_id + 1] = [left, right]
        self.layout = new_layout
        self._refresh_bounds()
        return self.layout

    def merge(self, shard_id: int, other: int) -> ShardLayout:
        """Merge ADJACENT shards ``shard_id`` and ``other == shard_id+1``
        into one (zero-copy). Returns the successor layout."""
        new_layout = self.layout.merge(shard_id, other)  # validates
        blocks = self.shards[shard_id].blocks_list() + \
            self.shards[other].blocks_list()
        merged = KVStore.from_blocks(blocks, self.row_width, self.block_rows)
        self.shards[shard_id: other + 1] = [merged]
        self.layout = new_layout
        self._refresh_bounds()
        return self.layout

    # -- cross-layout restore ---------------------------------------------
    def load(self, directory: str) -> None:
        """Restore a composite snapshot written under ANY historical
        layout into the CURRENT one (re-split/re-merge on restore).

        The snapshot's shard ranges are contiguous and ordered, so its
        ``shard{k}/blocks/{b}`` leaves concatenate to the global block
        sequence; the manifest's layout record (when present) validates
        the geometry. Blocks are rebound into the current shards' live
        providers (plain rebinds, no donation) — do not call while a
        snapshot epoch is in flight over this store, and note the rebinds
        do NOT route through ``before_write``: a coordinator's write
        counters and retained dirty-diff bases become stale, so policy
        users must go through ``KVEngine.load`` (gate + base
        invalidation) instead of calling this directly.
        """
        flat = read_file_snapshot(directory)
        keyed = {}
        for path, arr in flat.items():
            m = _SHARD_LEAF_RE.match(path)
            if m:
                keyed[(int(m.group(1)), int(m.group(2)))] = arr
        if not keyed:
            raise ValueError(
                f"snapshot {directory!r} holds no shard{{k}}/blocks/{{b}} "
                "leaves; not a sharded KV snapshot"
            )
        record = read_snapshot_layout(directory)
        if record is not None and record.get("kind") == "range":
            saved = ShardLayout.from_record(record)
            if saved.n_blocks != self.layout.n_blocks:
                raise ValueError(
                    f"snapshot covers {saved.n_blocks} blocks, store has "
                    f"{self.layout.n_blocks}"
                )
        # global block order = (shard, local block) lexicographic
        global_blocks = [keyed[key] for key in sorted(keyed)]
        if len(global_blocks) != self.layout.n_blocks:
            raise ValueError(
                f"snapshot holds {len(global_blocks)} blocks, store needs "
                f"{self.layout.n_blocks}"
            )
        g = 0
        for store in self.shards:
            for b in range(store.n_blocks):
                arr = global_blocks[g]
                if arr.shape != (self.block_rows, self.row_width):
                    raise ValueError(
                        f"block {g} has shape {arr.shape}, expected "
                        f"{(self.block_rows, self.row_width)}"
                    )
                store.provider.update_leaf(b, jnp.asarray(arr))
                g += 1
