"""A Redis-like in-memory KV store built on JAX.

The store is the paper's "parent process": a value table of ``capacity``
rows × ``row_width`` float32 (1 KiB values at width 256 — the paper's
benchmark value size), **physically blocked** into per-block device arrays
of ``block_rows`` rows. A SET donates only the touched block's buffer —
the analogue of a PMD-granular write — so the snapshot core can protect
exactly the about-to-die block (proactive synchronization) while the
copier reads every other block race-free. Keys address rows directly, as
redis-benchmark's integer key space does.
"""
from __future__ import annotations

import contextlib
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.provider import PyTreeProvider

_NO_GATE = contextlib.nullcontext()


@partial(jax.jit, donate_argnums=(0,))
def _scatter_set(block, rows, vals):
    return block.at[rows].set(vals)


@jax.jit
def _gather_get(block, rows):
    return block[rows]


class KVStore:
    """Blocked value table + provider integration for the snapshot core."""

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
    ):
        self.block_rows = int(block_rows)
        # round capacity up to a whole number of blocks (uniform jit shapes)
        self.n_blocks = max(1, -(-int(capacity) // self.block_rows))
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        key = jax.random.PRNGKey(seed)
        blocks = []
        for b in range(self.n_blocks):
            key, sub = jax.random.split(key)
            blocks.append(
                jax.random.uniform(sub, (self.block_rows, self.row_width), jnp.float32)
            )
        # list pytree: leaf b <-> block b (one "PMD + PTE table" per leaf)
        self.provider = PyTreeProvider({"blocks": blocks})

    @property
    def block_nbytes(self) -> int:
        return self.block_rows * self.row_width * 4

    @property
    def nbytes(self) -> int:
        return self.capacity * self.row_width * 4

    def _split(self, rows: np.ndarray):
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            yield int(b), rows[bids == b] - b * self.block_rows

    def set(
        self,
        rows: np.ndarray,
        vals: np.ndarray,
        before_write: Optional[Callable[[int, np.ndarray], None]] = None,
        gate=None,
    ) -> None:
        """Donated scatter write; ``before_write(leaf_id, local_rows)`` is
        the proactive synchronization hook invoked before each touched
        block dies. The hook receives the leaf-local row indices so a
        multi-block leaf syncs only the blocks the write will actually kill
        (row→block-precise, DESIGN.md §2) instead of the whole leaf.

        ``gate`` (a lock/context manager) is held across sync → donated
        commit per block, so a concurrent snapshot fork barrier can never
        land between a write's proactive sync and its buffer swap."""
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            mask = bids == b
            local = rows[mask] - b * self.block_rows
            with gate if gate is not None else _NO_GATE:
                if before_write is not None:
                    # sync THIS block's touched rows in all active snapshots
                    before_write(int(b), local)
                old = self.provider.leaf(int(b))
                new = _scatter_set(old, jnp.asarray(local), jnp.asarray(vals[mask]))
                new.block_until_ready()
                self.provider.update_leaf(int(b), new)  # old was donated by XLA

    def get(self, rows: np.ndarray) -> np.ndarray:
        outs = []
        for b, local in self._split(rows):
            out = _gather_get(self.provider.leaf(b), jnp.asarray(local))
            outs.append(np.asarray(out))
        return np.concatenate(outs) if outs else np.empty((0, self.row_width), np.float32)

    def read_all(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.provider.leaf(b)) for b in range(self.n_blocks)]
        )

    def warmup(self, batch: int = 4) -> None:
        """Trigger jit compiles outside the measured window."""
        rows = np.arange(batch, dtype=np.int64)
        vals = np.zeros((batch, self.row_width), np.float32)
        self.set(rows, vals)
        self.get(rows)


class ShardedKVStore:
    """Range-partitioned union of N independent :class:`KVStore` shards.

    The cluster analogue of the paper's single instance: shard k owns rows
    ``[k*shard_capacity, (k+1)*shard_capacity)``, each with its own blocked
    value table and provider, so the snapshot coordinator can give every
    shard its own block table, copiers, and persist stream. Routing is a
    contiguous range split (redis-cluster's hash slots collapse to ranges
    under the integer key space redis-benchmark uses).

    ``before_write`` hooks gain a leading ``shard_id``:
    ``before_write(shard_id, leaf_id, local_rows)``.
    """

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
        shards: int = 2,
    ):
        self.n_shards = max(1, int(shards))
        per = -(-int(capacity) // self.n_shards)
        self.shards: List[KVStore] = [
            KVStore(per, row_width=row_width, block_rows=block_rows, seed=seed + k)
            for k in range(self.n_shards)
        ]
        self.shard_capacity = self.shards[0].capacity
        self.capacity = self.shard_capacity * self.n_shards
        self.row_width = int(row_width)
        self.block_rows = int(block_rows)

    @property
    def block_nbytes(self) -> int:
        return self.shards[0].block_nbytes

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self.shards)

    @property
    def providers(self):
        return [s.provider for s in self.shards]

    def _route(self, rows: np.ndarray):
        rows = np.asarray(rows)
        sids = rows // self.shard_capacity
        for k in np.unique(sids):
            yield int(k), rows[sids == k] - k * self.shard_capacity

    def set(self, rows, vals, before_write=None, gate=None) -> None:
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        sids = rows // self.shard_capacity
        for k in np.unique(sids):
            mask = sids == k
            hook = None
            if before_write is not None:
                hook = (lambda leaf_id, lrows, _k=int(k):
                        before_write(_k, leaf_id, lrows))
            self.shards[int(k)].set(
                rows[mask] - int(k) * self.shard_capacity, vals[mask],
                before_write=hook, gate=gate,
            )

    def get(self, rows) -> np.ndarray:
        outs = [self.shards[k].get(local) for k, local in self._route(rows)]
        return (np.concatenate(outs) if outs
                else np.empty((0, self.row_width), np.float32))

    def read_all(self) -> np.ndarray:
        return np.concatenate([s.read_all() for s in self.shards])

    def warmup(self, batch: int = 4) -> None:
        for s in self.shards:
            s.warmup(batch)
