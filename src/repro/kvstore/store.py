"""A Redis-like in-memory KV store built on JAX.

The store is the paper's "parent process": a value table of ``capacity``
rows × ``row_width`` float32 (1 KiB values at width 256 — the paper's
benchmark value size), **physically blocked** into per-block device arrays
of ``block_rows`` rows. A SET donates only the touched block's buffer —
the analogue of a PMD-granular write — so the snapshot core can protect
exactly the about-to-die block (proactive synchronization) while the
copier reads every other block race-free. Keys address rows directly, as
redis-benchmark's integer key space does.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.provider import PyTreeProvider


@partial(jax.jit, donate_argnums=(0,))
def _scatter_set(block, rows, vals):
    return block.at[rows].set(vals)


@jax.jit
def _gather_get(block, rows):
    return block[rows]


class KVStore:
    """Blocked value table + provider integration for the snapshot core."""

    def __init__(
        self,
        capacity: int,
        row_width: int = 256,
        block_rows: int = 1024,
        seed: int = 0,
    ):
        self.block_rows = int(block_rows)
        # round capacity up to a whole number of blocks (uniform jit shapes)
        self.n_blocks = max(1, -(-int(capacity) // self.block_rows))
        self.capacity = self.n_blocks * self.block_rows
        self.row_width = int(row_width)
        key = jax.random.PRNGKey(seed)
        blocks = []
        for b in range(self.n_blocks):
            key, sub = jax.random.split(key)
            blocks.append(
                jax.random.uniform(sub, (self.block_rows, self.row_width), jnp.float32)
            )
        # list pytree: leaf b <-> block b (one "PMD + PTE table" per leaf)
        self.provider = PyTreeProvider({"blocks": blocks})

    @property
    def block_nbytes(self) -> int:
        return self.block_rows * self.row_width * 4

    @property
    def nbytes(self) -> int:
        return self.capacity * self.row_width * 4

    def _split(self, rows: np.ndarray):
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            yield int(b), rows[bids == b] - b * self.block_rows

    def set(
        self,
        rows: np.ndarray,
        vals: np.ndarray,
        before_write: Optional[Callable[[int], None]] = None,
    ) -> None:
        """Donated scatter write; ``before_write(leaf_id)`` is the proactive
        synchronization hook invoked before each touched block dies."""
        vals = np.asarray(vals)
        rows = np.asarray(rows)
        bids = rows // self.block_rows
        for b in np.unique(bids):
            mask = bids == b
            if before_write is not None:
                before_write(int(b))  # sync THIS block in all active snapshots
            old = self.provider.leaf(int(b))
            new = _scatter_set(old, jnp.asarray(rows[mask] - b * self.block_rows),
                               jnp.asarray(vals[mask]))
            new.block_until_ready()
            self.provider.update_leaf(int(b), new)  # old was donated by XLA

    def get(self, rows: np.ndarray) -> np.ndarray:
        outs = []
        for b, local in self._split(rows):
            out = _gather_get(self.provider.leaf(b), jnp.asarray(local))
            outs.append(np.asarray(out))
        return np.concatenate(outs) if outs else np.empty((0, self.row_width), np.float32)

    def read_all(self) -> np.ndarray:
        return np.concatenate(
            [np.asarray(self.provider.leaf(b)) for b in range(self.n_blocks)]
        )

    def warmup(self, batch: int = 4) -> None:
        """Trigger jit compiles outside the measured window."""
        rows = np.arange(batch, dtype=np.int64)
        vals = np.zeros((batch, self.row_width), np.float32)
        self.set(rows, vals)
        self.get(rows)
