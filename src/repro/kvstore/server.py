"""Async request server — many client sessions over one engine.

The shape of leveldb-rs's ``AsyncDB`` (SNIPPETS.md §3): clients submit
``Get`` / ``Set`` / ``Flush`` requests into one bounded request queue,
each carrying its own single-slot reply channel; a bounded pool of worker
threads drains the queue against the engine. Replies carry a completion
timestamp, so open-loop clients can submit without waiting and charge
queueing delay to latency afterwards (the paper's §3 measurement model).

Concurrency contract: with ``concurrent_reads=True`` (the default) the
workers serve ``Get`` through :meth:`ShardedKVStore.get_concurrent` —
seqlock fast path, shared-stripe fallback — and ``Set`` under the
engine's striped write gates, so any mix of requests is safe on any
worker. With ``concurrent_reads=False`` the pool degenerates to ONE
worker (enforced) and every request funnels through that single thread:
the paper's single-threaded parent, kept as the benchmark's serial arm.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.kvstore.engine import KVEngine


@dataclasses.dataclass
class GetRequest:
    rows: np.ndarray


@dataclasses.dataclass
class GetAtRequest:
    """Point-in-time read against a cataloged epoch (``engine.get_at``).

    ``epoch`` is either a bare epoch id (pinned transiently per request)
    or a pinned :class:`~repro.core.catalog.EpochRef` the client holds
    across many requests. Snapshot reads flow through the SAME queue and
    worker pool as live traffic — analytical readers and live queries
    contend only for workers, never for the store's gates or seqlock."""

    rows: np.ndarray
    epoch: Any  # int epoch id or EpochRef


@dataclasses.dataclass
class SetRequest:
    rows: np.ndarray
    vals: np.ndarray


@dataclasses.dataclass
class FlushRequest:
    """BGSAVE through the engine (paper's ``BGSAVE`` command)."""


_CLOSE = object()  # sentinel: one per worker, queued by close()


@dataclasses.dataclass
class Reply:
    value: Any                      # Get: rows; Set: None; Flush: snapshot
    error: Optional[BaseException]
    done_t: float                   # perf_counter at completion


@dataclasses.dataclass
class Message:
    """One in-flight request: the request plus its private reply slot."""

    req: Any
    reply: "queue.Queue[Reply]"

    def wait(self, timeout: Optional[float] = None) -> Reply:
        return self.reply.get(timeout=timeout)


class RequestServer:
    """Bounded request queue + worker pool over one :class:`KVEngine`.

    ``readers`` sizes the worker pool; ``queue_depth`` bounds the request
    queue (submit blocks when full — the open-loop generator's backstop
    against unbounded memory, not a latency hider). ``stats()`` reports
    request counts and the queue-depth high-water/mean sampled at each
    submit, which the benchmark threads into
    ``EngineReport.summary()['server_queue_depth']``.
    """

    def __init__(
        self,
        engine: KVEngine,
        readers: int = 4,
        queue_depth: int = 64,
        concurrent_reads: bool = True,
    ):
        readers = int(readers)
        if readers < 1:
            raise ValueError("need at least one worker")
        if not concurrent_reads and readers != 1:
            raise ValueError(
                "concurrent_reads=False is the single-threaded serial arm; "
                "it requires readers=1 (a multi-worker pool would race "
                "serial get/set)"
            )
        self.engine = engine
        self.concurrent_reads = bool(concurrent_reads)
        self._q: "queue.Queue[Message]" = queue.Queue(maxsize=int(queue_depth))
        self._lock = threading.Lock()
        self._counts = {"get": 0, "get_at": 0, "set": 0, "flush": 0}
        self._depth_max = 0
        self._depth_sum = 0
        self._depth_samples = 0
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker, name=f"kv-server-{i}",
                             daemon=True)
            for i in range(readers)
        ]
        for w in self._workers:
            w.start()

    # -- client side -----------------------------------------------------
    def submit(self, req: Any, timeout: Optional[float] = None) -> Message:
        """Enqueue a request, return its message WITHOUT waiting for the
        reply (open-loop clients collect ``msg.wait()`` later)."""
        if self._closed:
            raise RuntimeError("server is closed")
        msg = Message(req, queue.Queue(maxsize=1))
        self._q.put(msg, timeout=timeout)
        depth = self._q.qsize()
        with self._lock:
            if isinstance(req, GetRequest):
                self._counts["get"] += 1
            elif isinstance(req, GetAtRequest):
                self._counts["get_at"] += 1
            elif isinstance(req, SetRequest):
                self._counts["set"] += 1
            elif isinstance(req, FlushRequest):
                self._counts["flush"] += 1
            self._depth_max = max(self._depth_max, depth)
            self._depth_sum += depth
            self._depth_samples += 1
        return msg

    def _call(self, req: Any, timeout: Optional[float] = None) -> Any:
        reply = self.submit(req).wait(timeout=timeout)
        if reply.error is not None:
            raise reply.error
        return reply.value

    def get(self, rows, timeout: Optional[float] = None) -> np.ndarray:
        return self._call(GetRequest(np.asarray(rows)), timeout)

    def get_at(self, rows, epoch,
               timeout: Optional[float] = None) -> np.ndarray:
        return self._call(GetAtRequest(np.asarray(rows), epoch), timeout)

    def set(self, rows, vals, timeout: Optional[float] = None) -> None:
        self._call(SetRequest(np.asarray(rows), np.asarray(vals)), timeout)

    def flush(self, timeout: Optional[float] = None):
        """Synchronous BGSAVE trigger; returns the snapshot handle (its
        persist may still be draining — callers ``wait_persisted``)."""
        return self._call(FlushRequest(), timeout)

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain the queue and stop the pool (idempotent). Requests
        already submitted are served; new submits raise."""
        if self._closed:
            return
        self._closed = True
        for _ in self._workers:
            close_msg = Message(_CLOSE, queue.Queue(maxsize=1))
            self._q.put(close_msg)
        for w in self._workers:
            w.join(timeout=timeout)

    def __enter__(self) -> "RequestServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker side -----------------------------------------------------
    def _worker(self) -> None:
        while True:
            msg = self._q.get()
            if msg.req is _CLOSE:
                return
            try:
                value = self._dispatch(msg.req)
                err: Optional[BaseException] = None
            except BaseException as exc:  # the CLIENT decides what's fatal
                value, err = None, exc
            msg.reply.put(Reply(value, err, time.perf_counter()))

    def _dispatch(self, req: Any) -> Any:
        eng = self.engine
        store = eng.store
        if isinstance(req, GetRequest):
            if self.concurrent_reads and eng.coordinator is not None:
                return store.get_concurrent(
                    req.rows, gate=eng._gate,
                    on_read_event=eng._read_event_hook,
                )
            return store.get(req.rows)  # serial arm: the single worker
        if isinstance(req, GetAtRequest):
            return eng.get_at(req.rows, req.epoch)
        if isinstance(req, SetRequest):
            if eng.coordinator is not None:
                store.set(req.rows, req.vals,
                          before_write=eng._write_hook, gate=eng._gate,
                          on_gate_wait=eng._gate_wait_hook)
            else:
                store.set(req.rows, req.vals, before_write=eng._write_hook)
            return None
        if isinstance(req, FlushRequest):
            return eng.bgsave()
        raise TypeError(f"unknown request {type(req).__name__}")

    # -- observability ---------------------------------------------------
    def stats(self) -> Dict[str, float]:
        with self._lock:
            samples = self._depth_samples
            return {
                "gets": float(self._counts["get"]),
                "get_ats": float(self._counts["get_at"]),
                "sets": float(self._counts["set"]),
                "flushes": float(self._counts["flush"]),
                "queue_depth_max": float(self._depth_max),
                "queue_depth_mean": (
                    self._depth_sum / samples if samples else 0.0
                ),
                "readers": float(len(self._workers)),
                "concurrent_reads": float(self.concurrent_reads),
            }
