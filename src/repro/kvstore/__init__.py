from repro.kvstore.store import KVStore
from repro.kvstore.workload import Workload, QueryEvent
from repro.kvstore.engine import KVEngine, EngineReport

__all__ = ["KVStore", "Workload", "QueryEvent", "KVEngine", "EngineReport"]
