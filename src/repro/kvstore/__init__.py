from repro.kvstore.store import KVStore, ShardedKVStore
from repro.kvstore.workload import Workload, QueryEvent
from repro.kvstore.engine import KVEngine, EngineReport

__all__ = [
    "KVStore",
    "ShardedKVStore",
    "Workload",
    "QueryEvent",
    "KVEngine",
    "EngineReport",
]
