from repro.kvstore.store import KVStore, RoutingView, ShardedKVStore
from repro.kvstore.workload import Workload, QueryEvent
from repro.kvstore.engine import KVEngine, EngineReport
from repro.kvstore.server import (
    FlushRequest,
    GetRequest,
    Message,
    Reply,
    RequestServer,
    SetRequest,
)

__all__ = [
    "KVStore",
    "RoutingView",
    "ShardedKVStore",
    "Workload",
    "QueryEvent",
    "KVEngine",
    "EngineReport",
    "RequestServer",
    "GetRequest",
    "SetRequest",
    "FlushRequest",
    "Message",
    "Reply",
]
