from repro.kvstore.store import CowKVStore, KVStore, RoutingView, ShardedKVStore
from repro.kvstore.workload import Workload, QueryEvent
from repro.kvstore.engine import KVEngine, EngineReport
from repro.kvstore.server import (
    FlushRequest,
    GetAtRequest,
    GetRequest,
    Message,
    Reply,
    RequestServer,
    SetRequest,
)

__all__ = [
    "CowKVStore",
    "KVStore",
    "RoutingView",
    "ShardedKVStore",
    "Workload",
    "QueryEvent",
    "KVEngine",
    "EngineReport",
    "RequestServer",
    "GetAtRequest",
    "GetRequest",
    "SetRequest",
    "FlushRequest",
    "Message",
    "Reply",
]
