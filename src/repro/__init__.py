"""Async-fork snapshot substrate for JAX state (see DESIGN.md)."""
