from repro.data.pipeline import SyntheticPipeline, make_batch_specs

__all__ = ["SyntheticPipeline", "make_batch_specs"]
