"""Synthetic token pipeline with background prefetch.

``make_batch_specs`` is the single source of truth for every model's
input signature per (arch, shape) — the dry-run lowers against exactly
these specs, and the pipeline materializes host batches matching them.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg


def make_batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            specs["extra_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            specs["extra_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len KV cache/state
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, 1), jnp.int32)
    return specs


def batch_pspecs(cfg: ArchConfig, shape: ShapeCfg, multi_pod: bool) -> Dict[str, P]:
    """Input shardings: global batch over (pod, data)."""
    bdim = ("pod", "data") if multi_pod else ("data",)
    specs = make_batch_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if name == "mrope_positions":
            out[name] = P(None, bdim, None)
        elif name == "pos":
            out[name] = P(bdim)
        elif s.ndim == 3:
            out[name] = P(bdim, None, None)
        else:
            out[name] = P(bdim, *([None] * (s.ndim - 1)))
    return out


class SyntheticPipeline:
    """Reproducible token stream + double-buffered host prefetch."""

    def __init__(self, cfg: ArchConfig, shape: ShapeCfg, seed: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self._q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _make(self, rng: np.random.Generator) -> Dict[str, np.ndarray]:
        specs = make_batch_specs(self.cfg, self.shape)
        out = {}
        for name, s in specs.items():
            if name == "tokens":
                out[name] = rng.integers(0, self.cfg.vocab, s.shape, dtype=np.int32)
            elif name == "pos":
                out[name] = np.full(s.shape, self.shape.seq_len - 1, np.int32)
            elif name == "mrope_positions":
                base = np.arange(s.shape[-1], dtype=np.int32)
                out[name] = np.broadcast_to(base, s.shape).copy()
            else:
                out[name] = rng.standard_normal(s.shape).astype(np.float32)
        return out

    def _worker(self):
        rng = np.random.default_rng(self.seed)
        while not self._stop.is_set():
            batch = self._make(rng)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
