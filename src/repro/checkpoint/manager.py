"""Async-fork checkpointing for the training loop.

The hazard: production train steps DONATE (params, opt_state) — the
pre-step buffers are destroyed at every step boundary, so a checkpoint
must either stall the loop while it copies state out (default-fork
behaviour: the Orbax-style synchronous D2H), or protect the fork-time
buffers while a background copier drains them (Async-fork).

Async-fork mode here = the paper's design mapped to step-granular
updates (DESIGN.md §2):

  * ``save()`` is O(metadata): build the block table over the CURRENT
    state refs, start copier threads, return immediately.
  * While any snapshot's copy window is open, the manager hands the loop
    the NON-donating step (the CoW-of-data-pages analogue: old buffers
    stay alive for the "child", new buffers carry training forward).
  * Progressive release: as each leaf's two-way pointer closes (all its
    blocks staged), the manager drops the T0 reference — the 2x memory
    transient decays leaf-by-leaf instead of persisting for the window.
  * When the copy window closes, the loop gets the donating step back.

Sharded checkpoints (``shards > 1``): the state's leaves are partitioned
(greedy by bytes) across N shard providers, each with its own block table
and snapshotter; all shards stamp T0 behind the coordinator's fork
barrier and persist through one shared parallel pipeline into
``step_X/shard_k/`` FileSinks under a composite manifest (DESIGN.md §6).

``restore_checkpoint`` reads a FileSink directory — flat, delta-chained,
or composite-sharded (each shard resolving its own chain) — back into
(params, opt) host trees; re-device_put with any mesh's shardings gives
elastic restore (different device counts / topologies) for free.

Output location: ``directory=None`` defaults to ``$REPRO_CKPT_DIR`` or
``<tempdir>/repro_ckpts`` — OUTSIDE the repo tree, so checkpoint binaries
can never be committed by accident (PR 1 landed 661 MB under
``results/ckpts/`` this way). Pass an explicit path to override.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.coordinator import CoordinatedSnapshot, ShardedSnapshotCoordinator
from repro.core.persist import PersistPipeline
from repro.core.provider import PyTreeProvider
from repro.core.sinks import FileSink, read_file_snapshot
from repro.core.snapshot import (
    AsyncForkSnapshotter,
    BlockingSnapshotter,
    SnapshotHandle,
)
from repro.optim.adamw import AdamWState
from repro.utils.tree import flatten_with_paths, leaf_nbytes


def default_checkpoint_dir() -> str:
    """Checkpoints default OUTSIDE the repo tree; override with the
    ``REPRO_CKPT_DIR`` environment variable or an explicit ``directory``."""
    return os.environ.get(
        "REPRO_CKPT_DIR", os.path.join(tempfile.gettempdir(), "repro_ckpts")
    )


def _shard_leaves(flat: Sequence[Tuple[str, object]], shards: int) -> List[List[Tuple[str, object]]]:
    """Greedy byte-balanced partition of (path, leaf) pairs into shards.

    Deterministic for a fixed state structure, so shard k holds the same
    leaves at every save — a requirement for per-shard delta chains."""
    order = sorted(range(len(flat)), key=lambda i: -leaf_nbytes(flat[i][1]))
    loads = [0] * shards
    out: List[List[Tuple[str, object]]] = [[] for _ in range(shards)]
    for i in order:
        k = loads.index(min(loads))
        out[k].append(flat[i])
        loads[k] += leaf_nbytes(flat[i][1])
    return out


def _nest_tree(pairs: Sequence[Tuple[str, object]]) -> Dict:
    tree: Dict = {}
    for path, leaf in pairs:
        _nest(tree, path.split("/"), leaf)
    return tree


class TrainSnapshotManager:
    def __init__(
        self,
        directory: Optional[str] = None,
        mode: str = "asyncfork",
        copier_threads: int = 4,
        block_bytes: int = 4 << 20,
        copier_duty: float = 1.0,
        backend: str = "host",
        incremental: bool = False,
        full_every: int = 4,
        shards: int = 1,
        persist_workers: Optional[int] = None,
        durable: bool = True,
        compress: Optional[str] = None,
        replicate_to: Optional[str] = None,
    ):
        """``incremental=True`` turns the checkpoint stream into a delta
        chain: each save diffs against the previous save's retained T0
        image (the ``dirty`` kernel) and persists only changed blocks,
        with a full-snapshot anchor every ``full_every`` saves so restore
        chains stay short. ``backend`` picks host or device staging.

        ``shards > 1`` partitions the state across that many independent
        snapshot epochs per save (fork barrier + shared persist pipeline;
        ``persist_workers`` sizes the pool, default one per shard).

        ``durable=True`` (the default) runs the crash-safe commit
        protocol: per-run crc32 checksums in shard manifests, fsync of
        data + manifest + parent dir, and (sharded) the composite
        manifest's atomic rename as the single commit point.
        ``durable=False`` skips the fsyncs for throughput benchmarks.

        ``compress="zlib"`` writes every run as a zlib frame (DESIGN.md
        §13); checksums still cover the uncompressed bytes, so
        ``restore_checkpoint(verify=True)`` stays end-to-end. Deltas may
        compress over an uncompressed anchor and vice versa — each
        leaf's manifest records its own encoding.

        ``replicate_to`` names a standby pool directory: each save is
        shipped there by an :class:`~repro.core.replicate.EpochReplicator`
        on a background thread as soon as its commit point fires
        (carried-block diff on the wire, deep-verified arrival, replica-
        side rename commit — DESIGN.md §14). Ship threads chain, so the
        replica commits saves in save order and delta parents always
        precede their children; ``wait_all`` covers them. Ship failures
        are counted on ``self.replicator.metrics``, never raised into
        the training loop.

        ``directory=None`` resolves via :func:`default_checkpoint_dir`
        (outside the repo tree)."""
        self.directory = directory if directory is not None else default_checkpoint_dir()
        self.mode = mode
        self.copier_threads = copier_threads
        self.block_bytes = block_bytes
        self.copier_duty = copier_duty
        self.backend = backend
        self.incremental = bool(incremental)
        self.full_every = max(1, int(full_every))
        self.shards = max(1, int(shards))
        self.durable = bool(durable)
        self.compress = compress
        self._pipeline = PersistPipeline(
            workers=persist_workers if persist_workers is not None
            else max(1, self.shards)
        )
        self._snaps: List[Tuple[SnapshotHandle, PyTreeProvider]] = []
        # sharded saves also carry a composite-commit thread whose rename
        # is the epoch's commit point; wait_all must cover it too
        self._composites: List[CoordinatedSnapshot] = []
        # chain base: (parts, dirname, per-shard leaf-path partition) —
        # the partition is the manager's "layout"; a save whose partition
        # differs from the base's degrades the changed shards to full
        self._chain_base: Optional[
            Tuple[List[SnapshotHandle], str, List[List[str]]]
        ] = None
        self._chain_len = 0
        self._layout_epoch = 0
        self.stall_log: List[Tuple[str, float]] = []  # (what, seconds)
        self.replicator = None
        self._ship_threads: List[threading.Thread] = []
        if replicate_to is not None:
            from repro.core.replicate import EpochReplicator
            self.replicator = EpochReplicator(replicate_to)

    def reshard(self, shards: int) -> None:
        """Change the shard count for subsequent saves. Resets the delta
        chain: the next save is a full anchor under the new partition
        (per-shard delta chains require a stable leaf assignment, and a
        reshard changes every shard's assignment at once)."""
        shards = max(1, int(shards))
        if shards == self.shards:
            return
        self.shards = shards
        self._chain_base, self._chain_len = None, 0
        self._layout_epoch += 1

    # ------------------------------------------------------------------ #
    def snapshot_active(self) -> bool:
        self._release_done_leaves()
        return any(not s.copy_done.is_set() for s, _ in self._snaps)

    def _release_done_leaves(self) -> None:
        """Progressive release: drop T0 refs for fully-copied leaves."""
        for snap, prov in self._snaps:
            if snap.aborted:
                continue
            for h in snap.table.leaf_handles:
                if snap.table.leaf_done(h.leaf_id):
                    prov.update_leaf(h.leaf_id, _TOMBSTONE)

    def _make_snapshotter(self, provider: PyTreeProvider):
        if self.mode == "blocking":
            snapper = BlockingSnapshotter(
                provider, block_bytes=self.block_bytes, backend=self.backend
            )
        else:
            snapper = AsyncForkSnapshotter(
                provider,
                block_bytes=self.block_bytes,
                copier_threads=self.copier_threads,
                copier_duty=self.copier_duty,
                backend=self.backend,
            )
        snapper.persist_pipeline = self._pipeline
        return snapper

    def save(
        self, step: int, params, opt_state: AdamWState
    ) -> Union[SnapshotHandle, CoordinatedSnapshot]:
        """Take a checkpoint of (params, opt_state) at this step boundary.

        With ``incremental`` enabled, saves between anchors are deltas:
        each shard's snapshot diffs against the previous save's T0 image
        and its FileSink manifest records the parent directory + carried
        blocks. Returns a :class:`SnapshotHandle` (``shards == 1``) or a
        :class:`CoordinatedSnapshot` (``shards > 1``).
        """
        t0 = time.perf_counter()
        state = {"params": params, "opt": {"step": opt_state.step,
                                           "m": opt_state.m, "v": opt_state.v}}
        dirname = f"step_{step:08d}"
        path = os.path.join(self.directory, dirname)

        # the leaf partition (and its path lists, the manager's "layout")
        # only exist on the sharded path — a single-shard save must not
        # pay a tree flatten + greedy partition + path sort per call
        shard_paths: Optional[List[List[str]]] = None
        if self.shards > 1:
            flat, _ = flatten_with_paths(state)
            shard_flat = _shard_leaves(flat, self.shards)
            shard_paths = [sorted(p for p, _ in pairs) for pairs in shard_flat]

        bases: List[Optional[SnapshotHandle]] = [None] * self.shards
        parent: Optional[str] = None
        if self.incremental and self._chain_base is not None:
            prev_parts, prev_dir, prev_paths = self._chain_base
            if any(p.aborted for p in prev_parts) or \
                    len(prev_parts) != self.shards:
                # a base sink directory is gone (FileSink.abort), or the
                # shard count changed under us; restart the chain with a
                # fresh full anchor
                self._chain_base, self._chain_len = None, 0
            elif self._chain_len % self.full_every != 0:
                bases, parent = list(prev_parts), prev_dir
                # re-partitioning across the chain: any shard whose leaf
                # assignment changed (the state structure moved leaves
                # between shards) cannot diff against its old image —
                # degrade THAT shard to a full epoch, keep the rest delta.
                # (Single-shard chains need no comparison: a reshaped leaf
                # degrades per leaf inside _mark_clean_blocks.)
                if shard_paths is not None:
                    for k in range(self.shards):
                        if shard_paths[k] != prev_paths[k]:
                            bases[k] = None

        if self.shards == 1:
            provider = PyTreeProvider(state)  # pins T0 refs (CoW data pages)
            sink = FileSink(path, parent=parent, durable=self.durable,
                            compress=self.compress)
            snapper = self._make_snapshotter(provider)
            snap = snapper.fork(sink, incremental=bases[0] is not None,
                                base=bases[0])
            parts, providers = [snap], [provider]
            result: Union[SnapshotHandle, CoordinatedSnapshot] = snap
        else:
            layout_record = {"kind": "leaves", "epoch": self._layout_epoch,
                             "shards": shard_paths}
            providers = [PyTreeProvider(_nest_tree(pairs))
                         for pairs in shard_flat]
            # a per-save coordinator over the per-save providers: its fork
            # barrier stamps every shard's T0 before any copier starts
            # (the training loop is paused inside save(), so the write
            # gate is uncontended) and all shards share this manager's
            # persist pipeline
            coord = ShardedSnapshotCoordinator(
                providers, mode=self.mode, pipeline=self._pipeline,
                block_bytes=self.block_bytes,
                copier_threads=self.copier_threads,
                copier_duty=self.copier_duty, backend=self.backend,
            )
            result = coord.bgsave_to_dir(path, parent=parent, bases=bases,
                                         prefix="", layout_record=layout_record,
                                         durable=self.durable,
                                         compress=self.compress)
            parts = result.parts
            self._composites.append(result)

        for snap, prov in zip(parts, providers):
            self._snaps.append((snap, prov))
        if self.incremental:
            self._chain_base = (parts, dirname, shard_paths)
            self._chain_len += 1
        if self.replicator is not None:
            self._spawn_ship(result, path)
        self.stall_log.append(("save", time.perf_counter() - t0))
        return result

    def _spawn_ship(self, result, path: str) -> None:
        """Ship this save to the standby pool once its commit point
        fires. Threads chain (each joins its predecessor) so the replica
        commits in save order — a delta's parent and a skip's alias
        target are always committed replica-side first."""
        prev = self._ship_threads[-1] if self._ship_threads else None

        def _ship():
            if prev is not None:
                prev.join()
            try:
                result.wait_persisted(600.0)
            except Exception:
                return  # an aborted save has nothing durable to ship
            try:
                self.replicator.ship_dir(path)
            except Exception:
                pass  # counted on replicator.metrics.transfer_failures

        t = threading.Thread(target=_ship, name="ckpt-ship", daemon=True)
        self._ship_threads.append(t)
        t.start()

    def wait_all(self, timeout: float = 600.0) -> None:
        """Block until every save is durable — including each sharded
        save's composite-manifest commit point; surfaces the first abort
        (even with persist workers still in flight) as SnapshotError."""
        for comp in self._composites:
            comp.wait_persisted(timeout)
        for snap, _ in self._snaps:
            snap.wait_persisted(timeout)
        for t in self._ship_threads:
            t.join(timeout)
        self._ship_threads = [
            t for t in self._ship_threads if t.is_alive()
        ]

    def gc(self) -> None:
        self._release_done_leaves()
        self._snaps = [
            (s, p) for s, p in self._snaps if not s.persist_done.is_set()
        ]
        self._composites = [
            c for c in self._composites if not c.commit_done.is_set()
        ]

    def summary(self) -> Dict[str, float]:
        saves = [d for w, d in self.stall_log if w == "save"]
        return {
            "saves": float(len(saves)),
            "save_stall_ms_mean": float(np.mean(saves) * 1e3) if saves else 0.0,
            "save_stall_ms_max": float(np.max(saves) * 1e3) if saves else 0.0,
        }


class _Tombstone:
    """Placeholder for released T0 leaves (never read again)."""

    shape = ()
    dtype = np.float32


_TOMBSTONE = _Tombstone()


def restore_checkpoint(
    directory: str, workers: Optional[int] = None,
    max_depth: Optional[int] = None, verify: bool = True,
) -> Tuple[Dict, AdamWState]:
    """Read a checkpoint back into host numpy trees.

    Handles flat, delta-chained, and composite (sharded) snapshot
    directories alike — ``read_file_snapshot`` resolves shard manifests
    and per-shard parent chains transparently, restoring shards and
    leaves in parallel on a :class:`~repro.core.sinks.RestorePool`
    (``workers`` sizes it; default one per core, ``workers=1`` is the
    sequential path). ``max_depth`` bounds the parent-chain walk
    (corrupt/cyclic chains raise ``ValueError`` instead of recursing
    forever); ``None`` keeps ``read_file_snapshot``'s default bound.
    ``verify=True`` (default) checks every carried block's recorded
    crc32 against the bytes read — a flipped bit in a committed run
    raises ``ValueError`` naming the shard dir instead of silently
    restoring garbage.

    Elastic restart: callers re-``device_put`` these with whatever mesh
    they now have — nothing in the file format encodes the old topology.
    """
    kw = {} if max_depth is None else {"max_depth": int(max_depth)}
    flat = read_file_snapshot(directory, workers=workers, verify=verify, **kw)
    params: Dict = {}
    opt_m: Dict = {}
    opt_v: Dict = {}
    step = None
    for path, arr in flat.items():
        parts = path.split("/")
        if parts[0] == "params":
            _nest(params, parts[1:], arr)
        elif parts[0] == "opt" and parts[1] == "m":
            _nest(opt_m, parts[2:], arr)
        elif parts[0] == "opt" and parts[1] == "v":
            _nest(opt_v, parts[2:], arr)
        elif parts[0] == "opt" and parts[1] == "step":
            step = arr
    state = AdamWState(step=np.asarray(step), m=opt_m, v=opt_v)
    return params, state


def _nest(tree: Dict, parts, arr) -> None:
    for p in parts[:-1]:
        tree = tree.setdefault(p, {})
    tree[parts[-1]] = arr
