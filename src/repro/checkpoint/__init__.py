from repro.checkpoint.manager import TrainSnapshotManager, restore_checkpoint

__all__ = ["TrainSnapshotManager", "restore_checkpoint"]
