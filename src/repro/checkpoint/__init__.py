from repro.checkpoint.manager import (
    TrainSnapshotManager,
    default_checkpoint_dir,
    restore_checkpoint,
)

__all__ = ["TrainSnapshotManager", "default_checkpoint_dir", "restore_checkpoint"]
