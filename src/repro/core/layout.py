"""Versioned shard layouts — the cluster's "slot map" as an epoch chain.

A :class:`ShardLayout` is an immutable range partition of a global block
space: shard ``k`` owns global copy blocks ``[bounds[k], bounds[k+1])``.
Each :meth:`split`/:meth:`merge` returns a NEW layout with ``epoch + 1``;
nothing is mutated in place, so an in-flight snapshot epoch can hold the
layout it was stamped against ("the frozen layout snapshot", DESIGN.md §8)
while the serving path swaps to the successor under the write gate.

The unit is a *block* — the same copy unit the ``BlockTable`` tracks — and
reshard points are always block-aligned, so a global block id translates
between any two layouts of the same block space by pure index arithmetic:
``shard = searchsorted(bounds, g, "right") - 1``, ``local = g - bounds
[shard]``. That translation is what lets the coordinator keep proactively
synchronizing epochs stamped under a *retired* layout after the serving
path has moved on (no byte ever has two owners; only the naming changes).

Row routing is the same search over ``bounds * rows_per_block`` — the
``ShardedKVStore`` caches that row-bounds vector and routes whole query
batches with one vectorized ``np.searchsorted``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Ordered block boundaries + layout epoch (immutable)."""

    bounds: Tuple[int, ...]  # len n_shards + 1, strictly increasing, [0] == 0
    epoch: int = 0

    def __post_init__(self):
        b = tuple(int(x) for x in self.bounds)
        object.__setattr__(self, "bounds", b)
        if len(b) < 2 or b[0] != 0:
            raise ValueError(f"bounds must start at 0 and name >=1 shard: {b}")
        if any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"bounds must be strictly increasing: {b}")

    # -- construction ----------------------------------------------------
    @classmethod
    def uniform(cls, shard_blocks: Sequence[int], epoch: int = 0) -> "ShardLayout":
        """Layout from per-shard block counts (in shard order)."""
        return cls(tuple(np.cumsum([0] + [int(n) for n in shard_blocks])), epoch)

    @classmethod
    def from_record(cls, record: Dict) -> "ShardLayout":
        if record.get("kind", "range") != "range":
            raise ValueError(f"not a range layout record: {record!r}")
        return cls(tuple(record["bounds"]), int(record.get("epoch", 0)))

    def to_record(self) -> Dict:
        """JSON-safe manifest record (``write_composite_manifest``)."""
        return {"kind": "range", "epoch": self.epoch, "bounds": list(self.bounds)}

    # -- geometry --------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.bounds) - 1

    @property
    def n_blocks(self) -> int:
        return self.bounds[-1]

    def block_start(self, shard_id: int) -> int:
        return self.bounds[shard_id]

    def shard_blocks(self, shard_id: int) -> int:
        return self.bounds[shard_id + 1] - self.bounds[shard_id]

    def interval(self, shard_id: int) -> Tuple[int, int]:
        return (self.bounds[shard_id], self.bounds[shard_id + 1])

    def shard_of_block(self, g: int) -> int:
        if not 0 <= g < self.n_blocks:
            raise IndexError(f"global block {g} outside [0, {self.n_blocks})")
        # bisect on the tuple: this sits on the gate-held write hot path
        # (retired-layout sync), where a per-call tuple→ndarray conversion
        # would reintroduce the per-write overhead the vectorized router
        # removed
        return bisect.bisect_right(self.bounds, g) - 1

    def shard_of_blocks(self, g: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`shard_of_block` (no bounds check)."""
        return np.searchsorted(self.bounds, np.asarray(g), side="right") - 1

    def row_bounds(self, rows_per_block: int) -> np.ndarray:
        """Shard boundaries in row space (for vectorized query routing)."""
        return np.asarray(self.bounds, dtype=np.int64) * int(rows_per_block)

    # -- reshard operations ----------------------------------------------
    def split(self, shard_id: int, at_block: Optional[int] = None) -> "ShardLayout":
        """Split shard ``shard_id`` at local block ``at_block`` (default:
        midpoint). Returns the successor layout (``epoch + 1``)."""
        lo, hi = self.interval(shard_id)
        n = hi - lo
        if n < 2:
            raise ValueError(f"shard {shard_id} has {n} block(s); cannot split")
        at = n // 2 if at_block is None else int(at_block)
        if not 0 < at < n:
            raise ValueError(f"split point {at} outside (0, {n})")
        bounds = self.bounds[: shard_id + 1] + (lo + at,) + self.bounds[shard_id + 1:]
        return ShardLayout(bounds, self.epoch + 1)

    def merge(self, shard_id: int, other: int) -> "ShardLayout":
        """Merge two ADJACENT shards (``other == shard_id + 1``)."""
        if other != shard_id + 1:
            raise ValueError(
                f"can only merge adjacent shards, got ({shard_id}, {other})"
            )
        if not 0 <= shard_id < self.n_shards - 1:
            raise IndexError(f"shard pair ({shard_id}, {other}) out of range")
        bounds = self.bounds[: shard_id + 1] + self.bounds[shard_id + 2:]
        return ShardLayout(bounds, self.epoch + 1)

    # -- cross-layout mapping --------------------------------------------
    def parents(self, old: "ShardLayout") -> List[List[int]]:
        """For each shard of THIS layout, the ``old``-layout shard indices
        whose block ranges overlap it (policy state / write counters follow
        this mapping across a reshard)."""
        if old.n_blocks != self.n_blocks:
            raise ValueError(
                f"layouts cover different block spaces: "
                f"{old.n_blocks} vs {self.n_blocks}"
            )
        out: List[List[int]] = []
        for k in range(self.n_shards):
            lo, hi = self.interval(k)
            first = int(np.searchsorted(old.bounds, lo, side="right")) - 1
            last = int(np.searchsorted(old.bounds, hi - 1, side="right")) - 1
            out.append(list(range(first, last + 1)))
        return out

    def unchanged_shards(self, old: "ShardLayout") -> Dict[int, int]:
        """``{new_shard: old_shard}`` for shards whose block interval is
        identical in both layouts (their snapshotters/state carry over)."""
        old_by_interval = {old.interval(p): p for p in range(old.n_shards)}
        out: Dict[int, int] = {}
        for k in range(self.n_shards):
            p = old_by_interval.get(self.interval(k))
            if p is not None:
                out[k] = p
        return out
