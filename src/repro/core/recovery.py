"""Crash recovery — rebuild the snapshot catalog from a pool directory.

The commit protocol (DESIGN.md §12) guarantees exactly one disk-visible
distinction: an epoch directory either has a composite ``manifest.json``
(every shard durably closed before the atomic rename published it) or it
does not (the crash landed anywhere earlier). This module is the reader
of that contract at process startup:

* roll half-finished compactor swaps forward or back (``<dir>.compact``
  with a complete manifest wins; an intact ``<dir>.old`` restores the
  pre-fold chain; leftovers of finished swaps are deleted),
* scan the pool's epoch dirs in commit order (composite-manifest mtime),
* validate each: manifest parses, every shard entry resolves, data files
  exist at manifest sizes, delta parents and skip aliases point at
  already-validated dirs, and — with ``deep_verify`` — every carried
  block's crc32 matches,
* quarantine anything torn or orphaned into ``pool/quarantine/`` (moved,
  NEVER deleted — a torn epoch is evidence, and a false-negative
  validation must not destroy data), and
* register the surviving prefix with
  :meth:`SnapshotCatalog.register_durable_epoch` so ``restore_checkpoint``,
  ``get_at`` and ``branch`` work across the restart.

Invariant: an epoch is recovered iff its commit point fired AND every
dir its manifests reference (transitively, through skip aliases and
delta parents) was itself recovered — so the recovered set is exactly a
prefix of the committed epochs, never a superset. A ``drop_epoch`` that
crashed before its ``rmtree`` is NOT durable: the epoch's dirs are still
complete on disk, so recovery legitimately resurrects it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.layout import ShardLayout
from repro.core.sinks import _decompressed_leaf_bytes, _verify_leaf_bytes


@dataclasses.dataclass
class RecoveryReport:
    """What a recovery pass found and did."""

    pool_dir: str
    recovered: List[int] = dataclasses.field(default_factory=list)
    recovered_dirs: List[str] = dataclasses.field(default_factory=list)
    quarantined: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)                     # (path, reason)
    repaired_swaps: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)                     # (path, action)
    blocks_verified: int = 0

    def summary(self) -> Dict[str, float]:
        return {
            "recovered_epochs": float(len(self.recovered)),
            "quarantined_dirs": float(len(self.quarantined)),
            "repaired_swaps": float(len(self.repaired_swaps)),
            "blocks_verified": float(self.blocks_verified),
        }


QUARANTINE_DIRNAME = "quarantine"


def _load_manifest(directory: str) -> Optional[Dict]:
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def quarantine_dest(pool_dir: str, name: str) -> str:
    """Reserve a unique destination under ``pool_dir/quarantine/`` for a
    dir named ``name`` (``.N`` suffix on collision). Creates the
    quarantine dir; the caller performs the rename."""
    qdir = os.path.join(pool_dir, QUARANTINE_DIRNAME)
    os.makedirs(qdir, exist_ok=True)
    dest = os.path.join(qdir, name)
    n = 1
    while os.path.exists(dest):
        dest = os.path.join(qdir, f"{name}.{n}")
        n += 1
    return dest


def validate_sink_dir(sdir: str, valid_dirs: Optional[set] = None,
                      deep_verify: bool = True,
                      manifest: Optional[Dict] = None,
                      ) -> Tuple[Optional[str], int]:
    """Validate one FileSink shard dir against its manifest.

    Returns ``(problem, blocks_verified)`` — ``problem`` is None when the
    dir is consistent, else a human-readable quarantine reason. This is
    the single verify pass shared by startup recovery, the background
    scrubber, and the replicator's arrival check:

    * ``valid_dirs=None`` skips the parent-linkage check (the scrubber's
      crc-only pass over an already-registered dir, and the replicator,
      which ships epochs in commit order so parents are covered by
      construction); a set enforces that any delta parent resolves to an
      already-validated dir (recovery's prefix-exactness invariant).
    * ``manifest`` overrides the on-disk ``manifest.json`` — the
      replicator verifies arrived bytes BEFORE the manifest rename
      publishes them, so the manifest only exists in memory at that
      point.
    """
    blocks_verified = 0
    if manifest is None:
        manifest = _load_manifest(sdir)
    if manifest is None:
        return f"shard dir {sdir!r} has no parseable manifest", 0
    if "leaves" not in manifest:
        return f"shard dir {sdir!r} manifest lacks a leaves table", 0
    parent = manifest.get("parent")
    if parent is not None and valid_dirs is not None:
        pdir = parent if os.path.isabs(parent) else os.path.normpath(
            os.path.join(os.path.dirname(sdir), parent)
        )
        if os.path.realpath(pdir) not in valid_dirs:
            return (f"shard dir {sdir!r} chains to parent {pdir!r}, "
                    "which is not a recovered shard dir"), 0
    for leaf in manifest["leaves"]:
        path = os.path.join(sdir, leaf["file"])
        dtype = np.dtype(leaf["dtype"])
        n_elems = int(np.prod(leaf["shape"])) if leaf["shape"] else 1
        if not os.path.exists(path):
            return (f"shard dir {sdir!r}: leaf {leaf['path']!r} data "
                    f"file {leaf['file']!r} is missing"), blocks_verified
        if leaf.get("compress"):
            # compressed leaves hold variable-length frames: bound-
            # check each frame against the file, then deep-verify on
            # the inflated image (crc over uncompressed bytes, §13)
            size = os.path.getsize(path)
            for fr in leaf.get("frames", []):
                if fr[2] + fr[3] > size:
                    return (f"shard dir {sdir!r}: leaf {leaf['path']!r}"
                            f" frame at offset {fr[2]} (+{fr[3]} bytes)"
                            f" overruns the {size}-byte data file"
                            ), blocks_verified
            if deep_verify and n_elems and leaf.get("crc32"):
                try:
                    _verify_leaf_bytes(
                        sdir, leaf, _decompressed_leaf_bytes(sdir, leaf)
                    )
                except ValueError as exc:
                    return str(exc), blocks_verified
                blocks_verified += sum(
                    1 for c in leaf["crc32"] if c is not None
                )
            continue
        if os.path.getsize(path) != n_elems * dtype.itemsize:
            return (f"shard dir {sdir!r}: leaf {leaf['path']!r} file "
                    f"holds {os.path.getsize(path)} bytes, manifest "
                    f"needs {n_elems * dtype.itemsize}"), blocks_verified
        if deep_verify and n_elems and leaf.get("crc32"):
            try:
                _verify_leaf_bytes(
                    sdir, leaf, np.memmap(path, dtype=np.uint8, mode="r")
                )
            except ValueError as exc:
                return str(exc), blocks_verified
            blocks_verified += sum(
                1 for c in leaf["crc32"] if c is not None
            )
    return None, blocks_verified


class RecoveryManager:
    """Startup scanner rebuilding a catalog from one pool directory."""

    def __init__(self, pool_dir: str, deep_verify: bool = True,
                 quarantine: bool = True):
        self.pool_dir = os.path.abspath(pool_dir)
        self.deep_verify = deep_verify
        # quarantine=False validates and registers identically but leaves
        # invalid dirs where they are (forensics / read-only mounts)
        self.quarantine = quarantine

    # -- public entry -----------------------------------------------------
    def recover_into(self, catalog) -> RecoveryReport:
        """Scan, repair, validate and register into ``catalog``."""
        report = RecoveryReport(self.pool_dir)
        if not os.path.isdir(self.pool_dir):
            return report
        self._repair_swaps(report)
        valid_dirs: set = set()
        for epoch_dir in self._epoch_dirs_in_commit_order():
            problem = self._validate_epoch(epoch_dir, valid_dirs, report)
            if problem is not None:
                self._quarantine(epoch_dir, problem, report)
                continue
            shard_dirs, parents, modes, layout = self._epoch_record(epoch_dir)
            eid = catalog.register_durable_epoch(
                epoch_dir, shard_dirs, parents, modes=modes, layout=layout,
            )
            report.recovered.append(eid)
            report.recovered_dirs.append(epoch_dir)
            for sd in shard_dirs:
                valid_dirs.add(os.path.realpath(sd))
        return report

    # -- swap repair ------------------------------------------------------
    def _repair_swaps(self, report: RecoveryReport) -> None:
        """Finish or undo compactor rename swaps the crash interrupted.

        The swap sequence is: build ``X.compact`` (complete, with its own
        manifest) → rename ``X`` to ``X.old`` → rename ``X.compact`` to
        ``X`` → remove ``X.old``. Every crash point is repairable:
        ``X`` present → any ``X.compact``/``X.old`` are leftovers (drop);
        ``X`` missing + complete ``X.compact`` → roll FORWARD (the fold
        is byte-equivalent to the chain it replaced); ``X`` missing +
        ``X.old`` only → roll BACK.
        """
        import shutil
        for dirpath, dirnames, _ in os.walk(self.pool_dir):
            if os.path.basename(dirpath) == QUARANTINE_DIRNAME:
                dirnames[:] = []
                continue
            # sorted: "X.compact" processes before "X.old", so the
            # mid-swap state (target missing, BOTH staged dirs present)
            # deterministically rolls forward and then drops the .old
            for name in sorted(dirnames):
                for suffix in (".compact", ".old"):
                    if not name.endswith(suffix):
                        continue
                    staged = os.path.join(dirpath, name)
                    target = staged[: -len(suffix)]
                    if os.path.exists(target):
                        shutil.rmtree(staged, ignore_errors=True)
                        report.repaired_swaps.append((staged, "dropped"))
                    elif suffix == ".compact" and os.path.exists(
                            os.path.join(staged, "manifest.json")):
                        os.rename(staged, target)
                        report.repaired_swaps.append((target, "rolled_forward"))
                    elif suffix == ".old":
                        os.rename(staged, target)
                        report.repaired_swaps.append((target, "rolled_back"))
                    else:
                        # an incomplete .compact with no target and no
                        # .old sibling processed yet: leave it for the
                        # .old branch of this same walk entry
                        continue

    # -- scanning ---------------------------------------------------------
    def _epoch_dirs_in_commit_order(self) -> List[str]:
        """Top-level pool entries, committed ones ordered by their
        composite manifest's mtime (the rename that published them), torn
        ones last (they quarantine regardless of order)."""
        entries = []
        for name in sorted(os.listdir(self.pool_dir)):
            if name == QUARANTINE_DIRNAME:
                continue
            path = os.path.join(self.pool_dir, name)
            if not os.path.isdir(path):
                continue
            manifest = os.path.join(path, "manifest.json")
            try:
                key = (0, os.stat(manifest).st_mtime_ns)
            except OSError:
                key = (1, 0)  # torn: no commit point, order immaterial
            entries.append((key, name, path))
        return [p for _, _, p in sorted(entries)]

    # -- validation -------------------------------------------------------
    def _validate_epoch(self, epoch_dir: str, valid_dirs: set,
                        report: RecoveryReport) -> Optional[str]:
        """None if the epoch is fully committed and internally consistent;
        otherwise a human-readable reason to quarantine it."""
        manifest = self._load_manifest(epoch_dir)
        if manifest is None:
            return "no composite manifest (torn epoch: crash before the " \
                   "commit-point rename)"
        if not manifest.get("composite"):
            # flat single-sink epoch (the unsharded checkpoint manager)
            return self._validate_sink_dir(epoch_dir, valid_dirs, report)
        for entry in manifest.get("shards", []):
            sdir = entry["dir"]
            if not os.path.isabs(sdir):
                sdir = os.path.normpath(os.path.join(epoch_dir, sdir))
            if entry.get("mode") == "skip":
                # zero-copy epoch: the entry aliases a PREVIOUS epoch's
                # shard dir, which must itself have been recovered
                if os.path.realpath(sdir) not in valid_dirs:
                    return (f"skip entry aliases {sdir!r}, which is not a "
                            "recovered shard dir")
                continue
            if not sdir.startswith(epoch_dir + os.sep):
                return f"non-skip entry escapes the epoch dir: {sdir!r}"
            problem = self._validate_sink_dir(sdir, valid_dirs, report)
            if problem is not None:
                return problem
        return None

    def _validate_sink_dir(self, sdir: str, valid_dirs: set,
                           report: RecoveryReport) -> Optional[str]:
        problem, blocks = validate_sink_dir(
            sdir, valid_dirs=valid_dirs, deep_verify=self.deep_verify)
        report.blocks_verified += blocks
        return problem

    @staticmethod
    def _load_manifest(directory: str) -> Optional[Dict]:
        return _load_manifest(directory)

    # -- registration inputs ----------------------------------------------
    def _epoch_record(self, epoch_dir: str):
        """(shard_dirs, parents, modes, layout) for a VALIDATED epoch."""
        manifest = self._load_manifest(epoch_dir)
        if not manifest.get("composite"):
            parent = manifest.get("parent")
            pdir = None
            if parent is not None:
                pdir = parent if os.path.isabs(parent) else os.path.normpath(
                    os.path.join(os.path.dirname(epoch_dir), parent)
                )
            return ([epoch_dir], [pdir],
                    ["delta" if parent else "full"], None)
        shard_dirs: List[str] = []
        parents: List[Optional[str]] = []
        modes: List[str] = []
        for entry in manifest["shards"]:
            sdir = entry["dir"]
            if not os.path.isabs(sdir):
                sdir = os.path.normpath(os.path.join(epoch_dir, sdir))
            mode = entry.get("mode", "full")
            pdir: Optional[str] = None
            if mode != "skip":
                smanifest = self._load_manifest(sdir) or {}
                parent = smanifest.get("parent")
                if parent is not None:
                    pdir = parent if os.path.isabs(parent) else \
                        os.path.normpath(os.path.join(
                            os.path.dirname(sdir), parent))
            shard_dirs.append(sdir)
            parents.append(pdir)
            modes.append(mode)
        layout = None
        rec = manifest.get("layout")
        if rec and rec.get("kind") == "range":
            try:
                layout = ShardLayout.from_record(rec)
            except Exception:
                layout = None
        return shard_dirs, parents, modes, layout

    # -- quarantine -------------------------------------------------------
    def _quarantine(self, path: str, reason: str,
                    report: RecoveryReport) -> None:
        if not self.quarantine:
            report.quarantined.append((path, reason))
            return
        dest = quarantine_dest(self.pool_dir, os.path.basename(path))
        os.rename(path, dest)
        report.quarantined.append((dest, reason))
