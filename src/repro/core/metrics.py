"""Interruption / out-of-service accounting (paper §6.2 "Deep Diving").

The paper measures, with bcc, every ``copy_pmd_range()`` invocation in the
parent: count, duration histogram, and the summed out-of-service time
(Figs. 11 and 20). We record the same three quantities for:

  * the fork() call itself (kernel-mode entry),
  * every proactive synchronization (Async-fork) / CoW fault (ODF mode).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Tuple

# bcc-style power-of-two latency buckets, in microseconds.
_BUCKETS = [(2**i, 2**(i + 1) - 1) for i in range(0, 26)]


@dataclasses.dataclass
class SnapshotMetrics:
    fork_s: float = 0.0               # parent time inside fork()
    copy_window_s: float = 0.0        # child's PMD/PTE copy duration (Fig 15a)
    persist_s: float = 0.0            # full snapshot window (fork -> durable)
    sink_write_s: float = 0.0         # sink open -> last write. Pure sink IO
                                      # when the image is fully staged at
                                      # submit (blocking mode, the bench
                                      # cells); in cow/asyncfork the workers'
                                      # residual staging overlaps it, so
                                      # bytes / sink_write_s then LOWER-bounds
                                      # sink bandwidth
    copied_blocks_child: int = 0
    copied_blocks_parent: int = 0     # proactive syncs / CoW faults
    inherited_blocks: int = 0         # clean blocks adopted from the base epoch
    total_blocks: int = 0             # block-table size at fork (dirty_frac denom)
    policy_mode: str = "full"         # "full" | "delta" (BgsavePolicy decision)
    gate_wait_s: float = 0.0          # summed write-gate acquisition waits
    gate_waits: int = 0               # gated writes that landed in this epoch
    read_retries: int = 0             # seqlock re-reads while this epoch ran
    shared_wait_s: float = 0.0        # readers' shared-stripe waits
    shared_waits: int = 0             # reads that fell back to shared mode
    persist_retries: int = 0          # sink-write attempts replayed by RetryPolicy
    persist_aborts: int = 0           # epochs abandoned after the retry budget
    stage_s: float = 0.0              # summed stager-lane busy time (flag
                                      # machine + batched D2H drain) across runs
    write_busy_s: float = 0.0         # summed writer-lane busy time (gathered
                                      # sink writes incl. retries) across runs
    overlap_s: float = 0.0            # measured seconds BOTH lanes of this
                                      # epoch were busy at once (lane
                                      # enter/exit accounting in the pipeline)
    aborted: bool = False

    def __post_init__(self):
        self._lock = threading.Lock()
        self.interruptions: List[Tuple[float, float, int]] = []  # (t, dur_s, blocks)
        self._stage_active = 0
        self._write_active = 0
        self._both_since: float | None = None

    def record_interruption(self, t: float, dur_s: float, blocks: int) -> None:
        with self._lock:
            self.interruptions.append((t, dur_s, blocks))
            self.copied_blocks_parent += blocks

    def record_gate_wait(self, wait_s: float) -> None:
        """One write's gate-acquisition wait while this epoch was in
        flight (striped gates: only same-shard contention ever waits)."""
        with self._lock:
            self.gate_wait_s += wait_s
            self.gate_waits += 1

    def record_read_event(self, retries: int, shared_wait_s: float) -> None:
        """One read's seqlock churn while this epoch was in flight:
        ``retries`` fast-path re-reads plus (when the read fell back to
        shared stripe mode) its summed shared-acquisition wait."""
        with self._lock:
            self.read_retries += retries
            if shared_wait_s > 0.0:
                self.shared_wait_s += shared_wait_s
                self.shared_waits += 1

    def record_persist_retry(self) -> None:
        """One sink-write attempt replayed after a transient OSError."""
        with self._lock:
            self.persist_retries += 1

    def record_persist_abort(self) -> None:
        """This epoch's persist failed past the retry budget."""
        with self._lock:
            self.persist_aborts += 1

    def record_stage(self, dur_s: float) -> None:
        """One run's stager-lane busy time (flag machine + D2H drain)."""
        with self._lock:
            self.stage_s += dur_s

    def record_write_busy(self, dur_s: float) -> None:
        """One run's writer-lane busy time (gathered sink write)."""
        with self._lock:
            self.write_busy_s += dur_s

    def lane_enter(self, lane: str, now: float) -> None:
        """A stager/writer lane of this epoch became busy at ``now``
        (``time.perf_counter``). When both lanes are live the clock for
        ``overlap_s`` starts; counts handle N concurrent workers per
        lane."""
        with self._lock:
            if lane == "stage":
                self._stage_active += 1
            else:
                self._write_active += 1
            if (self._both_since is None and self._stage_active > 0
                    and self._write_active > 0):
                self._both_since = now

    def lane_exit(self, lane: str, now: float) -> None:
        """The matching lane went idle; banks any accumulated both-lanes
        interval into ``overlap_s``."""
        with self._lock:
            if lane == "stage":
                self._stage_active -= 1
            else:
                self._write_active -= 1
            if (self._both_since is not None
                    and (self._stage_active == 0 or self._write_active == 0)):
                self.overlap_s += now - self._both_since
                self._both_since = None

    @property
    def overlap_frac(self) -> float:
        """Achieved lane concurrency: measured both-lanes-busy seconds
        over the smaller lane's total busy time (the most that could
        have overlapped), clamped to [0, 1]. 0 means stage and write
        strictly alternated (the serial pipeline); 1 means the D2H
        drain was fully hidden behind disk writes (or vice versa)."""
        cap = min(self.stage_s, self.write_busy_s)
        if cap <= 0.0:
            return 0.0
        return max(0.0, min(1.0, self.overlap_s / cap))

    @property
    def n_interruptions(self) -> int:
        return len(self.interruptions)

    @property
    def out_of_service_s(self) -> float:
        """Fig 20: fork time + every parent-side copy stall."""
        return self.fork_s + sum(d for _, d, _ in self.interruptions)

    def histogram_us(self) -> Dict[str, int]:
        """bcc-style histogram of interruption durations (Fig 11)."""
        out: Dict[str, int] = {}
        for _, dur, _ in self.interruptions:
            us = dur * 1e6
            if us < 1.0:
                out["[0us,1us)"] = out.get("[0us,1us)", 0) + 1
                continue
            for lo, hi in _BUCKETS:
                if lo <= us <= hi:
                    out[f"[{lo}us,{hi}us]"] = out.get(f"[{lo}us,{hi}us]", 0) + 1
                    break
            else:
                out["[>64s]"] = out.get("[>64s]", 0) + 1
        return out

    @property
    def dirty_frac(self) -> float:
        """Dirty fraction observed by this epoch's scan: blocks actually
        copied over blocks total. 1.0 for a full epoch by definition; NaN
        when the table size was never stamped."""
        if not self.total_blocks:
            return float("nan")
        return (self.total_blocks - self.inherited_blocks) / self.total_blocks

    def summary(self) -> Dict[str, float]:
        return {
            "mode": self.policy_mode,
            "dirty_frac": self.dirty_frac,
            "fork_ms": self.fork_s * 1e3,
            "copy_window_ms": self.copy_window_s * 1e3,
            "persist_ms": self.persist_s * 1e3,
            "sink_write_ms": self.sink_write_s * 1e3,
            "stage_ms": self.stage_s * 1e3,
            "write_busy_ms": self.write_busy_s * 1e3,
            "overlap_ms": self.overlap_s * 1e3,
            "overlap_frac": self.overlap_frac,
            "interruptions": float(self.n_interruptions),
            "out_of_service_ms": self.out_of_service_s * 1e3,
            "parent_copied_blocks": float(self.copied_blocks_parent),
            "child_copied_blocks": float(self.copied_blocks_child),
            "inherited_blocks": float(self.inherited_blocks),
            "gate_wait_us": self.gate_wait_s * 1e6,
            "gate_waits": float(self.gate_waits),
            "read_retries": float(self.read_retries),
            "shared_wait_us": self.shared_wait_s * 1e6,
            "shared_waits": float(self.shared_waits),
            "persist_retries": float(self.persist_retries),
            "persist_aborts": float(self.persist_aborts),
        }
