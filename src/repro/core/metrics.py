"""Interruption / out-of-service accounting (paper §6.2 "Deep Diving").

The paper measures, with bcc, every ``copy_pmd_range()`` invocation in the
parent: count, duration histogram, and the summed out-of-service time
(Figs. 11 and 20). We record the same three quantities for:

  * the fork() call itself (kernel-mode entry),
  * every proactive synchronization (Async-fork) / CoW fault (ODF mode).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Tuple

# bcc-style power-of-two latency buckets, in microseconds.
_BUCKETS = [(2**i, 2**(i + 1) - 1) for i in range(0, 26)]


@dataclasses.dataclass
class SnapshotMetrics:
    fork_s: float = 0.0               # parent time inside fork()
    copy_window_s: float = 0.0        # child's PMD/PTE copy duration (Fig 15a)
    persist_s: float = 0.0            # full snapshot window (fork -> durable)
    sink_write_s: float = 0.0         # sink open -> last write. Pure sink IO
                                      # when the image is fully staged at
                                      # submit (blocking mode, the bench
                                      # cells); in cow/asyncfork the workers'
                                      # residual staging overlaps it, so
                                      # bytes / sink_write_s then LOWER-bounds
                                      # sink bandwidth
    copied_blocks_child: int = 0
    copied_blocks_parent: int = 0     # proactive syncs / CoW faults
    inherited_blocks: int = 0         # clean blocks adopted from the base epoch
    total_blocks: int = 0             # block-table size at fork (dirty_frac denom)
    policy_mode: str = "full"         # "full" | "delta" (BgsavePolicy decision)
    gate_wait_s: float = 0.0          # summed write-gate acquisition waits
    gate_waits: int = 0               # gated writes that landed in this epoch
    read_retries: int = 0             # seqlock re-reads while this epoch ran
    shared_wait_s: float = 0.0        # readers' shared-stripe waits
    shared_waits: int = 0             # reads that fell back to shared mode
    persist_retries: int = 0          # sink-write attempts replayed by RetryPolicy
    persist_aborts: int = 0           # epochs abandoned after the retry budget
    stage_s: float = 0.0              # summed stager-lane busy time (flag
                                      # machine + batched D2H drain) across runs
    write_busy_s: float = 0.0         # summed writer-lane busy time (gathered
                                      # sink writes incl. retries) across runs
    overlap_s: float = 0.0            # measured seconds BOTH lanes of this
                                      # epoch were busy at once (lane
                                      # enter/exit accounting in the pipeline)
    aborted: bool = False

    def __post_init__(self):
        self._lock = threading.Lock()
        self.interruptions: List[Tuple[float, float, int]] = []  # (t, dur_s, blocks)
        self._stage_active = 0
        self._write_active = 0
        self._both_since: float | None = None

    def record_interruption(self, t: float, dur_s: float, blocks: int) -> None:
        with self._lock:
            self.interruptions.append((t, dur_s, blocks))
            self.copied_blocks_parent += blocks

    def record_gate_wait(self, wait_s: float) -> None:
        """One write's gate-acquisition wait while this epoch was in
        flight (striped gates: only same-shard contention ever waits)."""
        with self._lock:
            self.gate_wait_s += wait_s
            self.gate_waits += 1

    def record_read_event(self, retries: int, shared_wait_s: float) -> None:
        """One read's seqlock churn while this epoch was in flight:
        ``retries`` fast-path re-reads plus (when the read fell back to
        shared stripe mode) its summed shared-acquisition wait."""
        with self._lock:
            self.read_retries += retries
            if shared_wait_s > 0.0:
                self.shared_wait_s += shared_wait_s
                self.shared_waits += 1

    def record_persist_retry(self) -> None:
        """One sink-write attempt replayed after a transient OSError."""
        with self._lock:
            self.persist_retries += 1

    def record_persist_abort(self) -> None:
        """This epoch's persist failed past the retry budget."""
        with self._lock:
            self.persist_aborts += 1

    def record_stage(self, dur_s: float) -> None:
        """One run's stager-lane busy time (flag machine + D2H drain)."""
        with self._lock:
            self.stage_s += dur_s

    def record_write_busy(self, dur_s: float) -> None:
        """One run's writer-lane busy time (gathered sink write)."""
        with self._lock:
            self.write_busy_s += dur_s

    def lane_enter(self, lane: str, now: float) -> None:
        """A stager/writer lane of this epoch became busy at ``now``
        (``time.perf_counter``). When both lanes are live the clock for
        ``overlap_s`` starts; counts handle N concurrent workers per
        lane."""
        with self._lock:
            if lane == "stage":
                self._stage_active += 1
            else:
                self._write_active += 1
            if (self._both_since is None and self._stage_active > 0
                    and self._write_active > 0):
                self._both_since = now

    def lane_exit(self, lane: str, now: float) -> None:
        """The matching lane went idle; banks any accumulated both-lanes
        interval into ``overlap_s``."""
        with self._lock:
            if lane == "stage":
                self._stage_active -= 1
            else:
                self._write_active -= 1
            if (self._both_since is not None
                    and (self._stage_active == 0 or self._write_active == 0)):
                self.overlap_s += now - self._both_since
                self._both_since = None

    @property
    def overlap_frac(self) -> float:
        """Achieved lane concurrency: measured both-lanes-busy seconds
        over the smaller lane's total busy time (the most that could
        have overlapped), clamped to [0, 1]. 0 means stage and write
        strictly alternated (the serial pipeline); 1 means the D2H
        drain was fully hidden behind disk writes (or vice versa)."""
        cap = min(self.stage_s, self.write_busy_s)
        if cap <= 0.0:
            return 0.0
        return max(0.0, min(1.0, self.overlap_s / cap))

    @property
    def n_interruptions(self) -> int:
        return len(self.interruptions)

    @property
    def out_of_service_s(self) -> float:
        """Fig 20: fork time + every parent-side copy stall."""
        return self.fork_s + sum(d for _, d, _ in self.interruptions)

    def histogram_us(self) -> Dict[str, int]:
        """bcc-style histogram of interruption durations (Fig 11)."""
        out: Dict[str, int] = {}
        for _, dur, _ in self.interruptions:
            us = dur * 1e6
            if us < 1.0:
                out["[0us,1us)"] = out.get("[0us,1us)", 0) + 1
                continue
            for lo, hi in _BUCKETS:
                if lo <= us <= hi:
                    out[f"[{lo}us,{hi}us]"] = out.get(f"[{lo}us,{hi}us]", 0) + 1
                    break
            else:
                out["[>64s]"] = out.get("[>64s]", 0) + 1
        return out

    @property
    def dirty_frac(self) -> float:
        """Dirty fraction observed by this epoch's scan: blocks actually
        copied over blocks total. 1.0 for a full epoch by definition; NaN
        when the table size was never stamped."""
        if not self.total_blocks:
            return float("nan")
        return (self.total_blocks - self.inherited_blocks) / self.total_blocks

    def summary(self) -> Dict[str, float]:
        return {
            "mode": self.policy_mode,
            "dirty_frac": self.dirty_frac,
            "fork_ms": self.fork_s * 1e3,
            "copy_window_ms": self.copy_window_s * 1e3,
            "persist_ms": self.persist_s * 1e3,
            "sink_write_ms": self.sink_write_s * 1e3,
            "stage_ms": self.stage_s * 1e3,
            "write_busy_ms": self.write_busy_s * 1e3,
            "overlap_ms": self.overlap_s * 1e3,
            "overlap_frac": self.overlap_frac,
            "interruptions": float(self.n_interruptions),
            "out_of_service_ms": self.out_of_service_s * 1e3,
            "parent_copied_blocks": float(self.copied_blocks_parent),
            "child_copied_blocks": float(self.copied_blocks_child),
            "inherited_blocks": float(self.inherited_blocks),
            "gate_wait_us": self.gate_wait_s * 1e6,
            "gate_waits": float(self.gate_waits),
            "read_retries": float(self.read_retries),
            "shared_wait_us": self.shared_wait_s * 1e6,
            "shared_waits": float(self.shared_waits),
            "persist_retries": float(self.persist_retries),
            "persist_aborts": float(self.persist_aborts),
        }


@dataclasses.dataclass
class MaintenanceMetrics:
    """Counters for the off-path maintenance plane (DESIGN.md §14):
    epoch shipping to a standby pool and the background scrubber.

    :class:`SnapshotMetrics` above is per-epoch and owned by the write
    path; this one is process-lifetime and owned by whichever
    replicator/scrubber it is handed to. ``bytes_shipped`` counts bytes
    that actually crossed the "wire" (carried-block runs + compressed
    frames); ``bytes_logical`` counts what a naive full-copy of the same
    dirs would have moved (every leaf at its full uncompressed size) —
    their ratio is the ``delta_vs_full_bytes`` headline the replication
    bench cell gates on.
    """

    epochs_shipped: int = 0       # replica-side commit points published
    dirs_shipped: int = 0         # shard dirs whose bytes crossed the wire
    dirs_reused: int = 0          # skip aliases resolved replica-side (0 bytes)
    bytes_shipped: int = 0        # run/frame bytes actually transferred
    bytes_logical: int = 0        # full-copy equivalent of the shipped dirs
    transfer_retries: int = 0     # read/write attempts replayed by RetryPolicy
    transfer_failures: int = 0    # ships abandoned past the retry budget
    dirs_scrubbed: int = 0        # committed dirs the crc pass covered
    blocks_scrubbed: int = 0      # carried blocks whose crc32 was re-checked
    corrupt_found: int = 0        # dirs the scrubber failed verification on
    repaired: int = 0             # corrupt dirs replaced by a verified re-fetch
    quarantined: int = 0          # dirs moved (never deleted) to quarantine/
    orphans_removed: int = 0      # gc_errors orphans whose retry rmtree worked
    orphans_quarantined: int = 0  # orphans that failed the retry too

    def __post_init__(self):
        self._lock = threading.Lock()

    def record_ship(self, shipped_bytes: int, logical_bytes: int) -> None:
        """One shard dir's bytes arrived on the replica."""
        with self._lock:
            self.dirs_shipped += 1
            self.bytes_shipped += int(shipped_bytes)
            self.bytes_logical += int(logical_bytes)

    def record_dir_reused(self) -> None:
        """A skip alias resolved against an already-shipped replica dir."""
        with self._lock:
            self.dirs_reused += 1

    def record_epoch_shipped(self) -> None:
        with self._lock:
            self.epochs_shipped += 1

    def record_transfer_retry(self) -> None:
        with self._lock:
            self.transfer_retries += 1

    def record_transfer_failure(self) -> None:
        with self._lock:
            self.transfer_failures += 1

    def record_scrub(self, blocks: int) -> None:
        """One committed dir passed (or at least finished) the crc pass."""
        with self._lock:
            self.dirs_scrubbed += 1
            self.blocks_scrubbed += int(blocks)

    def record_corrupt(self) -> None:
        with self._lock:
            self.corrupt_found += 1

    def record_repair(self) -> None:
        with self._lock:
            self.repaired += 1

    def record_quarantine(self) -> None:
        with self._lock:
            self.quarantined += 1

    def record_orphan(self, removed: bool) -> None:
        """One ``catalog.gc_errors`` orphan consumed: retry rmtree worked
        (``removed=True``) or the orphan went to quarantine."""
        with self._lock:
            if removed:
                self.orphans_removed += 1
            else:
                self.orphans_quarantined += 1

    def summary(self) -> Dict[str, float]:
        with self._lock:
            return {
                "epochs_shipped": float(self.epochs_shipped),
                "dirs_shipped": float(self.dirs_shipped),
                "dirs_reused": float(self.dirs_reused),
                "bytes_shipped": float(self.bytes_shipped),
                "bytes_logical": float(self.bytes_logical),
                "transfer_retries": float(self.transfer_retries),
                "transfer_failures": float(self.transfer_failures),
                "dirs_scrubbed": float(self.dirs_scrubbed),
                "blocks_scrubbed": float(self.blocks_scrubbed),
                "corrupt_found": float(self.corrupt_found),
                "repaired": float(self.repaired),
                "quarantined": float(self.quarantined),
                "orphans_removed": float(self.orphans_removed),
                "orphans_quarantined": float(self.orphans_quarantined),
            }
