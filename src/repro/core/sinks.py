"""Persistence sinks — where the "child process" dumps the snapshot.

The paper's child writes an RDB file; persisting 8 GB takes ~40 s (~200 MB/s
disk). Benchmarks use ``NullSink`` with a configurable bandwidth to model
that window without real IO; the checkpoint manager uses ``FileSink``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.blocks import BlockRef, LeafHandle


class Sink:
    """``write_block`` accepts host numpy blocks or device (jax) blocks —
    device-staged snapshots hand sinks device arrays and the sink decides
    when (if ever) to pull the bytes to the host."""

    inherited: frozenset = frozenset()

    def set_delta(self, inherited, parent: Optional[str] = None) -> None:
        """Incremental epochs: declare the block keys this snapshot does
        NOT carry (they are inherited from the base epoch). Called before
        ``open``. ``parent`` optionally names the base snapshot."""
        self.inherited = frozenset(inherited)
        if parent is not None:
            self.parent = parent

    def open(self, leaf_handles: List[LeafHandle]) -> None:  # pragma: no cover
        raise NotImplementedError

    def write_block(self, ref: BlockRef, data) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass

    def abort(self) -> None:
        pass


class NullSink(Sink):
    """Discards bytes, pacing to ``bandwidth`` bytes/s (disk emulation)."""

    def __init__(self, bandwidth: Optional[float] = None):
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self._lock = threading.Lock()

    def open(self, leaf_handles):
        pass

    def write_block(self, ref, data):
        with self._lock:
            self.bytes_written += data.nbytes
        if self.bandwidth:
            time.sleep(data.nbytes / self.bandwidth)


class MemorySink(Sink):
    """Keeps every block in memory; used by consistency tests."""

    def __init__(self):
        self.blocks: Dict[tuple, np.ndarray] = {}
        self.leaf_handles: List[LeafHandle] = []
        self.closed = False
        self.aborted = False

    def open(self, leaf_handles):
        self.leaf_handles = leaf_handles

    def write_block(self, ref, data):
        self.blocks[ref.key] = np.array(data, copy=True)

    def close(self):
        self.closed = True

    def abort(self):
        self.aborted = True
        self.blocks.clear()


class FileSink(Sink):
    """One binary file per leaf + a JSON manifest (the "RDB file").

    Layout: ``<dir>/leaf_<id>.bin`` written at block offsets with
    ``os.pwrite``, plus ``manifest.json`` describing paths/shapes/dtypes —
    enough to restore without pickles. Writes carry their own offset and
    never seek, so any number of persister workers can write blocks
    **out of order and in parallel** into the same file (the pipeline in
    :mod:`repro.core.persist` relies on this).

    Block offsets are precomputed once in :meth:`open` as a per-leaf
    prefix-sum table — the seed recomputed ``sum(nbytes)`` per call, which
    made a leaf's persist O(blocks²).

    Incremental epochs: the manifest's per-leaf ``carried`` list records
    which block ids this snapshot actually wrote; everything else is
    inherited from the ``parent`` snapshot directory (a sibling directory
    name, a relative path, or an absolute path). ``read_file_snapshot``
    follows the chain.
    """

    def __init__(self, directory: str, parent: Optional[str] = None):
        self.dir = directory
        self.parent = parent
        self._files: Dict[int, object] = {}
        self._offsets: Dict[int, np.ndarray] = {}  # leaf_id -> prefix sums
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight = 0
        self._open = False

    def open(self, leaf_handles):
        os.makedirs(self.dir, exist_ok=True)
        inherited = self.inherited
        manifest = {
            "leaves": [
                {
                    "leaf_id": h.leaf_id,
                    "path": h.path,
                    "shape": list(h.shape),
                    "dtype": h.dtype.name if hasattr(h.dtype, "name") else str(h.dtype),
                    "file": f"leaf_{h.leaf_id}.bin",
                    "blocks": [[b.start, b.stop, b.nbytes] for b in h.blocks],
                    "carried": [
                        b.block_id for b in h.blocks
                        if b.key not in inherited
                    ],
                }
                for h in leaf_handles
            ]
        }
        if self.parent is not None:
            manifest["parent"] = self.parent
        with open(os.path.join(self.dir, "manifest.json.tmp"), "w") as f:
            json.dump(manifest, f)
        self._handles = {h.leaf_id: h for h in leaf_handles}
        for h in leaf_handles:
            self._offsets[h.leaf_id] = np.cumsum(
                [0] + [b.nbytes for b in h.blocks]
            )
            fp = open(os.path.join(self.dir, f"leaf_{h.leaf_id}.bin"), "wb")
            total = int(self._offsets[h.leaf_id][-1])
            if total:
                fp.truncate(total)
            self._files[h.leaf_id] = fp
        with self._lock:
            self._open = True

    def write_block(self, ref, data):
        # Serialize (and, for device blocks, pull to host) OUTSIDE any lock;
        # pwrite itself is positioned + thread-safe, so concurrent workers
        # writing different blocks of one leaf never contend.
        payload = np.ascontiguousarray(data).tobytes()
        offset = int(self._offsets[ref.leaf_id][ref.block_id])
        with self._lock:
            if not self._open:
                raise RuntimeError("FileSink closed or aborted")
            fd = self._files[ref.leaf_id].fileno()
            self._inflight += 1
        try:
            os.pwrite(fd, payload, offset)
        finally:
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()

    def _drain(self):
        """Quiesce in-flight writes and bar new ones (close/abort barrier)."""
        with self._cv:
            self._open = False
            while self._inflight:
                self._cv.wait(timeout=1.0)

    def close(self):
        self._drain()
        for fp in self._files.values():
            fp.close()
        os.replace(
            os.path.join(self.dir, "manifest.json.tmp"),
            os.path.join(self.dir, "manifest.json"),
        )

    def abort(self):
        self._drain()
        for fp in self._files.values():
            try:
                fp.close()
            except Exception:
                pass
        shutil.rmtree(self.dir, ignore_errors=True)


def write_composite_manifest(directory: str, shards: List[Dict]) -> None:
    """Top-level manifest for a sharded snapshot: ``shards`` is a list of
    ``{"dir": <relative shard dir>, "prefix": <leaf-path prefix>}`` entries.
    ``read_file_snapshot`` merges the shard restores (each shard dir is a
    normal FileSink directory, possibly the head of its own delta chain)."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump({"composite": True, "shards": shards}, f)
    os.replace(tmp, os.path.join(directory, "manifest.json"))


def read_file_snapshot(directory: str):
    """Restore {path: np.ndarray} from a FileSink directory.

    Incremental snapshots resolve transparently: blocks a manifest does
    not carry are filled from the ``parent`` snapshot (itself possibly a
    delta — the chain bottoms out at a full-snapshot anchor). Sharded
    snapshots (a composite manifest naming per-shard FileSink dirs) merge
    into one flat dict, each shard's leaf paths under its ``prefix``.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)

    if manifest.get("composite"):
        out = {}
        for entry in manifest["shards"]:
            sdir = entry["dir"]
            if not os.path.isabs(sdir):
                sdir = os.path.join(directory, sdir)
            prefix = entry.get("prefix", "")
            for path, arr in read_file_snapshot(sdir).items():
                out[prefix + path] = arr
        return out

    parent_cache = {}

    def _parent():
        # resolved lazily: a manifest may name a parent yet carry every
        # block (e.g. nothing was clean), and the parent directory need
        # not exist in that case
        if "out" not in parent_cache:
            parent = manifest["parent"]
            pdir = parent if os.path.isabs(parent) else os.path.join(
                os.path.dirname(os.path.abspath(directory)), parent
            )
            parent_cache["out"] = read_file_snapshot(pdir)
        return parent_cache["out"]

    has_parent = manifest.get("parent") is not None
    out = {}
    for leaf in manifest["leaves"]:
        arr = np.fromfile(
            os.path.join(directory, leaf["file"]), dtype=np.dtype(leaf["dtype"])
        )
        arr = arr.reshape(leaf["shape"]) if leaf["shape"] else (arr[0] if arr.size else arr)
        blocks = leaf.get("blocks")
        carried = leaf.get("carried")
        if has_parent and blocks is not None and carried is not None:
            carried_set = set(carried)
            missing = [b for b in range(len(blocks)) if b not in carried_set]
            if missing:
                parr = _parent()[leaf["path"]]
                if leaf["shape"]:
                    for b in missing:
                        start, stop, _ = blocks[b]
                        arr[start:stop] = parr[start:stop]
                else:
                    # scalar leaf inherited wholesale — copy, never alias:
                    # callers mutate restored arrays in place when resolving
                    # further deltas, and an alias would corrupt the parent's
                    # cached restore
                    arr = np.array(parr, copy=True)
        out[leaf["path"]] = arr
    return out
