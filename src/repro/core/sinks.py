"""Persistence sinks — where the "child process" dumps the snapshot.

The paper's child writes an RDB file; persisting 8 GB takes ~40 s (~200 MB/s
disk). Benchmarks use ``NullSink`` with a configurable bandwidth to model
that window without real IO; the checkpoint manager uses ``FileSink``.

Hot-path contract (DESIGN.md §7): the persist pipeline hands sinks
**runs** — ``write_run(leaf_id, start_block, arrays)`` with one array per
block of a contiguous same-leaf run. ``FileSink`` turns a run into one
gathered ``os.pwritev`` of zero-copy memoryviews (the seed made a full
``tobytes()`` copy of every block and issued one ``pwrite`` per block).
``write_block`` remains as the one-block run for compatibility.

Restore mirrors persist: :class:`RestorePool` fans ``read_file_snapshot``
out across shards and leaves (memory-mapped leaf files, delta-chain holes
resolved per contiguous run), cutting cold-restart wall-clock for sharded
checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.blocks import BlockRef, LeafHandle
from repro.core.faults import FaultInjector, fire as _fire_fault

# pwritev gathers at most IOV_MAX (1024 on Linux) buffers per call.
_IOV_MAX = 1024

# Parent-chain walks are bounded: a corrupt manifest (cyclic or absurdly
# deep parent refs) must fail with a clear error, not recurse forever.
_DEFAULT_MAX_DEPTH = 32


def _as_block_view(data) -> memoryview:
    """Zero-copy byte view of one staged block.

    ``np.asarray`` pulls a device block to host in one transfer and is a
    no-op on host numpy views; ``ascontiguousarray`` is a no-op for the
    contiguous axis-0 slices staging hands out. The uint8 reinterpret is
    a view too, and it keeps extension dtypes (bfloat16 & friends, which
    reject the buffer protocol) on the zero-copy path — no ``tobytes()``.
    """
    arr = np.ascontiguousarray(np.asarray(data))
    return memoryview(arr.reshape(-1).view(np.uint8))


class Sink:
    """``write_block``/``write_run`` accept host numpy blocks or device
    (jax) blocks — device-staged snapshots hand sinks device arrays and the
    sink decides when (if ever) to pull the bytes to the host."""

    inherited: frozenset = frozenset()

    def set_delta(self, inherited, parent: Optional[str] = None) -> None:
        """Incremental epochs: declare the block keys this snapshot does
        NOT carry (they are inherited from the base epoch). Called before
        ``open``. ``parent`` optionally names the base snapshot."""
        self.inherited = frozenset(inherited)
        if parent is not None:
            self.parent = parent

    def open(self, leaf_handles: List[LeafHandle]) -> None:  # pragma: no cover
        raise NotImplementedError

    def write_block(self, ref: BlockRef, data) -> None:  # pragma: no cover
        raise NotImplementedError

    def write_run(self, leaf_id: int, start_block: int, arrays: Sequence) -> None:
        """Write a contiguous run of blocks (``arrays[i]`` is block
        ``start_block + i`` of ``leaf_id``). Row geometry (``ref.start``/
        ``stop``) is unknown at this level, so there is no generic
        fallback: the persist pipeline detects write_block-only sinks and
        feeds them per-block with the real refs instead."""
        raise NotImplementedError(
            f"{type(self).__name__} implements only write_block; runs are "
            "split into per-block writes by the persist pipeline"
        )

    def close(self) -> None:
        pass

    def abort(self) -> None:
        pass


class NullSink(Sink):
    """Discards bytes, pacing to ``bandwidth`` bytes/s (disk emulation)."""

    def __init__(self, bandwidth: Optional[float] = None):
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self._lock = threading.Lock()

    def open(self, leaf_handles):
        pass

    def write_block(self, ref, data):
        self.write_run(ref.leaf_id, ref.block_id, [data])

    def write_run(self, leaf_id, start_block, arrays):
        nbytes = sum(int(a.nbytes) for a in arrays)
        with self._lock:
            self.bytes_written += nbytes
        if self.bandwidth:
            time.sleep(nbytes / self.bandwidth)


class MemorySink(Sink):
    """Keeps every block in memory; used by consistency tests."""

    def __init__(self):
        self.blocks: Dict[tuple, np.ndarray] = {}
        self.leaf_handles: List[LeafHandle] = []
        self.closed = False
        self.aborted = False

    def open(self, leaf_handles):
        self.leaf_handles = leaf_handles

    def write_block(self, ref, data):
        self.blocks[ref.key] = np.array(data, copy=True)

    def write_run(self, leaf_id, start_block, arrays):
        for i, data in enumerate(arrays):
            self.blocks[(leaf_id, start_block + i)] = np.array(data, copy=True)

    def close(self):
        self.closed = True

    def abort(self):
        self.aborted = True
        self.blocks.clear()


class FileSink(Sink):
    """One binary file per leaf + a JSON manifest (the "RDB file").

    Layout: ``<dir>/leaf_<id>.bin`` written at block offsets with
    positioned writes, plus ``manifest.json`` describing paths/shapes/
    dtypes — enough to restore without pickles. Writes carry their own
    offset and never seek, so any number of persister workers can write
    runs **out of order and in parallel** into the same file (the pipeline
    in :mod:`repro.core.persist` relies on this).

    A run lands as ONE ``os.pwritev`` gathering one zero-copy memoryview
    per block: adjacent blocks occupy adjacent offsets (the per-leaf
    prefix-sum table computed once in :meth:`open`), so the syscall count
    per leaf drops from ``n_blocks`` to ``n_blocks / run_blocks`` and no
    intermediate ``tobytes()`` buffers are materialized.

    Incremental epochs: the manifest's per-leaf ``carried`` list records
    which block ids this snapshot actually wrote; everything else is
    inherited from the ``parent`` snapshot directory (a sibling directory
    name, a relative path, or an absolute path). ``read_file_snapshot``
    follows the chain.

    Durability (DESIGN.md §12): every written block's crc32 lands in the
    manifest (per-leaf ``crc32`` list parallel to ``carried``) and is
    re-checked on restore. With ``durable=True``, :meth:`close` becomes a
    commit protocol — fsync every data file, fsync the manifest tmp,
    rename it into place, fsync the directory — so after close returns,
    the shard either exists completely on disk or (no manifest.json) is
    recognizably torn. ``faults`` threads a :class:`FaultInjector` through
    the sink's write/fsync/rename sites.

    Compression (DESIGN.md §13): with ``compress="zlib"`` each run lands
    as ONE zlib frame at an append-reserved offset instead of at the
    block's fixed offset; the manifest leaf records ``compress`` plus a
    ``frames`` list of ``[start_block, n_blocks, offset, comp_len]``
    entries, appended only AFTER the frame's write returns (a retried run
    re-reserves a fresh offset — the orphaned bytes leak file space but
    are unreachable from the manifest, so correctness is untouched).
    The crc32 list is computed over the UNCOMPRESSED block views before
    compression, so the §12 torn-write argument is unchanged: restore
    inflates the frames and checks the same per-block crcs.
    """

    def __init__(self, directory: str, parent: Optional[str] = None,
                 durable: bool = False,
                 faults: Optional[FaultInjector] = None,
                 compress: Optional[str] = None):
        if compress not in (None, "zlib"):
            raise ValueError(
                f"unknown compression {compress!r}; pick from (None, 'zlib')"
            )
        self.dir = directory
        self.parent = parent
        self.durable = durable
        self.faults = faults
        self.compress = compress
        self._files: Dict[int, object] = {}
        self._offsets: Dict[int, np.ndarray] = {}  # leaf_id -> prefix sums
        self._crcs: Dict[tuple, int] = {}          # (leaf_id, block_id) -> crc32
        self._append: Dict[int, int] = {}          # leaf_id -> append cursor
        self._frames: Dict[int, List[list]] = {}   # leaf_id -> frame records
        self._manifest: Optional[Dict] = None
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._inflight = 0
        self._open = False

    def open(self, leaf_handles):
        os.makedirs(self.dir, exist_ok=True)
        inherited = self.inherited
        manifest = {
            "leaves": [
                {
                    "leaf_id": h.leaf_id,
                    "path": h.path,
                    "shape": list(h.shape),
                    "dtype": h.dtype.name if hasattr(h.dtype, "name") else str(h.dtype),
                    "file": f"leaf_{h.leaf_id}.bin",
                    "blocks": [[b.start, b.stop, b.nbytes] for b in h.blocks],
                    "carried": [
                        b.block_id for b in h.blocks
                        if b.key not in inherited
                    ],
                }
                for h in leaf_handles
            ]
        }
        if self.compress is not None:
            for leaf in manifest["leaves"]:
                leaf["compress"] = self.compress
                leaf["frames"] = []
        if self.parent is not None:
            manifest["parent"] = self.parent
        self._manifest = manifest
        with open(os.path.join(self.dir, "manifest.json.tmp"), "w") as f:
            json.dump(manifest, f)
        self._handles = {h.leaf_id: h for h in leaf_handles}
        for h in leaf_handles:
            self._offsets[h.leaf_id] = np.cumsum(
                [0] + [b.nbytes for b in h.blocks]
            )
            fp = open(os.path.join(self.dir, f"leaf_{h.leaf_id}.bin"), "wb")
            total = int(self._offsets[h.leaf_id][-1])
            # compressed files grow by append-reserved frames; the fixed
            # block-offset layout (and its preallocation) does not apply
            if total and self.compress is None:
                fp.truncate(total)
            self._files[h.leaf_id] = fp
            self._append[h.leaf_id] = 0
            self._frames[h.leaf_id] = []
        with self._lock:
            self._open = True

    def write_block(self, ref, data):
        self.write_run(ref.leaf_id, ref.block_id, [data])

    def write_run(self, leaf_id, start_block, arrays):
        # Export views (and, for device blocks, pull to host) OUTSIDE any
        # lock; positioned writes are thread-safe, so concurrent workers
        # writing different runs of one leaf never contend.
        views = [_as_block_view(a) for a in arrays]
        # checksum before the write (and before any compression): the crc
        # covers the UNCOMPRESSED bytes we INTEND to land, so a torn
        # pwritev can never record a matching crc — §12 unchanged, §13
        if self.compress is not None:
            self._write_run_compressed(leaf_id, start_block, views)
            return
        offset = int(self._offsets[leaf_id][start_block])
        crcs = [zlib.crc32(v) for v in views]
        with self._lock:
            if not self._open:
                raise RuntimeError("FileSink closed or aborted")
            fd = self._files[leaf_id].fileno()
            self._inflight += 1
        try:
            _fire_fault("sink.write", f"leaf={leaf_id}+{start_block}",
                        self.faults)
            self._pwritev(fd, views, offset)
            with self._lock:
                for i, crc in enumerate(crcs):
                    self._crcs[(leaf_id, start_block + i)] = crc
        finally:
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()

    def _write_run_compressed(self, leaf_id, start_block, views):
        """One zlib frame per run at an append-reserved offset. Frame
        record + crcs are published only after the write returns; a
        failed/retried attempt orphans its reserved bytes (space leak,
        never a correctness leak — the manifest is authoritative).

        Level 1 deliberately: on block-structured numeric state it
        compresses within ~1% of the default level at ~15x the speed,
        keeping the stager lane from starving the writer lane."""
        crcs = [zlib.crc32(v) for v in views]
        comp = zlib.compress(b"".join(views), 1)
        with self._lock:
            if not self._open:
                raise RuntimeError("FileSink closed or aborted")
            fd = self._files[leaf_id].fileno()
            offset = self._append[leaf_id]
            self._append[leaf_id] = offset + len(comp)
            self._inflight += 1
        try:
            _fire_fault("sink.write", f"leaf={leaf_id}+{start_block}",
                        self.faults)
            self._pwritev(fd, [memoryview(comp)], offset)
            with self._lock:
                self._frames[leaf_id].append(
                    [start_block, len(views), offset, len(comp)]
                )
                for i, crc in enumerate(crcs):
                    self._crcs[(leaf_id, start_block + i)] = crc
        finally:
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()

    @staticmethod
    def _pwritev(fd, views: List[memoryview], offset: int) -> None:
        """One gathered positioned write, handling short writes and the
        IOV_MAX cap; falls back to per-view pwrite where pwritev is
        missing (non-Linux) — still zero-copy."""
        if not hasattr(os, "pwritev"):  # pragma: no cover - Linux CI
            for v in views:
                off = offset
                while len(v):
                    n = os.pwrite(fd, v, off)
                    off += n
                    offset += n
                    v = v[n:]
            return
        remaining = list(views)
        while remaining:
            written = os.pwritev(fd, remaining[:_IOV_MAX], offset)
            offset += written
            while remaining and written >= remaining[0].nbytes:
                written -= remaining[0].nbytes
                remaining.pop(0)
            if remaining and written:
                remaining[0] = remaining[0][written:]

    def _drain(self):
        """Quiesce in-flight writes and bar new ones (close/abort barrier)."""
        with self._cv:
            self._open = False
            while self._inflight:
                self._cv.wait(timeout=1.0)

    def close(self):
        self._drain()
        for fp in self._files.values():
            if self.durable:
                _fire_fault("sink.fsync", f"data {self.dir}", self.faults)
                os.fsync(fp.fileno())
            fp.close()
        # fold the accumulated per-block checksums into the manifest:
        # each leaf gets a ``crc32`` list parallel to ``carried`` (None
        # for a carried block the pipeline never wrote — restore then
        # skips it rather than certifying bytes nobody produced)
        tmp = os.path.join(self.dir, "manifest.json.tmp")
        if self._manifest is not None:
            with self._lock:
                crcs = dict(self._crcs)
                frames = {lid: sorted(fr) for lid, fr in self._frames.items()}
            for leaf in self._manifest["leaves"]:
                lid = leaf["leaf_id"]
                leaf["crc32"] = [crcs.get((lid, b)) for b in leaf["carried"]]
                if self.compress is not None:
                    leaf["frames"] = frames.get(lid, [])
            with open(tmp, "w") as f:
                json.dump(self._manifest, f)
                if self.durable:
                    _fire_fault("sink.fsync", f"manifest {self.dir}",
                                self.faults)
                    f.flush()
                    os.fsync(f.fileno())
        _fire_fault("sink.rename", self.dir, self.faults)
        os.replace(tmp, os.path.join(self.dir, "manifest.json"))
        if self.durable:
            _fsync_dir(self.dir)

    def abort(self):
        self._drain()
        for fp in self._files.values():
            try:
                fp.close()
            except Exception:
                pass
        shutil.rmtree(self.dir, ignore_errors=True)


def _fsync_dir(directory: str) -> None:
    """fsync a directory so a just-renamed entry survives power loss."""
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_composite_manifest(
    directory: str, shards: List[Dict], layout: Optional[Dict] = None,
    durable: bool = False, faults: Optional[FaultInjector] = None,
) -> None:
    """Top-level manifest for a sharded snapshot: ``shards`` is a list of
    ``{"dir": <relative shard dir>, "prefix": <leaf-path prefix>}`` entries
    (entries may also carry a per-shard ``"mode"``: full/delta/skip — a
    skip entry's dir points at a PREVIOUS epoch's shard directory, the
    zero-copy epoch). ``layout`` is the JSON layout record of the shard
    layout the snapshot was stamped under (``ShardLayout.to_record()`` for
    range partitions), letting a restore re-split/re-merge the image into
    whatever layout is current. ``read_file_snapshot`` merges the shard
    restores (each shard dir is a normal FileSink directory, possibly the
    head of its own delta chain).

    Entries may additionally carry explicit reference records the
    :class:`repro.core.catalog.SnapshotCatalog` maintains: ``"refs"`` (the
    relative dirs this entry depends on beyond its own — a delta's parent
    or a skip's alias target), ``"chain_depth"`` (delta hops below this
    entry's dir) and ``"aliased": true`` on skip entries. The manifest's
    top-level ``aliased_dirs`` counts the skip entries so chain growth is
    visible without walking shard manifests.

    With ``durable=True`` the rename of this manifest is THE commit point
    of the whole epoch (DESIGN.md §12): the tmp is fsync'd before the
    rename and the directory after it, and the caller must only invoke
    this once every shard sink has durably closed. A crash anywhere
    before the rename leaves no ``manifest.json`` — recovery sees a torn
    epoch; a crash after it leaves a complete one."""
    os.makedirs(directory, exist_ok=True)
    manifest: Dict = {"composite": True, "shards": shards}
    manifest["aliased_dirs"] = sum(
        1 for e in shards if e.get("mode") == "skip"
    )
    if layout is not None:
        manifest["layout"] = layout
    tmp = os.path.join(directory, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
        if durable:
            f.flush()
            os.fsync(f.fileno())
    _fire_fault("bgsave.commit", directory, faults)
    os.replace(tmp, os.path.join(directory, "manifest.json"))
    if durable:
        _fsync_dir(directory)


def read_snapshot_layout(directory: str) -> Optional[Dict]:
    """The layout record a composite snapshot was written under, or None
    (flat/legacy snapshots). Raw JSON — callers holding a range layout
    rebuild it with ``ShardLayout.from_record``."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    return manifest.get("layout")


# --------------------------------------------------------------------- #
# restore                                                               #
# --------------------------------------------------------------------- #
class RestorePool:
    """Restore-side mirror of the persist pipeline's worker pool.

    ``map`` runs ``fn`` over ``items`` on up to ``workers`` threads and
    returns results in item order, surfacing the first error. Each call
    spawns its own short-lived thread group, so nested maps (shards →
    leaves) can never deadlock on a shared executor; numpy/mmap reads
    release the GIL, so leaf restores genuinely overlap their IO.
    ``workers<=1`` (or a single item) runs inline — the sequential path.
    """

    def __init__(self, workers: Optional[int] = None):
        if workers is None:
            workers = min(8, os.cpu_count() or 1)
        self.workers = max(1, int(workers))

    def map(self, fn: Callable, items: Sequence) -> List:
        items = list(items)
        if self.workers <= 1 or len(items) <= 1:
            return [fn(it) for it in items]
        with ThreadPoolExecutor(
            max_workers=min(self.workers, len(items))
        ) as ex:
            return list(ex.map(fn, items))


def _coalesce_ids(ids: Sequence[int]) -> List[tuple]:
    """Sorted block ids -> [(start_id, stop_id), ...] contiguous runs."""
    runs: List[tuple] = []
    for b in ids:
        if runs and b == runs[-1][1]:
            runs[-1] = (runs[-1][0], b + 1)
        else:
            runs.append((b, b + 1))
    return runs


def snapshot_chain_depth(directory: str, max_depth: int = 64) -> int:
    """Delta-chain length under a (non-composite) FileSink directory: 0
    for a full snapshot, 1 + the parent's depth for a delta. Walks
    manifests only — no data IO. Raises ``ValueError`` on a missing
    manifest, a cyclic chain, or a chain deeper than ``max_depth``."""
    depth = 0
    cur = directory
    seen = {os.path.realpath(directory)}
    while True:
        try:
            with open(os.path.join(cur, "manifest.json")) as f:
                manifest = json.load(f)
        except (FileNotFoundError, NotADirectoryError):
            raise ValueError(
                f"broken delta chain under {directory!r}: missing "
                f"snapshot manifest in {cur!r}"
            ) from None
        parent = manifest.get("parent")
        if parent is None:
            return depth
        cur = parent if os.path.isabs(parent) else os.path.join(
            os.path.dirname(os.path.abspath(cur)), parent
        )
        real = os.path.realpath(cur)
        if real in seen:
            raise ValueError(
                f"cyclic delta chain under {directory!r}: parent ref "
                f"revisits {real!r}"
            )
        seen.add(real)
        depth += 1
        if depth > max_depth:
            raise ValueError(
                f"delta chain under {directory!r} exceeds max_depth="
                f"{max_depth}; refusing to walk a likely-corrupt manifest"
            )


def _verify_leaf_bytes(directory: str, leaf: Dict, buf) -> None:
    """Check the manifest's carried-block crc32s against ``buf`` (a flat
    uint8 view of the whole leaf blob — ndarray or memmap). Legacy
    manifests without a ``crc32`` list pass vacuously, as does any block
    whose recorded crc is None (carried but never written). Raises
    ``ValueError`` naming the shard directory on the first mismatch."""
    crcs = leaf.get("crc32")
    if not crcs:
        return
    blocks = leaf.get("blocks")
    carried = leaf.get("carried")
    if blocks is None or carried is None:
        return
    bounds = np.cumsum([0] + [b[2] for b in blocks])
    for b, crc in zip(carried, crcs):
        if crc is None:
            continue
        lo, hi = int(bounds[b]), int(bounds[b + 1])
        got = zlib.crc32(buf[lo:hi])
        if got != crc:
            raise ValueError(
                f"checksum mismatch in snapshot shard dir {directory!r}: "
                f"leaf {leaf['path']!r} block {b} (bytes [{lo},{hi})) "
                f"crc32 {got:#010x} != recorded {crc:#010x}"
            )


def _decompressed_leaf_bytes(directory: str, leaf: Dict) -> np.ndarray:
    """Inflate a compressed leaf blob back to its flat uncompressed byte
    image (one uint8 array covering every block offset; uncarried holes
    and never-written blocks stay zero, exactly like the uncompressed
    layout's preallocated file). Raises ``ValueError`` naming the shard
    directory on a frame that overruns the file, fails to decompress, or
    inflates to the wrong size — the compressed-era torn-write surface."""
    path = os.path.join(directory, leaf["file"])
    dtype = np.dtype(leaf["dtype"])
    n_elems = int(np.prod(leaf["shape"])) if leaf["shape"] else 1
    blocks = leaf.get("blocks") or []
    bounds = np.cumsum([0] + [b[2] for b in blocks])
    total = int(bounds[-1]) if blocks else n_elems * dtype.itemsize
    buf = np.zeros(total, dtype=np.uint8)
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        for start_block, nblocks, off, clen in leaf.get("frames", []):
            if off + clen > size:
                raise ValueError(
                    f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                    f"frame at offset {off} (+{clen} bytes) overruns the "
                    f"{size}-byte data file {leaf['file']!r}"
                )
            f.seek(off)
            try:
                raw = zlib.decompress(f.read(clen))
            except zlib.error as e:
                raise ValueError(
                    f"checksum mismatch in snapshot shard dir {directory!r}:"
                    f" leaf {leaf['path']!r} frame blocks "
                    f"[{start_block},{start_block + nblocks}) fails to "
                    f"decompress ({e})"
                ) from None
            lo = int(bounds[start_block])
            hi = int(bounds[start_block + nblocks])
            if len(raw) != hi - lo:
                raise ValueError(
                    f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                    f"frame blocks [{start_block},{start_block + nblocks}) "
                    f"inflates to {len(raw)} bytes, manifest needs {hi - lo}"
                )
            buf[lo:hi] = np.frombuffer(raw, np.uint8)
    return buf


def verify_snapshot_dir(directory: str, max_depth: int = _DEFAULT_MAX_DEPTH,
                        _chain: tuple = ()) -> int:
    """Checksum-verify every carried block reachable from ``directory``
    (composite fan-out plus delta-chain parents) without materializing a
    restore. Returns the number of blocks verified; raises ``ValueError``
    (naming the offending shard dir) on a mismatch, a missing/oversized
    file, or a broken chain. Used by :class:`repro.core.recovery.
    RecoveryManager`'s deep verification pass."""
    me = os.path.realpath(directory)
    if me in _chain:
        raise ValueError(
            f"corrupt snapshot {directory!r}: cyclic snapshot chain"
        )
    if len(_chain) >= max_depth:
        raise ValueError(
            f"snapshot chain under {directory!r} exceeds max_depth={max_depth}"
        )
    _chain = _chain + (me,)
    try:
        with open(os.path.join(directory, "manifest.json")) as f:
            manifest = json.load(f)
    except (FileNotFoundError, NotADirectoryError):
        raise ValueError(
            f"snapshot dir {directory!r} has no manifest.json (torn?)"
        ) from None
    checked = 0
    if manifest.get("composite"):
        for entry in manifest["shards"]:
            sdir = entry["dir"]
            if not os.path.isabs(sdir):
                sdir = os.path.join(directory, sdir)
            checked += verify_snapshot_dir(sdir, max_depth, _chain)
        return checked
    for leaf in manifest["leaves"]:
        path = os.path.join(directory, leaf["file"])
        if not os.path.exists(path):
            raise ValueError(
                f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                f"data file {leaf['file']!r} is missing"
            )
        dtype = np.dtype(leaf["dtype"])
        n_elems = int(np.prod(leaf["shape"])) if leaf["shape"] else 1
        if leaf.get("compress"):
            # the file holds variable-length frames; equality with the
            # uncompressed size is meaningless — bound-check each frame
            # and (below) crc the inflated image instead
            size = os.path.getsize(path)
            for fr in leaf.get("frames", []):
                if fr[2] + fr[3] > size:
                    raise ValueError(
                        f"corrupt snapshot {directory!r}: leaf "
                        f"{leaf['path']!r} frame at offset {fr[2]} "
                        f"(+{fr[3]} bytes) overruns the {size}-byte "
                        f"data file {leaf['file']!r}"
                    )
            if n_elems and leaf.get("crc32"):
                _verify_leaf_bytes(directory, leaf,
                                   _decompressed_leaf_bytes(directory, leaf))
                checked += sum(1 for c in leaf["crc32"] if c is not None)
            continue
        if os.path.getsize(path) != n_elems * dtype.itemsize:
            raise ValueError(
                f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                f"file {leaf['file']!r} holds {os.path.getsize(path)} "
                f"bytes, manifest needs {n_elems * dtype.itemsize}"
            )
        if n_elems and leaf.get("crc32"):
            mm = np.memmap(path, dtype=np.uint8, mode="r")
            _verify_leaf_bytes(directory, leaf, mm)
            checked += sum(1 for c in leaf["crc32"] if c is not None)
    parent = manifest.get("parent")
    if parent is not None:
        pdir = parent if os.path.isabs(parent) else os.path.join(
            os.path.dirname(os.path.abspath(directory)), parent
        )
        checked += verify_snapshot_dir(pdir, max_depth, _chain)
    return checked


def read_file_snapshot(
    directory: str,
    *,
    pool: Optional[RestorePool] = None,
    workers: Optional[int] = None,
    max_depth: int = _DEFAULT_MAX_DEPTH,
    verify: bool = True,
):
    """Restore {path: np.ndarray} from a FileSink directory.

    Incremental snapshots resolve transparently: blocks a manifest does
    not carry are filled from the ``parent`` snapshot (itself possibly a
    delta — the chain bottoms out at a full-snapshot anchor), with
    adjacent holes coalesced into one slice copy per contiguous run.
    Sharded snapshots (a composite manifest naming per-shard FileSink
    dirs) merge into one flat dict, each shard's leaf paths under its
    ``prefix``.

    Shards and leaves restore in parallel on a :class:`RestorePool`
    (default: one worker per core, capped at 8); pass ``workers=1`` for
    the sequential seed behavior, or a shared ``pool``. Returned leaves
    are materialized with GIL-releasing bulk reads (they overlap across
    pool workers); *parent* snapshots along a delta chain are
    memory-mapped instead, so a hole-free ancestor leaf contributes only
    the hole ranges a descendant copies out of it (an ancestor leaf that
    itself carries holes must still be materialized in full to resolve
    its own chain).

    Parent-chain walks are hard-bounded: a chain deeper than ``max_depth``
    hops, a cyclic parent ref, or a parent whose manifest is missing all
    raise ``ValueError`` instead of recursing or looping on a corrupt
    manifest.

    ``verify`` (default on) re-checks each carried block's manifest crc32
    against the bytes actually read and raises ``ValueError`` naming the
    shard dir on a mismatch; pass ``verify=False`` to skip (trusted local
    round-trips, benchmarks isolating raw restore bandwidth).
    """
    if pool is None:
        pool = RestorePool(workers)
    return _read_snapshot_dir(directory, pool, depth_left=max_depth,
                              verify=verify)


def _read_snapshot_dir(
    directory: str,
    pool: RestorePool,
    lazy: bool = False,
    depth_left: int = _DEFAULT_MAX_DEPTH,
    chain: tuple = (),
    verify: bool = True,
):
    # ``chain`` carries the realpaths already visited on this resolution
    # path (composite hop + parent hops); revisiting one is a cycle.
    me = os.path.realpath(directory)
    if me in chain:
        raise ValueError(
            f"corrupt snapshot {directory!r}: cyclic snapshot chain "
            f"({' -> '.join(chain + (me,))})"
        )
    chain = chain + (me,)
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)

    if manifest.get("composite"):
        entries = manifest["shards"]

        def _one_shard(entry):
            sdir = entry["dir"]
            if not os.path.isabs(sdir):
                sdir = os.path.join(directory, sdir)
            return entry.get("prefix", ""), _read_snapshot_dir(
                sdir, pool, lazy, depth_left=depth_left, chain=chain,
                verify=verify,
            )

        out = {}
        for prefix, shard_out in pool.map(_one_shard, entries):
            for path, arr in shard_out.items():
                out[prefix + path] = arr
        return out

    parent_cache: Dict[str, Dict] = {}
    parent_mu = threading.Lock()

    def _parent():
        # resolved lazily: a manifest may name a parent yet carry every
        # block (e.g. nothing was clean), and the parent directory need
        # not exist in that case. The lock makes concurrent leaf workers
        # share ONE recursive parent restore. Parents restore lazy
        # (memory-mapped): only the hole ranges the child actually copies
        # out are ever read from the ancestor files.
        with parent_mu:
            if "out" not in parent_cache:
                parent = manifest["parent"]
                pdir = parent if os.path.isabs(parent) else os.path.join(
                    os.path.dirname(os.path.abspath(directory)), parent
                )
                if depth_left <= 1:
                    raise ValueError(
                        f"corrupt snapshot {directory!r}: delta chain "
                        f"exceeds max_depth; parent {parent!r} not followed"
                    )
                if not os.path.exists(os.path.join(pdir, "manifest.json")):
                    raise ValueError(
                        f"corrupt snapshot {directory!r}: parent snapshot "
                        f"{parent!r} is missing its manifest "
                        f"(resolved {pdir!r})"
                    )
                parent_cache["out"] = _read_snapshot_dir(
                    pdir, pool, lazy=True,
                    depth_left=depth_left - 1, chain=chain, verify=verify,
                )
            return parent_cache["out"]

    has_parent = manifest.get("parent") is not None
    leaves = manifest["leaves"]
    restored = pool.map(
        lambda leaf: _read_leaf(directory, leaf, has_parent, _parent, lazy,
                                verify),
        leaves,
    )
    return {leaf["path"]: arr for leaf, arr in zip(leaves, restored)}


def _read_leaf(directory: str, leaf: Dict, has_parent: bool, parent_fn,
               lazy: bool, verify: bool = True):
    """Restore one leaf; resolve delta holes per contiguous run.

    ``lazy`` (parent-chain position) memory-maps the blob so only the
    ranges a descendant copies out are read; the top level materializes
    with one bulk ``fromfile`` read, which releases the GIL and so
    overlaps across restore-pool workers.
    """
    path = os.path.join(directory, leaf["file"])
    dtype = np.dtype(leaf["dtype"])
    shape = tuple(leaf["shape"])
    n_elems = int(np.prod(shape)) if shape else 1
    compressed = bool(leaf.get("compress"))
    if n_elems == 0:
        return np.empty(shape, dtype=dtype)
    if not compressed:
        # stored-size checks only apply to the fixed block-offset layout;
        # a compressed blob holds variable-length frames whose inflated
        # sizes are checked in _decompressed_leaf_bytes
        if not shape and os.path.getsize(path) == 0:
            raise ValueError(
                f"corrupt snapshot {directory!r}: scalar leaf "
                f"{leaf['path']!r} has an empty data file {leaf['file']!r}"
            )
        n_stored = os.path.getsize(path) // dtype.itemsize
        if n_stored != n_elems:
            raise ValueError(
                f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                f"file {leaf['file']!r} holds {n_stored} {dtype} elements, "
                f"manifest shape {shape or '()'} needs {n_elems}"
            )

    blocks = leaf.get("blocks")
    carried = leaf.get("carried")
    missing: List[int] = []
    if has_parent:
        if blocks is None or carried is None:
            raise ValueError(
                f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
                "manifest names a parent but lacks the 'blocks'/'carried' "
                "lists needed to resolve the delta chain"
            )
        carried_set = set(carried)
        missing = [b for b in range(len(blocks)) if b not in carried_set]
    elif blocks is not None and carried is not None and \
            len(carried) < len(blocks):
        # a delta manifest with NO parent cannot be resolved — the
        # uncarried offsets hold zeros, and silently returning them would
        # corrupt the restore (e.g. a policy delta written into a bare
        # caller sink; the coordinator degrades those to full, this guard
        # is the restore-side backstop)
        raise ValueError(
            f"corrupt snapshot {directory!r}: leaf {leaf['path']!r} "
            f"carries only {len(carried)}/{len(blocks)} blocks but names "
            "no parent snapshot to inherit the rest from"
        )

    if lazy and not missing and not compressed:
        mm = np.memmap(path, dtype=dtype, mode="r")
        if verify:
            # carried-block slices of the raw byte map: only the verified
            # ranges are paged in, holes (none here) stay untouched
            _verify_leaf_bytes(directory, leaf,
                               np.memmap(path, dtype=np.uint8, mode="r"))
        return mm.reshape(shape) if shape else mm[0]

    if compressed:
        # no memmap era for compressed leaves: inflate the frames into a
        # flat byte image (even in parent-chain position — only whole
        # frames exist on disk), verify on it, then reinterpret
        buf = _decompressed_leaf_bytes(directory, leaf)
        if verify:
            _verify_leaf_bytes(directory, leaf, buf)
        arr = buf.view(dtype)
    else:
        arr = np.fromfile(path, dtype=dtype)
        if verify:
            # verify on the flat bytes BEFORE delta holes are filled from
            # the parent — the crc covers what THIS dir wrote, not the
            # merge
            _verify_leaf_bytes(directory, leaf, arr.view(np.uint8))
    arr = arr.reshape(shape) if shape else arr
    if missing:
        parr = parent_fn()[leaf["path"]]
        if shape:
            # fill each contiguous run of holes with one slice copy —
            # against a memmapped parent this reads exactly the hole
            # ranges of the ancestor file
            for b0, b1 in _coalesce_ids(missing):
                start, stop = blocks[b0][0], blocks[b1 - 1][1]
                arr[start:stop] = parr[start:stop]
            return arr
        # scalar leaf inherited wholesale — copy, never alias: callers
        # mutate restored arrays in place when resolving further deltas,
        # and an alias would corrupt the parent's cached restore
        return np.array(parr, copy=True)
    return arr if shape else arr[0]
