"""Persistence sinks — where the "child process" dumps the snapshot.

The paper's child writes an RDB file; persisting 8 GB takes ~40 s (~200 MB/s
disk). Benchmarks use ``NullSink`` with a configurable bandwidth to model
that window without real IO; the checkpoint manager uses ``FileSink``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.blocks import BlockRef, LeafHandle


class Sink:
    """``write_block`` accepts host numpy blocks or device (jax) blocks —
    device-staged snapshots hand sinks device arrays and the sink decides
    when (if ever) to pull the bytes to the host."""

    inherited: frozenset = frozenset()

    def set_delta(self, inherited, parent: Optional[str] = None) -> None:
        """Incremental epochs: declare the block keys this snapshot does
        NOT carry (they are inherited from the base epoch). Called before
        ``open``. ``parent`` optionally names the base snapshot."""
        self.inherited = frozenset(inherited)
        if parent is not None:
            self.parent = parent

    def open(self, leaf_handles: List[LeafHandle]) -> None:  # pragma: no cover
        raise NotImplementedError

    def write_block(self, ref: BlockRef, data) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass

    def abort(self) -> None:
        pass


class NullSink(Sink):
    """Discards bytes, pacing to ``bandwidth`` bytes/s (disk emulation)."""

    def __init__(self, bandwidth: Optional[float] = None):
        self.bandwidth = bandwidth
        self.bytes_written = 0
        self._lock = threading.Lock()

    def open(self, leaf_handles):
        pass

    def write_block(self, ref, data):
        with self._lock:
            self.bytes_written += data.nbytes
        if self.bandwidth:
            time.sleep(data.nbytes / self.bandwidth)


class MemorySink(Sink):
    """Keeps every block in memory; used by consistency tests."""

    def __init__(self):
        self.blocks: Dict[tuple, np.ndarray] = {}
        self.leaf_handles: List[LeafHandle] = []
        self.closed = False
        self.aborted = False

    def open(self, leaf_handles):
        self.leaf_handles = leaf_handles

    def write_block(self, ref, data):
        self.blocks[ref.key] = np.array(data, copy=True)

    def close(self):
        self.closed = True

    def abort(self):
        self.aborted = True
        self.blocks.clear()


class FileSink(Sink):
    """One binary file per leaf + a JSON manifest (the "RDB file").

    Layout: ``<dir>/leaf_<id>.bin`` written at block offsets (pwrite-style,
    so parallel persisters could write out of order), plus ``manifest.json``
    describing paths/shapes/dtypes — enough to restore without pickles.

    Incremental epochs: the manifest's per-leaf ``carried`` list records
    which block ids this snapshot actually wrote; everything else is
    inherited from the ``parent`` snapshot directory (a sibling directory
    name or an absolute path). ``read_file_snapshot`` follows the chain.
    """

    def __init__(self, directory: str, parent: Optional[str] = None):
        self.dir = directory
        self.parent = parent
        self._files: Dict[int, object] = {}
        self._lock = threading.Lock()

    def open(self, leaf_handles):
        os.makedirs(self.dir, exist_ok=True)
        inherited = self.inherited
        manifest = {
            "leaves": [
                {
                    "leaf_id": h.leaf_id,
                    "path": h.path,
                    "shape": list(h.shape),
                    "dtype": h.dtype.name if hasattr(h.dtype, "name") else str(h.dtype),
                    "file": f"leaf_{h.leaf_id}.bin",
                    "blocks": [[b.start, b.stop, b.nbytes] for b in h.blocks],
                    "carried": [
                        b.block_id for b in h.blocks
                        if b.key not in inherited
                    ],
                }
                for h in leaf_handles
            ]
        }
        if self.parent is not None:
            manifest["parent"] = self.parent
        with open(os.path.join(self.dir, "manifest.json.tmp"), "w") as f:
            json.dump(manifest, f)
        self._handles = {h.leaf_id: h for h in leaf_handles}
        for h in leaf_handles:
            fp = open(os.path.join(self.dir, f"leaf_{h.leaf_id}.bin"), "wb")
            total = sum(b.nbytes for b in h.blocks)
            if total:
                fp.truncate(total)
            self._files[h.leaf_id] = fp

    def write_block(self, ref, data):
        h = self._handles[ref.leaf_id]
        offset = sum(b.nbytes for b in h.blocks[: ref.block_id])
        fp = self._files[ref.leaf_id]
        with self._lock:
            fp.seek(offset)
            fp.write(np.ascontiguousarray(data).tobytes())

    def close(self):
        for fp in self._files.values():
            fp.close()
        os.replace(
            os.path.join(self.dir, "manifest.json.tmp"),
            os.path.join(self.dir, "manifest.json"),
        )

    def abort(self):
        for fp in self._files.values():
            try:
                fp.close()
            except Exception:
                pass
        shutil.rmtree(self.dir, ignore_errors=True)


def read_file_snapshot(directory: str):
    """Restore {path: np.ndarray} from a FileSink directory.

    Incremental snapshots resolve transparently: blocks a manifest does
    not carry are filled from the ``parent`` snapshot (itself possibly a
    delta — the chain bottoms out at a full-snapshot anchor).
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)

    parent_cache = {}

    def _parent():
        # resolved lazily: a manifest may name a parent yet carry every
        # block (e.g. nothing was clean), and the parent directory need
        # not exist in that case
        if "out" not in parent_cache:
            parent = manifest["parent"]
            pdir = parent if os.path.isabs(parent) else os.path.join(
                os.path.dirname(os.path.abspath(directory)), parent
            )
            parent_cache["out"] = read_file_snapshot(pdir)
        return parent_cache["out"]

    has_parent = manifest.get("parent") is not None
    out = {}
    for leaf in manifest["leaves"]:
        arr = np.fromfile(
            os.path.join(directory, leaf["file"]), dtype=np.dtype(leaf["dtype"])
        )
        arr = arr.reshape(leaf["shape"]) if leaf["shape"] else (arr[0] if arr.size else arr)
        blocks = leaf.get("blocks")
        carried = leaf.get("carried")
        if has_parent and blocks is not None and carried is not None:
            carried_set = set(carried)
            missing = [b for b in range(len(blocks)) if b not in carried_set]
            if missing:
                parr = _parent()[leaf["path"]]
                if leaf["shape"]:
                    for b in missing:
                        start, stop, _ = blocks[b]
                        arr[start:stop] = parr[start:stop]
                else:
                    arr = parr  # scalar leaf inherited wholesale
        out[leaf["path"]] = arr
    return out
