"""Sharded snapshot coordinator — cross-shard BGSAVE with a fork barrier,
dynamic shard layouts, and a per-shard full-vs-delta BGSAVE policy.

Production Redis clusters shard the keyspace and BGSAVE shards
independently; the paper's design (one child per VMA, one RDB writer)
snapshots a single instance. This module is the distributed analogue for
our substrate: the state is partitioned into N shards, each owning its own
``BlockTable`` + ``Snapshotter`` + staging backend, and the coordinator

  (a) takes a **consistent cross-shard BGSAVE** via a fork barrier: every
      shard's ``fork_prepare`` (write-protect + T0 stamp) completes while
      the write gate is held, before ANY shard's ``fork_commit`` launches
      copiers — so the union of shard images is a single point-in-time cut
      (consistency argument in DESIGN.md §6);
  (b) persists all shard epochs through one shared
      :class:`~repro.core.persist.PersistPipeline`;
  (c) supports **online resharding** (:meth:`set_layout`): a split/merge
      swaps in the successor :class:`~repro.core.layout.ShardLayout` under
      the same write gate the barrier holds, so no layout swap can land
      between two shards' T0 stamps — every epoch is stamped against one
      frozen layout. Epochs stamped under a *retired* layout keep
      receiving proactive synchronization: the write hook translates the
      (shard, leaf) it was called with into the retired layout's indexing
      through the global block id (DESIGN.md §8);
  (d) optionally delegates the full-vs-delta decision to a per-shard
      :class:`~repro.core.policy.BgsavePolicy` instead of one global
      ``incremental=`` flag; shards with zero writes since their last
      epoch take zero-copy "skip" epochs.

Writers cooperate through the STRIPED write gates (:attr:`gates`, a
:class:`~repro.core.gates.GateSet`, one reentrant stripe per shard): a
write holds only the touched shard's stripe across ``before_write`` →
donated-update-commit for its whole routed batch
(``ShardedKVStore.set(gate=...)`` does this, one acquisition per
(shard, batch)), while barrier-class operations — ``bgsave``'s fork
barrier, ``set_layout``, ``set_copier_duty``, ``invalidate_bases`` — take
ALL stripes in deterministic index order (:attr:`write_gate`). The §6
consistency argument generalizes stripe-wise: no commit *on shard k* can
land between shard k's T0 stamp and barrier release, because the barrier
holds stripe k for that whole interval (DESIGN.md §9). A single-threaded
engine (the paper's Redis model) never contends; multi-writer engines
only contend per shard.
"""
from __future__ import annotations

import math
import os
import shutil
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.catalog import SnapshotCatalog
from repro.core.gates import GateSet
from repro.core.layout import ShardLayout
from repro.core.persist import PersistPipeline
from repro.core.policy import BgsavePolicy, ShardEpochView, ShardWriteCounters
from repro.core.provider import PyTreeProvider
from repro.core.sinks import FileSink, NullSink, Sink, write_composite_manifest
from repro.core.snapshot import (SnapshotError, SnapshotHandle, Snapshotter,
                                 make_snapshotter)


class AggregateMetrics:
    """Read-only roll-up of per-shard :class:`SnapshotMetrics`.

    The parent-visible quantities sum (fork stalls and interruptions all
    land on the serving thread); the window quantities take the max (the
    barrier's window closes when the slowest shard's does).

    Under a :class:`BgsavePolicy` some shards may have *skipped* the epoch
    (zero-copy): they contribute no handle, so every roll-up here iterates
    only the shards that actually forked, and :meth:`summary` merges with
    defaults rather than assuming all shards report the same keys.
    """

    def __init__(
        self,
        parts: Sequence[Optional[SnapshotHandle]],
        modes: Optional[Sequence[str]] = None,
        chain_depths: Optional[Sequence[int]] = None,
        aliased_dirs: int = 0,
    ):
        # ``parts`` may be shard-ordered with None holes (skipped shards)
        self._by_shard = list(parts)
        self._parts = [p for p in self._by_shard if p is not None]
        self._modes = (
            list(modes) if modes is not None
            else ["full" if p is not None else "skip" for p in self._by_shard]
        )
        # durable epochs only: per-shard delta hops below each manifest
        # entry's dir, and how many entries alias a previous epoch's dir —
        # the ChainCompactor's trigger signal (None/0 for memory epochs)
        self._chain_depths = (
            list(chain_depths) if chain_depths is not None else None
        )
        self._aliased_dirs = int(aliased_dirs)

    @property
    def fork_s(self) -> float:
        """Serving-thread stall of the whole barrier: first prepare entry
        to last commit exit. Per-part fork_s intervals overlap (prepares
        and commits run sequentially on one thread), so summing them would
        overstate the stall roughly in proportion to shard count."""
        if not self._parts:
            return 0.0
        starts = [p.fork_start for p in self._parts]
        ends = [p.fork_start + p.metrics.fork_s for p in self._parts]
        return max(ends) - min(starts)

    @property
    def _t0(self) -> float:
        return min(p.t0 for p in self._parts)

    @property
    def copy_window_s(self) -> float:
        """Barrier start to the slowest shard's copy-window close."""
        if not self._parts:
            return 0.0
        return max(
            ((p.t0 - self._t0) + p.metrics.copy_window_s for p in self._parts),
            default=0.0,
        )

    @property
    def persist_s(self) -> float:
        """Barrier start to the slowest shard's durability."""
        if not self._parts:
            return 0.0
        return max(
            ((p.t0 - self._t0) + p.metrics.persist_s for p in self._parts),
            default=0.0,
        )

    @property
    def sink_write_s(self) -> float:
        """Slowest shard's pure sink-IO interval (shards drain the shared
        pipeline concurrently, so the max bounds the IO wall-clock)."""
        return max((p.metrics.sink_write_s for p in self._parts), default=0.0)

    @property
    def stage_s(self) -> float:
        """Summed stager-lane busy time across shards (lane busy times
        add — they measure work, not wall-clock)."""
        return sum(p.metrics.stage_s for p in self._parts)

    @property
    def write_busy_s(self) -> float:
        """Summed writer-lane busy time across shards."""
        return sum(p.metrics.write_busy_s for p in self._parts)

    @property
    def overlap_s(self) -> float:
        """Summed measured both-lanes-busy seconds across shards."""
        return sum(p.metrics.overlap_s for p in self._parts)

    @property
    def overlap_frac(self) -> float:
        """Barrier-level lane overlap: summed measured both-lanes-busy
        seconds over the summed per-shard overlap capacity (each shard's
        smaller lane busy time), clamped to [0, 1] — the same derivation
        as ``SnapshotMetrics.overlap_frac``, aggregated."""
        cap = sum(
            min(p.metrics.stage_s, p.metrics.write_busy_s)
            for p in self._parts
        )
        if cap <= 0.0:
            return 0.0
        return max(0.0, min(1.0, self.overlap_s / cap))

    @property
    def copied_blocks_child(self) -> int:
        return sum(p.metrics.copied_blocks_child for p in self._parts)

    @property
    def copied_blocks_parent(self) -> int:
        return sum(p.metrics.copied_blocks_parent for p in self._parts)

    @property
    def inherited_blocks(self) -> int:
        return sum(p.metrics.inherited_blocks for p in self._parts)

    @property
    def n_interruptions(self) -> int:
        return sum(p.metrics.n_interruptions for p in self._parts)

    @property
    def skipped_shards(self) -> int:
        return sum(1 for p in self._by_shard if p is None)

    @property
    def gate_wait_s(self) -> float:
        """Summed write-gate acquisition waits across shards (each lands
        on some writer thread, so — like interruptions — they add)."""
        return sum(p.metrics.gate_wait_s for p in self._parts)

    @property
    def read_retries(self) -> int:
        """Summed seqlock re-reads charged to shards' epochs."""
        return sum(p.metrics.read_retries for p in self._parts)

    @property
    def shared_wait_s(self) -> float:
        """Summed shared-stripe waits (reader-side ``gate_wait_s``)."""
        return sum(p.metrics.shared_wait_s for p in self._parts)

    @property
    def persist_retries(self) -> int:
        """Summed sink-write attempts replayed under the RetryPolicy."""
        return sum(p.metrics.persist_retries for p in self._parts)

    @property
    def persist_aborts(self) -> int:
        """Shard epochs abandoned after the retry budget."""
        return sum(p.metrics.persist_aborts for p in self._parts)

    @property
    def out_of_service_s(self) -> float:
        """Fig 20 analogue: one barrier stall + every parent-side copy
        stall (per-part out_of_service_s would re-count overlapping fork
        intervals, shard count times)."""
        return self.fork_s + sum(
            d for p in self._parts for _, d, _ in p.metrics.interruptions
        )

    def histogram_us(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for p in self._parts:
            for k, v in p.metrics.histogram_us().items():
                out[k] = out.get(k, 0) + v
        return out

    def summary(self) -> Dict[str, float]:
        per_shard: List[Dict] = []
        for k, p in enumerate(self._by_shard):
            mode = self._modes[k] if k < len(self._modes) else "full"
            if p is None:
                # zero-copy epoch: the shard's previous image stands in;
                # deliberately a MINIMAL dict — downstream merges must not
                # assume every shard reports every key
                per_shard.append({"mode": "skip", "zero_copy_epoch": 1.0})
            else:
                s = p.metrics.summary()
                s["mode"] = mode
                per_shard.append(s)
            if self._chain_depths is not None and \
                    k < len(self._chain_depths):
                per_shard[-1]["chain_depth"] = float(self._chain_depths[k])
        # skips are a CERTIFIED dirty fraction of 0.0 (that is what made
        # them skippable) — excluding them would overstate cluster
        # dirtiness exactly when the zero-copy optimization works best
        dirty: List[float] = []
        for s in per_shard:
            if s.get("mode") == "skip":
                dirty.append(0.0)
            else:
                df = s.get("dirty_frac")
                if isinstance(df, float) and not math.isnan(df):
                    dirty.append(df)
        return {
            "fork_ms": self.fork_s * 1e3,
            "copy_window_ms": self.copy_window_s * 1e3,
            "persist_ms": self.persist_s * 1e3,
            "sink_write_ms": self.sink_write_s * 1e3,
            "stage_ms": self.stage_s * 1e3,
            "write_busy_ms": self.write_busy_s * 1e3,
            "overlap_ms": self.overlap_s * 1e3,
            "overlap_frac": self.overlap_frac,
            "interruptions": float(self.n_interruptions),
            "out_of_service_ms": self.out_of_service_s * 1e3,
            "parent_copied_blocks": float(self.copied_blocks_parent),
            "child_copied_blocks": float(self.copied_blocks_child),
            "inherited_blocks": float(self.inherited_blocks),
            "shards": float(len(self._by_shard)),
            "full_shards": float(sum(1 for m in self._modes if m == "full")),
            "delta_shards": float(sum(1 for m in self._modes if m == "delta")),
            "skipped_shards": float(self.skipped_shards),
            "gate_wait_us": self.gate_wait_s * 1e6,
            "read_retries": float(self.read_retries),
            "shared_wait_us": self.shared_wait_s * 1e6,
            "persist_retries": float(self.persist_retries),
            "persist_aborts": float(self.persist_aborts),
            "dirty_frac_mean": (sum(dirty) / len(dirty)) if dirty else float("nan"),
            "chain_depth_max": float(max(self._chain_depths))
            if self._chain_depths else 0.0,
            "aliased_dirs": float(self._aliased_dirs),
            "per_shard": per_shard,
        }


class CoordinatedSnapshot:
    """The union of per-shard epochs taken at one fork barrier.

    ``parts_by_shard`` is shard-ordered with ``None`` holes for shards the
    policy skipped (zero-copy epochs); ``parts`` is the dense list of
    handles that actually forked. ``layout`` is the frozen layout the
    barrier was stamped under (``None`` for leaf-partitioned shards).
    """

    def __init__(
        self,
        parts: Sequence[Optional[SnapshotHandle]],
        directory: Optional[str] = None,
        *,
        layout: Optional[ShardLayout] = None,
        modes: Optional[Sequence[str]] = None,
        skipped_bases: Optional[Dict[int, SnapshotHandle]] = None,
    ):
        self.parts_by_shard: List[Optional[SnapshotHandle]] = list(parts)
        self.parts: List[SnapshotHandle] = [
            p for p in self.parts_by_shard if p is not None
        ]
        self.directory = directory
        self.layout = layout
        self.modes = (
            list(modes) if modes is not None
            else ["full" if p is not None else "skip" for p in self.parts_by_shard]
        )
        self._skipped_bases = dict(skipped_bases or {})
        now = time.perf_counter()
        self.t0 = min((p.t0 for p in self.parts), default=now)
        self.fork_start = min((p.fork_start for p in self.parts), default=now)
        # stamped by the SnapshotCatalog / bgsave_to_dir after commit
        self.epoch_id: Optional[int] = None
        self.chain_depths: Optional[List[int]] = None
        self.aliased_dirs: int = 0
        # durable (to-dir) epochs defer the composite-manifest commit to a
        # thread that waits for every shard's persist first (the rename is
        # the epoch's single commit point — DESIGN.md §12); commit_done
        # fires after the commit OR after a failed epoch's full unwind
        self.commit_done = threading.Event()
        self.commit_error: Optional[BaseException] = None
        self._commit_pending = False

    @property
    def metrics(self) -> AggregateMetrics:
        return AggregateMetrics(self.parts_by_shard, self.modes,
                                chain_depths=self.chain_depths,
                                aliased_dirs=self.aliased_dirs)

    def shard_handle(self, shard_id: int) -> Optional[SnapshotHandle]:
        """The handle holding shard ``shard_id``'s T0 image at this
        barrier: its own epoch if it forked, the base epoch its zero-copy
        skip certified byte-identical otherwise. ``None`` only for a
        skipped shard whose base record is gone (never the case for
        snapshots this coordinator produced)."""
        p = self.parts_by_shard[shard_id]
        if p is not None:
            return p
        return self._skipped_bases.get(shard_id)

    @property
    def aborted(self) -> bool:
        return any(p.aborted for p in self.parts)

    @property
    def ok(self) -> bool:
        return not self.aborted

    def wait(self, timeout: Optional[float] = None) -> bool:
        ok = True
        for p in self.parts:
            ok = p.wait(timeout) and ok
        return ok

    def wait_persisted(self, timeout: Optional[float] = None) -> bool:
        ok = True
        # durable epochs: wait the commit thread FIRST — it waits every
        # part itself, and on failure finishes the unwind before setting
        # the event, so a caller seeing the abort below can also trust
        # that the partial epoch dir is already gone
        if self._commit_pending:
            ok = self.commit_done.wait(timeout)
        for p in self.parts:
            ok = p.wait_persisted(timeout) and ok
        if self._commit_pending and self.commit_error is not None:
            raise SnapshotError(
                f"composite commit failed: {self.commit_error!r}"
            ) from self.commit_error
        return ok

    def to_trees(self) -> List:
        """Per-shard T0 pytrees, in shard order. A skipped shard's tree
        comes from the base epoch its zero-copy decision certified."""
        out = []
        for k, p in enumerate(self.parts_by_shard):
            if p is not None:
                out.append(p.to_tree())
            else:
                out.append(self._skipped_bases[k].to_tree())
        return out


class ShardedSnapshotCoordinator:
    """N shard snapshotters + fork barrier + shared persist pipeline.

    ``providers`` are the per-shard state providers (one ``PyTreeProvider``
    per shard); every shard gets its own snapshotter built from the same
    ``mode``/``**snapshotter_kw``. ``persist_workers`` sizes the shared
    pipeline (default: one worker per shard, min 2).

    ``layout`` (a :class:`ShardLayout` whose per-shard block counts match
    the providers' leaf counts, one leaf per block) enables online
    resharding via :meth:`set_layout`; without it the partition is static,
    as in PR 2. ``policy`` (a :class:`BgsavePolicy`) makes every
    :meth:`bgsave` decide full-vs-delta-vs-skip per shard; it forces
    ``retain_images=True`` on the shard snapshotters so delta bases exist.
    """

    def __init__(
        self,
        providers: Sequence[PyTreeProvider],
        mode: str = "asyncfork",
        persist_workers: Optional[int] = None,
        persist_queue_depth: int = 64,
        pipeline: Optional[PersistPipeline] = None,
        layout: Optional[ShardLayout] = None,
        policy: Optional[BgsavePolicy] = None,
        striped_gates: bool = True,
        catalog: Optional[SnapshotCatalog] = None,
        **snapshotter_kw,
    ):
        if not providers:
            raise ValueError("need at least one shard provider")
        if layout is not None and layout.n_shards != len(providers):
            raise ValueError(
                f"layout names {layout.n_shards} shards, got "
                f"{len(providers)} providers"
            )
        self.mode = mode
        self.policy = policy
        if policy is not None:
            snapshotter_kw["retain_images"] = True
        self._snapshotter_kw = dict(snapshotter_kw)
        self.snapshotters: List[Snapshotter] = [
            make_snapshotter(mode, p, **snapshotter_kw) for p in providers
        ]
        if pipeline is None:
            workers = persist_workers if persist_workers is not None \
                else max(2, len(self.snapshotters))
            pipeline = PersistPipeline(workers=workers,
                                       queue_depth=persist_queue_depth)
        self.pipeline = pipeline
        for sn in self.snapshotters:
            sn.persist_pipeline = self.pipeline
        # one write-gate stripe per shard; striped_gates=False aliases
        # them all to a single lock (the PR-2 global gate, kept as the
        # gate_contention benchmark's baseline arm)
        self.gates = GateSet(len(self.snapshotters), striped=striped_gates)
        self.layout = layout
        # epochs stamped under layouts that have since been replaced:
        # [(frozen layout, {old_shard_index: snapshotter})] — only the
        # shards whose interval changed; unchanged shards carry their
        # snapshotter (and its active epochs) into the new indexing
        self._retired: List[Tuple[ShardLayout, Dict[int, Snapshotter]]] = []
        # writes since each shard's last T0 stamp (slot k mutates only
        # under stripe k; the barrier reads/resets under all stripes, so
        # ==0 at a barrier still proves byte-identity — the policy's
        # "skip" precondition, DESIGN.md §9), plus the DISTINCT blocks
        # those writes touched. Only maintained under a policy — the
        # no-policy hot path pays nothing, and bgsave degrades explicit
        # "skip" modes accordingly.
        self._counters = ShardWriteCounters(len(self.snapshotters))
        # last persisted (directory, epoch handle) per shard: the dir a
        # policy delta/skip may reference from a composite manifest, PLUS
        # the handle it holds — a sink-less bgsave advances the retained
        # base past the directory, and chaining against the stale dir
        # would restore stale bytes, so consumers require the recorded
        # handle to still BE the shard's retained base
        self._last_dirs: List[Optional[Tuple[str, SnapshotHandle]]] = \
            [None] * len(self.snapshotters)
        self._snaps: List[CoordinatedSnapshot] = []
        # every committed barrier registers as an epoch: pin one with
        # catalog.pin(epoch_id) to serve GetAt reads / fork branches
        self.catalog = catalog if catalog is not None else SnapshotCatalog()

    @property
    def n_shards(self) -> int:
        return len(self.snapshotters)

    @property
    def write_gate(self):
        """The ALL-gate barrier as a context manager — barrier-class
        callers (``bgsave``, layout swaps, restores, duty retunes) and
        legacy single-gate callers use ``with coord.write_gate:`` exactly
        as before PR 5; it now takes every stripe in index order. Writers
        on the hot path should hold only their shard's stripe instead
        (:attr:`gates`; ``ShardedKVStore.set`` does)."""
        return self.gates.all()

    # -- engine-facing ---------------------------------------------------
    def before_write(self, shard_id: int, leaf_id: int, rows=None) -> float:
        """Proactive synchronization for one shard's leaf. The caller must
        hold shard ``shard_id``'s gate stripe across this call AND the
        donated update it guards (``ShardedKVStore.set(gate=...)`` holds
        it across the whole routed batch); the stripes are reentrant so a
        caller holding the full barrier qualifies too.

        ``shard_id``/``leaf_id`` are indices under the CURRENT layout;
        epochs stamped under a retired layout are synchronized through the
        global block id (one leaf == one layout block)."""
        if self.policy is not None:
            self._counters.note(
                shard_id,
                leaf_id if self.layout is None
                else self.layout.block_start(shard_id) + leaf_id,
            )
        total = self.snapshotters[shard_id].before_write(leaf_id, rows)
        if self._retired:
            total += self._sync_retired(shard_id, leaf_id, rows)
        return total

    def note_gate_wait(self, shard_id: int, wait_s: float) -> None:
        """Attribute one write's gate-acquisition wait to the shard's
        in-flight epochs (caller just acquired — and still holds — stripe
        ``shard_id``). Makes the striped-gate p99 claim observable from
        the engine report: contention shows up as ``gate_wait_us`` in the
        same per-shard summaries the copy stalls land in."""
        if wait_s > 0.0:
            self.snapshotters[shard_id].note_gate_wait(wait_s)

    def note_read_event(self, shard_id: int, retries: int,
                        shared_wait_s: float) -> None:
        """Attribute one read's seqlock churn (fast-path retries + shared
        stripe waits) to the shard's in-flight epochs. ``shard_id`` is the
        FIRST shard the retrying read touched under whatever view it last
        routed with — a reshard may have shrunk the layout since, so the
        index clamps rather than raising (the charge is an attribution,
        not an invariant)."""
        if retries or shared_wait_s > 0.0:
            k = min(max(0, shard_id), len(self.snapshotters) - 1)
            self.snapshotters[k].note_read_event(retries, shared_wait_s)

    def _sync_retired(self, shard_id: int, leaf_id: int, rows) -> float:
        # Lock-free under striped gates: writers on different stripes may
        # run this concurrently. Appends happen only under ALL stripes
        # (set_layout), iteration binds the list object once, and
        # active() is monotone (an epoch never un-finishes), so the worst
        # a racing filter can do is briefly resurrect an already-drained
        # group — whose next check drops it again. The per-block data
        # movement below is the block table's own thread-safe machinery.
        g = self.layout.block_start(shard_id) + leaf_id
        total = 0.0
        live: List[Tuple[ShardLayout, Dict[int, Snapshotter]]] = []
        for old_layout, snappers in self._retired:
            if not any(sn.active() for sn in snappers.values()):
                continue  # every epoch of this group finished — drop it
            live.append((old_layout, snappers))
            k_old = old_layout.shard_of_block(g)
            sn = snappers.get(k_old)
            if sn is not None:
                total += sn.before_write(g - old_layout.block_start(k_old), rows)
        self._retired = live
        return total

    # -- online resharding ------------------------------------------------
    def set_layout(
        self, providers: Sequence[PyTreeProvider], layout: ShardLayout
    ) -> None:
        """Swap in a resharded provider set under the write gate.

        Shards whose block interval is unchanged keep their snapshotter
        (active epochs, retained delta base, policy state move with it);
        changed shards get fresh snapshotters, and their old ones — if they
        still carry in-flight epochs — retire with the frozen old layout so
        :meth:`before_write` keeps synchronizing them until they drain.
        The gate serializes this swap against the fork barrier: no layout
        change can land between two shards' T0 stamps (DESIGN.md §8).
        """
        if self.layout is None:
            raise ValueError(
                "coordinator was built without a ShardLayout; online "
                "resharding needs the block-range layout"
            )
        if layout.n_shards != len(providers):
            raise ValueError(
                f"layout names {layout.n_shards} shards, got "
                f"{len(providers)} providers"
            )
        with self.write_gate:
            old_layout, old_sn = self.layout, self.snapshotters
            unchanged = layout.unchanged_shards(old_layout)
            # provider identity must match for a snapshotter to carry over
            unchanged = {
                k: p for k, p in unchanged.items()
                if old_sn[p].provider is providers[k]
            }
            moved = set(unchanged.values())
            new_sn: List[Snapshotter] = []
            for k in range(layout.n_shards):
                if k in unchanged:
                    new_sn.append(old_sn[unchanged[k]])
                else:
                    sn = make_snapshotter(
                        self.mode, providers[k], **self._snapshotter_kw
                    )
                    sn.persist_pipeline = self.pipeline
                    new_sn.append(sn)
            retired = {
                p: old_sn[p] for p in range(len(old_sn))
                if p not in moved and old_sn[p].active()
            }
            if retired:
                self._retired.append((old_layout, retired))
            self._retired = [
                (L, d) for (L, d) in self._retired
                if any(sn.active() for sn in d.values())
            ]
            parents = layout.parents(old_layout)
            self._counters.remap(parents, layout.bounds)
            self._last_dirs = [
                self._last_dirs[unchanged[k]] if k in unchanged else None
                for k in range(layout.n_shards)
            ]
            if self.policy is not None:
                self.policy.remap(parents, unchanged)
            self.snapshotters = new_sn
            self.layout = layout
            # the stripe set follows the layout: unchanged shards keep
            # their gate object, changed shards get fresh stripes created
            # already-held so no writer slips in before this barrier exits
            self.gates.resize(layout.n_shards, carry=unchanged)

    # -- policy ------------------------------------------------------------
    def _usable_base(self, sn: Snapshotter) -> Optional[SnapshotHandle]:
        base = sn.retained_base()
        if base is None or base.aborted:
            return None
        return base

    def set_copier_duty(self, duty: float) -> None:
        """Re-tune the per-shard copier duty cycle for FUTURE epochs on
        every current snapshotter (and for snapshotters future reshards
        create). The engine's 1/sqrt(N) aggregate-steal budget depends on
        the live shard count, which online splits/merges change."""
        with self.write_gate:
            self._snapshotter_kw["copier_duty"] = float(duty)
            for sn in self.snapshotters:
                sn.copier_duty = float(duty)

    def has_active_epochs(self) -> bool:
        """Any in-flight epoch on any shard, current layout or retired."""
        if any(sn.active() for sn in self.snapshotters):
            return True
        return any(
            sn.active() for _, d in self._retired for sn in d.values()
        )

    def _recorded_dir(self, k: int) -> Optional[str]:
        """The shard's last persisted directory, ONLY while it still holds
        the shard's retained base — a sink-less epoch in between advances
        the base past the directory, and a delta/skip referencing the
        stale dir would restore stale bytes."""
        rec = self._last_dirs[k]
        if rec is None:
            return None
        path, handle = rec
        return path if handle is self._usable_base(self.snapshotters[k]) else None

    def _decide_modes(self, need_dirs: bool) -> List[str]:
        """One policy decision per shard (caller holds the write gate).

        ``need_dirs``: deltas/skips will be referenced from a composite
        manifest, so they additionally need a recorded parent directory
        that still matches the retained base epoch.
        """
        modes: List[str] = []
        for k, sn in enumerate(self.snapshotters):
            base = self._usable_base(sn)
            has_dir = self._recorded_dir(k) is not None
            view = ShardEpochView(
                writes_since_epoch=self._counters.writes[k],
                has_base=base is not None and not (need_dirs and not has_dir),
                base_persisted=base is not None and base.persist_done.is_set(),
                can_skip=not need_dirs or has_dir,
            )
            modes.append(self.policy.decide(k, view))
        return modes

    def invalidate_bases(self) -> None:
        """Drop every retained delta base and recorded directory. Call
        after replacing shard state OUT-OF-BAND (``ShardedKVStore.load``
        does not route through ``before_write``, so the zero-write skip
        proof and any dirty diff against the old images would be wrong):
        each shard's next epoch is a full snapshot. ``KVEngine.load``
        packages the restore + this call under the write gate."""
        with self.write_gate:
            for k, sn in enumerate(self.snapshotters):
                sn.drop_retained()
                self._last_dirs[k] = None
                self._counters.reset(k)

    def _observe(self, modes: Sequence[str],
                 parts: Sequence[Optional[SnapshotHandle]],
                 touched_at_barrier: Sequence[int]) -> None:
        if self.policy is None:
            return
        for k, (mode, part) in enumerate(zip(modes, parts)):
            dirty = None
            if part is not None and part.metrics.total_blocks:
                m = part.metrics
                if mode == "delta":
                    # the real scan count (PR-1 dirty kernel, via BlockTable)
                    dirty = (m.total_blocks - m.inherited_blocks) / m.total_blocks
                else:
                    # full epochs run no scan; the gate-serialized count of
                    # DISTINCT touched blocks upper-bounds the dirty set
                    # (a raw write counter would pin a write-skewed shard's
                    # EMA at 1.0), so the EMA still converges and deltas
                    # become reachable
                    dirty = min(1.0, touched_at_barrier[k] / m.total_blocks)
            self.policy.observe(k, mode, dirty)

    # -- the barrier -----------------------------------------------------
    def bgsave(
        self,
        sinks: Optional[Sequence[Optional[Sink]]] = None,
        sink_factory=None,
        incremental: bool = False,
        bases: Optional[Sequence[Optional[SnapshotHandle]]] = None,
        modes: Optional[Sequence[str]] = None,
    ) -> CoordinatedSnapshot:
        """Consistent cross-shard BGSAVE.

        Under the ALL-gate barrier (every stripe, taken in index order):
        phase 1 prepares every shard (stamp T0 + write-protect — after
        this, any write anywhere proactively syncs), then phase 2 commits
        every shard (copiers + persist jobs start). No write can commit
        ON ANY SHARD between that shard's T0 stamp and barrier release
        (its stripe is held the whole time), so the union of shard images
        is the state at one instant (DESIGN.md §9).

        Mode precedence: explicit ``modes`` (one of "full"/"delta"/"skip"
        per shard) > ``bases`` (shard k is delta iff ``bases[k]``, used by
        checkpoint delta chains) > the coordinator's ``policy`` > the
        global ``incremental`` flag. A skipped shard does not fork at all:
        its previous epoch's image is certified byte-identical by the
        zero-writes counter, so the epoch is zero-copy.
        """
        if sinks is not None and len(sinks) != self.n_shards:
            raise ValueError(f"need {self.n_shards} sinks, got {len(sinks)}")
        if bases is not None and len(bases) != self.n_shards:
            raise ValueError(f"need {self.n_shards} bases, got {len(bases)}")
        if modes is not None and len(modes) != self.n_shards:
            raise ValueError(f"need {self.n_shards} modes, got {len(modes)}")
        parts: List[Optional[SnapshotHandle]] = []
        skipped_bases: Dict[int, SnapshotHandle] = {}
        with self.write_gate:
            # the frozen layout this barrier stamps against — read under
            # the gate: a reshard racing the gate release must not attach
            # its successor layout to an epoch taken under the predecessor
            layout_at_barrier = self.layout
            touched_at_barrier = [
                self._counters.touched_count(k) for k in range(self.n_shards)
            ]
            decided_by_policy = False
            if modes is None:
                if bases is not None:
                    modes = ["delta" if b is not None else "full" for b in bases]
                elif self.policy is not None:
                    modes = self._decide_modes(need_dirs=False)
                    decided_by_policy = True
                else:
                    modes = ["delta" if incremental else "full"] * self.n_shards
            modes = list(modes)
            try:
                for k, sn in enumerate(self.snapshotters):
                    # A DURABLE caller sink (anything but a pacing
                    # NullSink) must receive a restorable record: a skip
                    # would write nothing at all, and a policy delta would
                    # write a delta manifest with NO parent reference —
                    # both restore wrong. Degrade to full. (bgsave_to_dir
                    # passes modes explicitly with parent-chained
                    # FileSinks, so it is exempt; explicit bases likewise
                    # leave the parent naming to the caller.)
                    durable_sink = sink_factory is not None or (
                        sinks is not None and sinks[k] is not None
                        and not isinstance(sinks[k], NullSink)
                    )
                    if decided_by_policy and durable_sink and \
                            modes[k] == "delta":
                        modes[k] = "full"
                    if modes[k] == "skip":
                        base = self._usable_base(sn)
                        # Degrade rather than certify what we can't honor:
                        # no policy means no write counters backing the
                        # zero-copy proof (bgsave_to_dir skips carry a
                        # manifest entry pointing at the previous epoch
                        # instead of a sink).
                        if base is None or self.policy is None or \
                                self._counters.writes[k] != 0 or durable_sink:
                            modes[k] = ("full" if durable_sink or base is None
                                        else "delta")
                        else:
                            skipped_bases[k] = base
                            parts.append(None)
                            continue
                    parts.append(sn.fork_prepare(
                        incremental=modes[k] == "delta",
                        base=None if bases is None else bases[k],
                    ))
                    self._counters.reset(k)
                for k, sn in enumerate(self.snapshotters):
                    if parts[k] is None:
                        continue
                    sink = sinks[k] if sinks is not None else (
                        sink_factory(k) if sink_factory is not None else None
                    )
                    sn.fork_commit(parts[k], sink)
            except BaseException as exc:
                # a mid-barrier failure must not leave prepared-but-never-
                # committed epochs behind: their events would never fire
                # (wait_all stalls to timeout) and they would pin T0 refs
                # in their snapshotter's active list forever
                for p in parts:
                    if p is not None and not p.persist_done.is_set():
                        p.abort(exc)
                raise
            # still under the gate: a concurrent reshard's policy.remap
            # must not swap shard indexing mid-observation
            self._observe(modes, parts, touched_at_barrier)
        snap = CoordinatedSnapshot(
            parts, layout=layout_at_barrier, modes=modes,
            skipped_bases=skipped_bases,
        )
        self._snaps.append(snap)
        self.catalog.register_epoch(snap)
        return snap

    def bgsave_to_dir(
        self,
        directory: str,
        parent: Optional[str] = None,
        incremental: bool = False,
        bases: Optional[Sequence[Optional[SnapshotHandle]]] = None,
        prefix: str = "shard{k}/",
        layout_record: Optional[Dict] = None,
        durable: bool = True,
        compress: Optional[str] = None,
    ) -> CoordinatedSnapshot:
        """BGSAVE into ``<directory>/shard_<k>/`` FileSinks plus a top-level
        composite manifest (with the layout record and per-shard modes)
        that ``read_file_snapshot`` resolves. ``parent`` (a sibling
        snapshot directory name) chains incremental epochs globally:
        shard k inherits from ``../<parent>/shard_<k>``. With a policy,
        each shard chains against its OWN last persisted directory
        instead, and skipped shards' manifest entries point straight at
        that directory (a zero-copy epoch).

        The composite manifest is written by a deferred COMMIT thread
        only after every shard's sink has durably closed — its atomic
        rename is the epoch's single commit point (DESIGN.md §12), so a
        crash at any earlier instant leaves a recognizably torn epoch and
        never a half-certified one. ``wait_persisted`` on the returned
        snapshot covers the commit. ``durable=False`` keeps the same
        commit ordering but skips the fsync protocol (bench baseline).
        ``compress="zlib"`` writes every shard's runs as zlib frames
        (DESIGN.md §13); delta shards may compress over an uncompressed
        parent and vice versa — each leaf's manifest records its own
        encoding, so mixed chains restore transparently.
        A persist failure on ANY shard unwinds the whole epoch: sibling
        sinks aborted, the partial epoch dir removed, nothing registered
        in the catalog."""
        directory = os.path.abspath(directory)
        with self.write_gate:
            if bases is not None:
                modes: Optional[List[str]] = [
                    "delta" if b is not None else "full" for b in bases
                ]
            elif self.policy is not None:
                # every delta/skip here gets referenced from the composite
                # manifest, so each needs a RECORDED previous directory —
                # even when a legacy ``parent`` name is passed (a prior
                # sink-less bgsave may have advanced the retained base
                # past whatever ``parent`` points at). Shards without one
                # degrade to full inside _decide_modes.
                modes = self._decide_modes(need_dirs=True)
            else:
                modes = ["delta" if incremental else "full"] * self.n_shards
            sinks: List[Optional[Sink]] = []
            entries: List[Dict] = []
            for k in range(self.n_shards):
                entry = {"dir": f"shard_{k}", "prefix": prefix.format(k=k),
                         "mode": modes[k]}
                if modes[k] == "skip":
                    # re-checked inside bgsave; if it degrades there we
                    # patch the entry afterwards
                    sinks.append(None)
                elif modes[k] == "delta":
                    if self.policy is not None and bases is None:
                        # policy deltas diff against the RETAINED base; the
                        # recorded dir is usable only while it still holds
                        # that base (a caller-passed ``parent`` name, or a
                        # dir a sink-less epoch has advanced past, is stale)
                        rec = self._recorded_dir(k)
                        parent_k = (os.path.relpath(rec, directory)
                                    if rec is not None else None)
                    elif parent is not None:
                        parent_k = os.path.join("..", parent, f"shard_{k}")
                    else:
                        parent_k = None
                    if parent_k is None:  # no recorded base dir: go full
                        modes[k] = "full"
                        entry["mode"] = "full"
                    sinks.append(FileSink(os.path.join(directory, f"shard_{k}"),
                                          parent=parent_k, durable=durable,
                                          compress=compress))
                else:
                    sinks.append(FileSink(os.path.join(directory, f"shard_{k}"),
                                          durable=durable, compress=compress))
                entries.append(entry)
            try:
                snap = self.bgsave(sinks=sinks, bases=bases, modes=modes)
            except BaseException:
                # the barrier never produced an epoch: remove whatever
                # sink scaffolding already hit the disk
                for s in sinks:
                    if s is not None:
                        try:
                            s.abort()
                        except Exception:
                            pass
                shutil.rmtree(directory, ignore_errors=True)
                raise
            for k, mode in enumerate(snap.modes):
                if mode == "skip":
                    entries[k]["mode"] = "skip"
                    entries[k]["dir"] = os.path.relpath(
                        self._recorded_dir(k), directory
                    )
                elif entries[k]["mode"] == "skip":  # degraded inside bgsave
                    raise RuntimeError(
                        "shard mode degraded after sink creation"
                    )  # pragma: no cover - guarded by gate serialization
            # explicit reference records (the catalog's refcount inputs,
            # written into the manifest so chain growth is observable):
            # each entry carries its delta depth, the dirs it depends on
            # beyond its own, and whether it aliases a previous epoch
            shard_dirs: List[str] = []
            parent_dirs: List[Optional[str]] = []
            depths: List[int] = []
            for k, mode in enumerate(snap.modes):
                sdir = os.path.normpath(
                    os.path.join(directory, entries[k]["dir"])
                )
                parent_abs: Optional[str] = None
                if mode == "skip":
                    # the aliased dir's own chain depth — the alias holds
                    # a ref on the dir itself, not on its parent
                    depth = self.catalog.dir_depth(sdir)
                    entries[k]["aliased"] = True
                    entries[k]["refs"] = [entries[k]["dir"]]
                elif mode == "delta":
                    parent_rel = sinks[k].parent
                    if parent_rel is not None:
                        parent_abs = os.path.normpath(
                            os.path.join(directory, parent_rel)
                        )
                        entries[k]["refs"] = [parent_rel]
                        depth = self.catalog.dir_depth(parent_abs) + 1
                    else:  # pragma: no cover - delta without parent degrades
                        depth = 0
                else:
                    depth = 0
                entries[k]["chain_depth"] = depth
                shard_dirs.append(sdir)
                parent_dirs.append(parent_abs)
                depths.append(depth)
            if layout_record is None and self.layout is not None:
                layout_record = self.layout.to_record()
        # Deferred commit, OUTSIDE the gate (writers never stall on sink
        # fsyncs or a json.dump): the composite manifest may only appear
        # once every shard is durably on disk, so a commit thread waits
        # the parts and then performs the single atomic rename. Until it
        # fires, the epoch is recognizably torn (no manifest.json) and
        # recovery will quarantine it. A failure anywhere — shard abort,
        # spent retry budget, manifest IO — unwinds the WHOLE epoch.
        snap._commit_pending = True
        aliased = sum(1 for m in snap.modes if m == "skip")
        # captured by the commit thread: a reshard REPLACES self._last_dirs
        # with a fresh list, so a late commit writes into the abandoned one
        # (harmless) instead of corrupting the new partition's slots
        last_dirs = self._last_dirs

        def _commit() -> None:
            try:
                for p in snap.parts:
                    if not p.wait_persisted(600.0):
                        raise SnapshotError(
                            f"shard persist timed out before composite "
                            f"commit of {directory!r}"
                        )
                write_composite_manifest(directory, entries,
                                         layout=layout_record,
                                         durable=durable)
                snap.directory = directory
                snap.chain_depths = depths
                snap.aliased_dirs = aliased
                self.catalog.attach_dirs(snap, directory, shard_dirs,
                                         parent_dirs, modes=snap.modes)
                # only a COMMITTED dir may become a future delta parent or
                # skip alias (item assignment is atomic; a barrier racing
                # this sees the stale record and safely degrades to full)
                for k, mode in enumerate(snap.modes):
                    if mode != "skip":
                        last_dirs[k] = (
                            os.path.join(directory, f"shard_{k}"),
                            snap.parts_by_shard[k],
                        )
            except BaseException as exc:
                snap.commit_error = exc
                self._unwind_composite(snap, directory, sinks)
            finally:
                snap.commit_done.set()

        threading.Thread(target=_commit, daemon=True,
                         name="composite-commit").start()
        return snap

    def _unwind_composite(self, snap: CoordinatedSnapshot, directory: str,
                          sinks: Sequence[Optional[Sink]]) -> None:
        """Roll a failed durable epoch ALL the way back: abort every shard
        sink (also removing sibling shards' completed dirs), drop the
        never-committed catalog record, and delete the partial epoch
        directory — disk and refcounts end up as if the barrier never
        fired. Skip aliases point OUTSIDE the epoch dir (at a previous
        epoch's shard dir) and are deliberately untouched."""
        for s in sinks:
            if s is not None:
                try:
                    s.abort()
                except Exception:
                    pass
        if snap.epoch_id is not None:
            try:
                self.catalog.drop_epoch(snap.epoch_id)
            except Exception:
                pass
        shutil.rmtree(directory, ignore_errors=True)

    # -- lifecycle -------------------------------------------------------
    def active(self) -> List[CoordinatedSnapshot]:
        self._snaps = [
            s for s in self._snaps
            if not all(p.copy_done.is_set() and p.persist_done.is_set()
                       for p in s.parts)
        ]
        return list(self._snaps)

    def wait_all(self, timeout: float = 600.0) -> None:
        """Block until every registered epoch is durable; surfaces the
        first shard abort as :class:`SnapshotError` (workers may still be
        in flight on other shards — their jobs drain as no-ops)."""
        for snap in list(self._snaps):
            snap.wait_persisted(timeout)
