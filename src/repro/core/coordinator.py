"""Sharded snapshot coordinator — cross-shard BGSAVE with a fork barrier.

Production Redis clusters shard the keyspace and BGSAVE shards
independently; the paper's design (one child per VMA, one RDB writer)
snapshots a single instance. This module is the distributed analogue for
our substrate: the state is partitioned into N shards, each owning its own
``BlockTable`` + ``Snapshotter`` + staging backend, and the coordinator

  (a) takes a **consistent cross-shard BGSAVE** via a fork barrier: every
      shard's ``fork_prepare`` (write-protect + T0 stamp) completes while
      the write gate is held, before ANY shard's ``fork_commit`` launches
      copiers — so the union of shard images is a single point-in-time cut
      (consistency argument in DESIGN.md §6);
  (b) persists all shard epochs through one shared
      :class:`~repro.core.persist.PersistPipeline` — a bounded work queue
      feeding a pool of persister workers that write blocks out of order
      into each shard's ``FileSink`` (pwrite layout), so N shards drain at
      pool parallelism instead of one disk stream per instance.

Writers cooperate through :attr:`write_gate`: the engine holds the gate
across ``before_write`` → donated-update-commit for each touched block
(``KVStore.set(gate=...)`` does this), and ``bgsave`` holds it across the
barrier. A single-threaded engine (the paper's Redis model) never contends.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.persist import PersistPipeline
from repro.core.provider import PyTreeProvider
from repro.core.sinks import FileSink, Sink, write_composite_manifest
from repro.core.snapshot import SnapshotHandle, Snapshotter, make_snapshotter


class AggregateMetrics:
    """Read-only roll-up of per-shard :class:`SnapshotMetrics`.

    The parent-visible quantities sum (fork stalls and interruptions all
    land on the serving thread); the window quantities take the max (the
    barrier's window closes when the slowest shard's does).
    """

    def __init__(self, parts: Sequence[SnapshotHandle]):
        self._parts = list(parts)

    @property
    def fork_s(self) -> float:
        """Serving-thread stall of the whole barrier: first prepare entry
        to last commit exit. Per-part fork_s intervals overlap (prepares
        and commits run sequentially on one thread), so summing them would
        overstate the stall roughly in proportion to shard count."""
        starts = [p.fork_start for p in self._parts]
        ends = [p.fork_start + p.metrics.fork_s for p in self._parts]
        return max(ends) - min(starts)

    @property
    def _t0(self) -> float:
        return min(p.t0 for p in self._parts)

    @property
    def copy_window_s(self) -> float:
        """Barrier start to the slowest shard's copy-window close."""
        return max(
            ((p.t0 - self._t0) + p.metrics.copy_window_s for p in self._parts),
            default=0.0,
        )

    @property
    def persist_s(self) -> float:
        """Barrier start to the slowest shard's durability."""
        return max(
            ((p.t0 - self._t0) + p.metrics.persist_s for p in self._parts),
            default=0.0,
        )

    @property
    def sink_write_s(self) -> float:
        """Slowest shard's pure sink-IO interval (shards drain the shared
        pipeline concurrently, so the max bounds the IO wall-clock)."""
        return max((p.metrics.sink_write_s for p in self._parts), default=0.0)

    @property
    def copied_blocks_child(self) -> int:
        return sum(p.metrics.copied_blocks_child for p in self._parts)

    @property
    def copied_blocks_parent(self) -> int:
        return sum(p.metrics.copied_blocks_parent for p in self._parts)

    @property
    def inherited_blocks(self) -> int:
        return sum(p.metrics.inherited_blocks for p in self._parts)

    @property
    def n_interruptions(self) -> int:
        return sum(p.metrics.n_interruptions for p in self._parts)

    @property
    def out_of_service_s(self) -> float:
        """Fig 20 analogue: one barrier stall + every parent-side copy
        stall (per-part out_of_service_s would re-count overlapping fork
        intervals, shard count times)."""
        return self.fork_s + sum(
            d for p in self._parts for _, d, _ in p.metrics.interruptions
        )

    def histogram_us(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for p in self._parts:
            for k, v in p.metrics.histogram_us().items():
                out[k] = out.get(k, 0) + v
        return out

    def summary(self) -> Dict[str, float]:
        return {
            "fork_ms": self.fork_s * 1e3,
            "copy_window_ms": self.copy_window_s * 1e3,
            "persist_ms": self.persist_s * 1e3,
            "sink_write_ms": self.sink_write_s * 1e3,
            "interruptions": float(self.n_interruptions),
            "out_of_service_ms": self.out_of_service_s * 1e3,
            "parent_copied_blocks": float(self.copied_blocks_parent),
            "child_copied_blocks": float(self.copied_blocks_child),
            "inherited_blocks": float(self.inherited_blocks),
            "shards": float(len(self._parts)),
            "per_shard": [p.metrics.summary() for p in self._parts],
        }


class CoordinatedSnapshot:
    """The union of per-shard epochs taken at one fork barrier."""

    def __init__(self, parts: List[SnapshotHandle], directory: Optional[str] = None):
        self.parts = parts
        self.directory = directory
        self.t0 = min(p.t0 for p in parts)
        self.fork_start = min(p.fork_start for p in parts)

    @property
    def metrics(self) -> AggregateMetrics:
        return AggregateMetrics(self.parts)

    @property
    def aborted(self) -> bool:
        return any(p.aborted for p in self.parts)

    @property
    def ok(self) -> bool:
        return not self.aborted

    def wait(self, timeout: Optional[float] = None) -> bool:
        ok = True
        for p in self.parts:
            ok = p.wait(timeout) and ok
        return ok

    def wait_persisted(self, timeout: Optional[float] = None) -> bool:
        ok = True
        for p in self.parts:
            ok = p.wait_persisted(timeout) and ok
        return ok

    def to_trees(self) -> List:
        """Per-shard T0 pytrees, in shard order."""
        return [p.to_tree() for p in self.parts]


class ShardedSnapshotCoordinator:
    """N shard snapshotters + fork barrier + shared persist pipeline.

    ``providers`` are the per-shard state providers (one ``PyTreeProvider``
    per shard); every shard gets its own snapshotter built from the same
    ``mode``/``**snapshotter_kw``. ``persist_workers`` sizes the shared
    pipeline (default: one worker per shard, min 2).
    """

    def __init__(
        self,
        providers: Sequence[PyTreeProvider],
        mode: str = "asyncfork",
        persist_workers: Optional[int] = None,
        persist_queue_depth: int = 64,
        pipeline: Optional[PersistPipeline] = None,
        **snapshotter_kw,
    ):
        if not providers:
            raise ValueError("need at least one shard provider")
        self.mode = mode
        self.snapshotters: List[Snapshotter] = [
            make_snapshotter(mode, p, **snapshotter_kw) for p in providers
        ]
        if pipeline is None:
            workers = persist_workers if persist_workers is not None \
                else max(2, len(self.snapshotters))
            pipeline = PersistPipeline(workers=workers,
                                       queue_depth=persist_queue_depth)
        self.pipeline = pipeline
        for sn in self.snapshotters:
            sn.persist_pipeline = self.pipeline
        self.write_gate = threading.RLock()
        self._snaps: List[CoordinatedSnapshot] = []

    @property
    def n_shards(self) -> int:
        return len(self.snapshotters)

    # -- engine-facing ---------------------------------------------------
    def before_write(self, shard_id: int, leaf_id: int, rows=None) -> float:
        """Proactive synchronization for one shard's leaf. The caller must
        hold :attr:`write_gate` across this call AND the donated update it
        guards (``KVStore.set(gate=...)`` does); the gate is reentrant so
        ``bgsave`` can run under it too."""
        return self.snapshotters[shard_id].before_write(leaf_id, rows)

    # -- the barrier -----------------------------------------------------
    def bgsave(
        self,
        sinks: Optional[Sequence[Optional[Sink]]] = None,
        sink_factory=None,
        incremental: bool = False,
        bases: Optional[Sequence[Optional[SnapshotHandle]]] = None,
    ) -> CoordinatedSnapshot:
        """Consistent cross-shard BGSAVE.

        Under the write gate: phase 1 prepares every shard (stamp T0 +
        write-protect — after this, any write anywhere proactively syncs),
        then phase 2 commits every shard (copiers + persist jobs start).
        No write can commit between two shards' T0 stamps, so the union of
        shard images is the state at one instant.

        ``bases`` overrides the incremental diff base per shard (used by
        checkpoint delta chains): shard k is incremental iff ``bases[k]``
        is not None. Without ``bases``, ``incremental`` applies globally
        against each snapshotter's retained image.
        """
        if sinks is not None and len(sinks) != self.n_shards:
            raise ValueError(f"need {self.n_shards} sinks, got {len(sinks)}")
        if bases is not None and len(bases) != self.n_shards:
            raise ValueError(f"need {self.n_shards} bases, got {len(bases)}")
        parts: List[SnapshotHandle] = []
        with self.write_gate:
            try:
                for k, sn in enumerate(self.snapshotters):
                    parts.append(sn.fork_prepare(
                        incremental=incremental if bases is None
                        else bases[k] is not None,
                        base=None if bases is None else bases[k],
                    ))
                for k, sn in enumerate(self.snapshotters):
                    sink = sinks[k] if sinks is not None else (
                        sink_factory(k) if sink_factory is not None else None
                    )
                    sn.fork_commit(parts[k], sink)
            except BaseException as exc:
                # a mid-barrier failure must not leave prepared-but-never-
                # committed epochs behind: their events would never fire
                # (wait_all stalls to timeout) and they would pin T0 refs
                # in their snapshotter's active list forever
                for p in parts:
                    if not p.persist_done.is_set():
                        p.abort(exc)
                raise
        snap = CoordinatedSnapshot(parts)
        self._snaps.append(snap)
        return snap

    def bgsave_to_dir(
        self,
        directory: str,
        parent: Optional[str] = None,
        incremental: bool = False,
        bases: Optional[Sequence[Optional[SnapshotHandle]]] = None,
        prefix: str = "shard{k}/",
    ) -> CoordinatedSnapshot:
        """BGSAVE into ``<directory>/shard_<k>/`` FileSinks plus a top-level
        composite manifest that ``read_file_snapshot`` resolves. ``parent``
        (a sibling snapshot directory name) chains incremental epochs:
        shard k inherits from ``../<parent>/shard_<k>``."""
        sinks = [
            FileSink(
                os.path.join(directory, f"shard_{k}"),
                parent=None if parent is None
                else os.path.join("..", parent, f"shard_{k}"),
            )
            for k in range(self.n_shards)
        ]
        snap = self.bgsave(sinks=sinks, incremental=incremental, bases=bases)
        write_composite_manifest(
            directory,
            [{"dir": f"shard_{k}", "prefix": prefix.format(k=k)}
             for k in range(self.n_shards)],
        )
        snap.directory = directory
        return snap

    # -- lifecycle -------------------------------------------------------
    def active(self) -> List[CoordinatedSnapshot]:
        self._snaps = [
            s for s in self._snaps
            if not all(p.copy_done.is_set() and p.persist_done.is_set()
                       for p in s.parts)
        ]
        return list(self._snaps)

    def wait_all(self, timeout: float = 600.0) -> None:
        """Block until every registered epoch is durable; surfaces the
        first shard abort as :class:`SnapshotError` (workers may still be
        in flight on other shards — their jobs drain as no-ops)."""
        for snap in list(self._snaps):
            snap.wait_persisted(timeout)
