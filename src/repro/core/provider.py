"""State providers: how the snapshot core reads the engine's live buffers.

The engine (parent process) owns a pytree of ``jax.Array`` leaves that it
updates with buffer donation — donation destroys the old buffer, which is
exactly the overwrite hazard the paper's write-protection guards against.
A provider reads the *current* content of a block; the snapshot protocol
guarantees that content equals the fork-time (T0) content for every block
that is still UNCOPIED, because the parent proactively copies blocks
before its first donated write to them.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from repro.core.blocks import BlockRef
from repro.utils.tree import flatten_with_paths


class PyTreeProvider:
    """Reads blocks out of a mutable pytree of jax/numpy arrays.

    Concurrency contract (the ``trylock_page()`` analogue at VMA scope):
    every leaf has its own lock; block reads slice-and-copy *under* that
    lock, and donated updates rebind + delete the old buffer under the same
    lock, so a copier thread can never observe a half-deleted buffer.

    Correctness under donation: the engine calls ``before_write`` for the
    rows a donated update will change, so every still-UNCOPIED block only
    covers rows whose values are unchanged by the update — reading them
    from the *new* buffer still yields fork-time (T0) content.
    """

    def __init__(self, tree):
        self._meta_lock = threading.Lock()
        self._leaves: List[Any] = []
        self._paths: List[str] = []
        self._leaf_locks: List[threading.RLock] = []
        self.refresh(tree)

    def refresh(self, tree) -> None:
        leaves_with_paths, treedef = flatten_with_paths(tree)
        with self._meta_lock:
            self._paths = [p for p, _ in leaves_with_paths]
            self._leaves = [l for _, l in leaves_with_paths]
            self._leaf_locks = [threading.RLock() for _ in self._leaves]
            self.treedef = treedef

    def update_leaf(self, leaf_id: int, new_leaf, delete_old: bool = False) -> None:
        """Commit a (possibly donated) update. With ``delete_old`` the old
        buffer is destroyed atomically w.r.t. concurrent block reads."""
        with self._leaf_locks[leaf_id]:
            old = self._leaves[leaf_id]
            self._leaves[leaf_id] = new_leaf
            if delete_old and old is not new_leaf and hasattr(old, "delete"):
                old.delete()

    def leaf(self, leaf_id: int):
        with self._leaf_locks[leaf_id]:
            return self._leaves[leaf_id]

    def with_leaf(self, leaf_id: int, fn: Callable[[Any], Any]):
        """Run ``fn(live_leaf)`` under the leaf lock.

        Device-staging backends use this to launch + complete an on-device
        block copy while the buffer is pinned: a donated update cannot
        delete the source buffer until ``fn`` returns.
        """
        with self._leaf_locks[leaf_id]:
            return fn(self._leaves[leaf_id])

    def tree(self):
        with self._meta_lock:
            return jax.tree_util.tree_unflatten(self.treedef, list(self._leaves))

    def read_block(self, ref: BlockRef) -> np.ndarray:
        """Device->host copy of one block. The copy MUST complete under the
        leaf lock: on the CPU backend ``np.asarray(jax.Array)`` can be a
        zero-copy view, and a donated update would free the buffer under a
        view that escaped the lock."""
        with self._leaf_locks[ref.leaf_id]:
            leaf = self._leaves[ref.leaf_id]
            if not getattr(leaf, "shape", ()):  # scalar
                return np.array(leaf, copy=True)
            if ref.start == 0 and ref.stop == leaf.shape[0]:
                # whole-leaf fast path: a single export, no slice dispatch
                return np.array(leaf, copy=True)
            return np.array(leaf[ref.start : ref.stop], copy=True)

    def read_block_into(self, ref: BlockRef, out: np.ndarray) -> None:
        """Copy one block directly into ``out`` (a staging slice) — one
        memcpy, still entirely under the leaf lock."""
        with self._leaf_locks[ref.leaf_id]:
            leaf = self._leaves[ref.leaf_id]
            if not getattr(leaf, "shape", ()):
                out[...] = np.asarray(leaf)
            elif ref.start == 0 and ref.stop == leaf.shape[0]:
                np.copyto(out, np.asarray(leaf))
            else:
                np.copyto(out, np.asarray(leaf[ref.start : ref.stop]))


class FailingProvider(PyTreeProvider):
    """Test hook: injects copy failures (§4.4 "out of memory in the child").

    ``fail_on`` is a predicate over BlockRef; matching reads raise
    ``MemoryError`` exactly ``max_failures`` times.
    """

    def __init__(self, tree, fail_on: Callable[[BlockRef], bool], max_failures: int = 1):
        super().__init__(tree)
        self._fail_on = fail_on
        self._budget = max_failures
        self._fail_lock = threading.Lock()

    def _maybe_fail(self, ref: BlockRef) -> None:
        with self._fail_lock:
            should_fail = self._budget > 0 and self._fail_on(ref)
            if should_fail:
                self._budget -= 1
        if should_fail:
            raise MemoryError(f"injected copy failure at block {ref.key}")

    def read_block(self, ref: BlockRef) -> np.ndarray:
        self._maybe_fail(ref)
        return super().read_block(ref)

    def read_block_into(self, ref: BlockRef, out: np.ndarray) -> None:
        self._maybe_fail(ref)
        super().read_block_into(ref, out)
