"""Epoch replication — stream committed epoch dirs to a standby pool.

PR 8 made an epoch a self-validating durable unit: per-run crc32s in the
shard manifests, an fsync'd rename as the single commit point, and a
ref-closed manifest graph (delta parents and skip aliases recorded as
RELATIVE paths inside one pool). That unit is exactly what a standby
needs, and the delta chain IS the wire format (DESIGN.md §14):

* a **full** epoch ships every carried block once;
* a **delta** epoch ships only its own run bytes — the uncompressed data
  files are full-size *sparse* (the sink preallocates with ``truncate``
  and writes only carried offsets), so the shipper coalesces the
  manifest's ``carried`` block ids into runs and moves just those byte
  ranges, recreating the sparse holes with a ``truncate`` on the replica;
* a **compressed** leaf ships only the frames its manifest lists (which
  also drops orphaned retry frames on the floor);
* a **skip** epoch ships nothing but its composite manifest — the alias
  entry's relative path resolves against the already-shipped target dir
  because the replica pool preserves epoch-dir basenames.

Manifests are copied byte-verbatim, so the replica's ref graph is the
primary's ref graph. Shipping in epoch-id order (``catalog.
durable_epochs``) guarantees every parent/alias target is committed
replica-side before anything referencing it, and each arrival is
**deep-verified against the in-memory manifest before the manifest
rename publishes it** — the replica-side commit point is the same
tmp→fsync→rename→dir-fsync protocol as the primary's (§12), so
``SnapshotCatalog.from_dir(replica)`` is the failover story: it recovers
exactly the shipped prefix, byte-exact, and quarantines any epoch a
crash left torn.

Transient transfer faults (``replicate.read`` / ``replicate.write``
injection sites) are retried under a bounded
:class:`~repro.core.policy.RetryPolicy` with exponential backoff —
positioned reads/writes are idempotent, so replaying an attempt is safe.
``replicate.commit`` fires just before the replica commit rename and is
NOT retried (mirroring ``sink.rename``): a failure there unwinds the
whole partial epoch dir, a crash leaves it torn for recovery to
quarantine.

The replicator also serves the scrubber's repair path:
:meth:`EpochReplicator.fetch_dir` stages a deep-verified copy of a
corrupt primary shard dir out of the replica pool (quarantine → re-fetch,
``core/scrub.py``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import List, Optional, Tuple

from repro.core.faults import fire as _fire_fault
from repro.core.metrics import MaintenanceMetrics
from repro.core.policy import ReplicationPolicy, RetryPolicy
from repro.core.recovery import _load_manifest, validate_sink_dir
from repro.core.sinks import _coalesce_ids, _fsync_dir


class ReplicationError(RuntimeError):
    """A ship/fetch failed for a non-transient reason (bad source state,
    verification mismatch, or the retry budget is spent)."""


def _pread_exact(fd: int, n: int, offset: int) -> bytes:
    chunks = []
    while n > 0:
        buf = os.pread(fd, n, offset)
        if not buf:
            raise OSError(f"short read at offset {offset}")
        chunks.append(buf)
        offset += len(buf)
        n -= len(buf)
    return b"".join(chunks)


def _pwrite_exact(fd: int, data: bytes, offset: int) -> None:
    view = memoryview(data)
    while view:
        n = os.pwrite(fd, view, offset)
        offset += n
        view = view[n:]


class EpochReplicator:
    """Ships committed epoch dirs to a standby pool directory.

    ``catalog`` is optional: with one, :meth:`pending`/:meth:`lag`/
    :meth:`sync` track the primary's committed epochs and the background
    loop (:meth:`start`) drains them at ``policy.interval_s`` pace;
    without one, :meth:`ship_dir` still ships any committed epoch dir
    explicitly (the checkpoint manager's replicate-on-commit option).
    """

    def __init__(self, replica_dir: str, catalog=None,
                 retry: Optional[RetryPolicy] = None, verify: bool = True,
                 policy: Optional[ReplicationPolicy] = None,
                 metrics: Optional[MaintenanceMetrics] = None,
                 faults=None):
        self.replica_dir = os.path.abspath(replica_dir)
        self.catalog = catalog
        self.retry = retry if retry is not None else RetryPolicy()
        self.verify = verify
        self.policy = policy if policy is not None else ReplicationPolicy()
        self.metrics = metrics if metrics is not None else MaintenanceMetrics()
        self.faults = faults
        self.ship_errors = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- catalog-driven shipping ------------------------------------------
    def _replica_committed(self, epoch_dir: str) -> bool:
        dst = os.path.join(self.replica_dir, os.path.basename(epoch_dir))
        return os.path.exists(os.path.join(dst, "manifest.json"))

    def pending(self) -> List[Tuple[int, str]]:
        """Committed primary epochs not yet committed replica-side, in
        ship (epoch-id) order."""
        if self.catalog is None:
            return []
        return [
            (eid, d) for eid, d in self.catalog.durable_epochs()
            if not self._replica_committed(d)
        ]

    def lag(self) -> int:
        """Epochs committed on the primary but not on the replica."""
        return len(self.pending())

    def sync(self) -> int:
        """Drain the pending queue (bounded by ``policy.epochs_per_sync``
        when non-zero); returns how many epochs shipped. Stops at the
        first failure — a missing parent must block its dependents, or
        the replica would accept orphans recovery then drops."""
        shipped = 0
        for _, d in self.pending():
            if self.policy.epochs_per_sync and \
                    shipped >= self.policy.epochs_per_sync:
                break
            try:
                if self.ship_dir(d):
                    shipped += 1
            except Exception:
                self.ship_errors += 1
                break
        return shipped

    def start(self) -> None:
        """Run ``sync()`` on a paced daemon thread until :meth:`stop`."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.policy.interval_s):
                try:
                    self.sync()
                except Exception:
                    self.ship_errors += 1

        self._thread = threading.Thread(
            target=_loop, name="epoch-replicator", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None

    # -- one epoch --------------------------------------------------------
    def ship_dir(self, epoch_dir: str) -> bool:
        """Ship one committed epoch dir (composite or flat) into
        ``replica_dir/basename(epoch_dir)``. Idempotent: returns False
        without touching disk when the replica already committed it.
        Raises on failure after unwinding the partial replica dir; a
        crash fault leaves the torn dir for recovery to quarantine."""
        epoch_dir = os.path.abspath(epoch_dir)
        dst_epoch = os.path.join(
            self.replica_dir, os.path.basename(epoch_dir))
        if os.path.exists(os.path.join(dst_epoch, "manifest.json")):
            return False
        manifest = _load_manifest(epoch_dir)
        if manifest is None:
            raise ReplicationError(
                f"{epoch_dir!r} has no composite manifest "
                "(not committed; nothing to ship)")
        os.makedirs(self.replica_dir, exist_ok=True)
        try:
            if manifest.get("composite"):
                for entry in manifest.get("shards", []):
                    rel = entry["dir"]
                    if entry.get("mode") == "skip":
                        # zero-copy on the wire too: the alias target is a
                        # previous epoch's dir, shipped when that epoch
                        # was (ship order == commit order)
                        tgt = rel if os.path.isabs(rel) else os.path.normpath(
                            os.path.join(dst_epoch, rel))
                        if not os.path.exists(
                                os.path.join(tgt, "manifest.json")):
                            raise ReplicationError(
                                f"skip entry aliases {rel!r}, which is not "
                                "committed on the replica yet")
                        self.metrics.record_dir_reused()
                        continue
                    src = os.path.normpath(os.path.join(epoch_dir, rel))
                    dst = os.path.normpath(os.path.join(dst_epoch, rel))
                    self._ship_sink_dir(src, dst)
                self._commit_manifest(epoch_dir, dst_epoch, fire_site=True)
            else:
                # flat single-sink epoch (the unsharded checkpoint
                # manager): the shard manifest rename IS the commit point
                self._ship_sink_dir(epoch_dir, dst_epoch, commit_site=True)
        except BaseException:
            # non-crash failure: unwind so the replica never shows a
            # half-shipped dir past this process's lifetime (a crash
            # fault never reaches here — os._exit — and recovery
            # quarantines the torn dir instead)
            self.metrics.record_transfer_failure()
            shutil.rmtree(dst_epoch, ignore_errors=True)
            raise
        self.metrics.record_epoch_shipped()
        return True

    # -- one shard dir ----------------------------------------------------
    def _ship_sink_dir(self, src: str, dst: str,
                       commit_site: bool = False) -> None:
        manifest = _load_manifest(src)
        if manifest is None:
            raise ReplicationError(
                f"shard dir {src!r} has no parseable manifest")
        os.makedirs(dst, exist_ok=True)
        shipped = logical = 0
        for leaf in manifest.get("leaves", []):
            s, l = self._ship_leaf(src, dst, leaf)
            shipped += s
            logical += l
        if self.verify:
            # deep-verify the arrived bytes against the IN-MEMORY
            # manifest — it is not on the replica disk yet, which is the
            # point: bad bytes must never reach the commit rename
            problem, _ = validate_sink_dir(
                dst, valid_dirs=None, deep_verify=True, manifest=manifest)
            if problem is not None:
                raise ReplicationError(
                    f"arrival verification failed: {problem}")
        self._commit_manifest(src, dst, fire_site=commit_site)
        self.metrics.record_ship(shipped, logical)

    def _ship_leaf(self, src: str, dst: str, leaf: dict) -> Tuple[int, int]:
        """Move one leaf's bytes; returns (shipped_bytes, logical_bytes).

        ``logical_bytes`` is the full uncompressed leaf size — what a
        naive ``cp -r`` of the dir would ship (sparse holes and all)."""
        src_path = os.path.join(src, leaf["file"])
        dst_path = os.path.join(dst, leaf["file"])
        blocks = leaf.get("blocks") or []
        bounds = [0]
        for b in blocks:
            bounds.append(bounds[-1] + int(b[2]))
        shipped = 0
        sfd = os.open(src_path, os.O_RDONLY)
        try:
            dfd = os.open(dst_path,
                          os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
            try:
                if leaf.get("compress"):
                    frames = sorted(leaf.get("frames") or [])
                    end = max((fr[2] + fr[3] for fr in frames), default=0)
                    os.ftruncate(dfd, end)
                    for _, _, off, clen in frames:
                        data = self._read_range(sfd, clen, off, src_path)
                        self._write_range(dfd, data, off, dst_path)
                        shipped += clen
                    logical = bounds[-1] if blocks else end
                elif blocks and leaf.get("carried") is not None:
                    # the carried-block diff: recreate the full-size
                    # sparse file, move only this dir's own run bytes
                    total = bounds[-1]
                    os.ftruncate(dfd, total)
                    for b0, b1 in _coalesce_ids(sorted(leaf["carried"])):
                        lo, hi = bounds[b0], bounds[b1]
                        data = self._read_range(sfd, hi - lo, lo, src_path)
                        self._write_range(dfd, data, lo, dst_path)
                        shipped += hi - lo
                    logical = total
                else:
                    # blockless leaf (scalars / legacy manifests): whole
                    # file, it is tiny or has no run structure to diff
                    size = os.fstat(sfd).st_size
                    data = self._read_range(sfd, size, 0, src_path)
                    self._write_range(dfd, data, 0, dst_path)
                    shipped += size
                    logical = size
                os.fsync(dfd)
            finally:
                os.close(dfd)
        finally:
            os.close(sfd)
        return shipped, logical

    def _commit_manifest(self, src_dir: str, dst_dir: str,
                         fire_site: bool) -> None:
        """Replica-side commit point: copy the manifest byte-verbatim
        (preserving the relative ref graph) through the §12 protocol —
        tmp, fsync, rename, dir fsync. ``fire_site`` marks THE epoch
        commit (the composite rename, or the shard rename of a flat
        epoch); per-shard renames inside a composite are not it."""
        os.makedirs(dst_dir, exist_ok=True)
        with open(os.path.join(src_dir, "manifest.json"), "rb") as f:
            raw = f.read()
        tmp = os.path.join(dst_dir, "manifest.json.tmp")
        with open(tmp, "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        if fire_site:
            _fire_fault("replicate.commit", dst_dir, self.faults)
        os.replace(tmp, os.path.join(dst_dir, "manifest.json"))
        _fsync_dir(dst_dir)

    # -- retried positioned IO --------------------------------------------
    def _with_retry(self, attempt):
        n = 0
        while True:
            try:
                return attempt()
            except OSError:
                delay = self.retry.backoff(n)
                if delay is None:
                    raise
                self.metrics.record_transfer_retry()
                time.sleep(delay)
                n += 1

    def _read_range(self, fd: int, n: int, offset: int, path: str) -> bytes:
        def attempt():
            _fire_fault("replicate.read", f"{path}@{offset}", self.faults)
            return _pread_exact(fd, n, offset)
        return self._with_retry(attempt)

    def _write_range(self, fd: int, data: bytes, offset: int,
                     path: str) -> None:
        def attempt():
            _fire_fault("replicate.write", f"{path}@{offset}", self.faults)
            _pwrite_exact(fd, data, offset)
        self._with_retry(attempt)

    # -- repair source (the scrubber's re-fetch) --------------------------
    def fetch_dir(self, sdir: str) -> Optional[str]:
        """Stage a deep-verified copy of primary shard dir ``sdir`` from
        the replica at ``sdir + '.fetch'``; returns the staged path or
        None when the replica has no verified copy. The caller owns the
        quarantine + rename swap (and the staged dir on success)."""
        sdir = os.path.abspath(sdir)
        # a composite shard lives at pool/epN/shard_k -> replica/epN/
        # shard_k; a flat epoch at pool/epN -> replica/epN
        candidates = (
            os.path.join(self.replica_dir,
                         os.path.basename(os.path.dirname(sdir)),
                         os.path.basename(sdir)),
            os.path.join(self.replica_dir, os.path.basename(sdir)),
        )
        src = next(
            (c for c in candidates
             if os.path.exists(os.path.join(c, "manifest.json"))),
            None,
        )
        if src is None:
            return None
        staged = sdir + ".fetch"
        shutil.rmtree(staged, ignore_errors=True)
        try:
            shutil.copytree(src, staged)
        except OSError:
            shutil.rmtree(staged, ignore_errors=True)
            self.metrics.record_transfer_failure()
            return None
        # verify the STAGED bytes (not just the replica's): the copy
        # itself crossed the same unreliable path the ship did. Relative
        # parent refs resolve identically from <sdir>.fetch — same
        # parent dir as sdir.
        problem, _ = validate_sink_dir(
            staged, valid_dirs=None, deep_verify=True)
        if problem is not None:
            shutil.rmtree(staged, ignore_errors=True)
            self.metrics.record_transfer_failure()
            return None
        return staged

    # -- introspection -----------------------------------------------------
    def summary(self) -> dict:
        out = self.metrics.summary()
        out["replication_lag"] = float(self.lag())
        out["ship_errors"] = float(self.ship_errors)
        return out
