"""The block table — this framework's analogue of the process page table.

Paper mapping (Async-fork §2.1, §4.1):

  * pytree structure / treedef .......... PGD/PUD levels (cheap metadata, the
                                          parent copies these synchronously)
  * one pytree leaf ("VMA") ............. a contiguous virtual memory area
  * one copy block of a leaf ("PMD") .... a PMD entry + its 512-PTE table;
                                          the unit of (a) asynchronous copying
                                          by the child and (b) proactive
                                          synchronization by the parent
  * per-block tri-state flag ............ the reused R/W protection bit

Blocks partition a leaf along axis 0 so that a block is a contiguous,
cheaply-sliceable region of roughly ``block_bytes`` bytes (default 4 MiB,
mirroring a PMD's 2 MiB reach at the paper's 4 KiB pages, scaled for HBM).
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.utils.tree import flatten_with_paths, leaf_nbytes

DEFAULT_BLOCK_BYTES = 4 << 20  # 4 MiB


class BlockState(enum.IntEnum):
    """Copy status of one block ("PMD R/W flag", Async-fork §4.2)."""

    UNCOPIED = 0   # write-protected: a parent write must proactively sync
    COPYING = 1    # trylock_page() held by copier/parent/persister
    COPIED = 2     # staged; parent writes need no synchronization
    PERSISTED = 3  # durable; no synchronization for the rest of the window


@dataclasses.dataclass(frozen=True)
class BlockRef:
    """One copy unit (a "PMD entry + its PTE table")."""

    leaf_id: int
    block_id: int
    start: int      # row range [start, stop) along axis 0 of the leaf
    stop: int
    nbytes: int

    @property
    def key(self):
        return (self.leaf_id, self.block_id)


@dataclasses.dataclass(frozen=True)
class BlockRun:
    """A maximal contiguous run of same-leaf copy blocks.

    Runs are the persist hot path's transfer unit: adjacent blocks of one
    leaf occupy adjacent file offsets (``FileSink``'s prefix-sum layout)
    and adjacent rows of the leaf's blocked image, so one run moves with
    one gathered ``pwritev`` and (device staging) one batched D2H
    transfer instead of ``len(refs)`` single-block operations.
    """

    leaf_id: int
    start_block: int
    refs: Tuple[BlockRef, ...]
    state: Optional["BlockState"] = None  # shared state at coalesce time

    @property
    def stop_block(self) -> int:
        return self.start_block + len(self.refs)

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.refs)

    @property
    def start(self) -> int:
        """First row covered (axis 0 of the leaf)."""
        return self.refs[0].start

    @property
    def stop(self) -> int:
        """One past the last row covered."""
        return self.refs[-1].stop


def coalesce_refs(refs: List[BlockRef]) -> List["BlockRun"]:
    """Group a sorted (leaf-major) list of :class:`BlockRef`s into maximal
    contiguous :class:`BlockRun`s — gaps in ``block_id`` and leaf
    boundaries both break a run (a run is a same-leaf unit).

    Unlike :meth:`BlockTable.coalesce_runs` this takes an explicit ref
    list — the run-aware proactive sync coalesces exactly the blocks whose
    trylocks it just won, which need not be every block of the leaf.
    """
    runs: List[BlockRun] = []
    cur: List[BlockRef] = []
    for ref in refs:
        if cur and (ref.leaf_id != cur[-1].leaf_id
                    or ref.block_id != cur[-1].block_id + 1):
            runs.append(BlockRun(cur[0].leaf_id, cur[0].block_id, tuple(cur)))
            cur = []
        cur.append(ref)
    if cur:
        runs.append(BlockRun(cur[0].leaf_id, cur[0].block_id, tuple(cur)))
    return runs


class TwoWayPointer:
    """Paper §4.3: per-VMA connection between parent and child.

    Lets the parent answer "is every block of this leaf copied?" in O(1)
    instead of looping over all PMDs, and carries the error code used by
    §4.4 error handling. ``close()`` severs the connection once the whole
    leaf is copied (or the snapshot aborts).
    """

    __slots__ = ("remaining", "error", "_lock", "closed")

    def __init__(self, n_blocks: int):
        self.remaining = n_blocks
        self.error: Optional[BaseException] = None
        self.closed = n_blocks == 0
        self._lock = threading.Lock()

    def block_done(self) -> None:
        with self._lock:
            self.remaining -= 1
            if self.remaining <= 0:
                self.closed = True

    def set_error(self, exc: BaseException) -> None:
        with self._lock:
            self.error = exc
            self.closed = True


@dataclasses.dataclass(frozen=True)
class BlockGeometry:
    """Block-table layout of one leaf, shared by staging backends and the
    dirty-epoch comparison: the leaf reshaped to (n_blocks, block_elems)
    with only the final block zero-padded."""

    n_blocks: int
    rows_per_block: int
    row_elems: int
    block_elems: int
    total_elems: int

    def matches(self, other: "BlockGeometry") -> bool:
        return self == other


@dataclasses.dataclass
class LeafHandle:
    """One "VMA": a pytree leaf plus its block list and two-way pointer."""

    leaf_id: int
    path: str
    shape: tuple
    dtype: Any
    blocks: List[BlockRef]
    twoway: TwoWayPointer

    def geometry(self) -> Optional[BlockGeometry]:
        """Blocked layout of this leaf, or None for a zero-block leaf."""
        if not self.blocks:
            return None
        rows_per_block = self.blocks[0].stop - self.blocks[0].start
        if self.shape:
            total = 1
            for d in self.shape:
                total *= int(d)
            row_elems = total // max(1, int(self.shape[0]))
        else:
            total = row_elems = 1
        return BlockGeometry(
            n_blocks=len(self.blocks),
            rows_per_block=rows_per_block,
            row_elems=row_elems,
            block_elems=rows_per_block * row_elems,
            total_elems=total,
        )


class BlockTable:
    """Partition a pytree of arrays into copy blocks and track their state.

    Thread-safety: flag transitions are guarded by a single mutex +
    condition variable; bulk copies happen *outside* the lock while the
    block is in ``COPYING`` state (the ``trylock_page()`` analogue), so the
    parent and the copier threads never copy the same block concurrently
    (Async-fork §4.2 "Eliminating Unnecessary Synchronizations").
    """

    def __init__(self, tree, block_bytes: int = DEFAULT_BLOCK_BYTES):
        leaves_with_paths, treedef = flatten_with_paths(tree)
        self.treedef = treedef
        self.block_bytes = int(block_bytes)
        self.leaf_handles: List[LeafHandle] = []
        self.blocks: List[BlockRef] = []
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        self.total_bytes = 0

        for leaf_id, (path, leaf) in enumerate(leaves_with_paths):
            shape = tuple(leaf.shape)
            nbytes = leaf_nbytes(leaf)
            self.total_bytes += nbytes
            if not shape:  # scalar leaf -> single block
                rows, row_bytes = 1, nbytes
            else:
                rows = shape[0]
                row_bytes = max(1, nbytes // max(1, rows))
            rows_per_block = max(1, self.block_bytes // row_bytes)
            refs: List[BlockRef] = []
            start = 0
            bid = 0
            while start < rows:
                stop = min(rows, start + rows_per_block)
                refs.append(
                    BlockRef(leaf_id, bid, start, stop, (stop - start) * row_bytes)
                )
                start = stop
                bid += 1
            handle = LeafHandle(
                leaf_id, path, shape, np.dtype(leaf.dtype), refs, TwoWayPointer(len(refs))
            )
            self.leaf_handles.append(handle)
            self.blocks.extend(refs)

        # Single vectorized state vector behind the lock/CV: leaf_id's
        # blocks occupy the contiguous index range
        # [_leaf_base[leaf_id], _leaf_base[leaf_id + 1]), so a whole-leaf
        # flag mirror is one array copy and run transitions are one slice
        # assignment instead of a Python loop over a dict.
        self._leaf_base = np.cumsum(
            [0] + [len(h.blocks) for h in self.leaf_handles]
        )
        self._states = np.full(
            (len(self.blocks),), int(BlockState.UNCOPIED), dtype=np.int32
        )

    def _idx(self, key) -> int:
        return int(self._leaf_base[key[0]]) + key[1]

    # ------------------------------------------------------------------ #
    # flag machine                                                       #
    # ------------------------------------------------------------------ #
    def state(self, key) -> BlockState:
        with self._mu:
            return BlockState(int(self._states[self._idx(key)]))

    def try_acquire(self, key) -> bool:
        """UNCOPIED -> COPYING transition (the trylock). Returns True if won."""
        i = self._idx(key)
        with self._mu:
            if self._states[i] == int(BlockState.UNCOPIED):
                self._states[i] = int(BlockState.COPYING)
                return True
            return False

    def mark(self, key, state: BlockState, *, count_done: bool = True) -> None:
        leaf_id = key[0]
        i = self._idx(key)
        with self._cv:
            prev = int(self._states[i])
            self._states[i] = int(state)
            self._cv.notify_all()
        if (
            count_done
            and state in (BlockState.COPIED, BlockState.PERSISTED)
            and prev in (int(BlockState.COPYING), int(BlockState.UNCOPIED))
        ):
            self.leaf_handles[leaf_id].twoway.block_done()

    def mark_run(
        self, run: BlockRun, state: BlockState, *, count_done: bool = True
    ) -> None:
        """One-slice :meth:`mark` of a whole run (single lock round)."""
        base = int(self._leaf_base[run.leaf_id])
        lo, hi = base + run.start_block, base + run.stop_block
        with self._cv:
            prev = self._states[lo:hi].copy()
            self._states[lo:hi] = int(state)
            self._cv.notify_all()
        if count_done and state in (BlockState.COPIED, BlockState.PERSISTED):
            n = int(
                np.isin(
                    prev, (int(BlockState.COPYING), int(BlockState.UNCOPIED))
                ).sum()
            )
            twoway = self.leaf_handles[run.leaf_id].twoway
            for _ in range(n):
                twoway.block_done()

    def wait_not_copying(self, key) -> BlockState:
        """Wait out a concurrent copier holding the block lock."""
        i = self._idx(key)
        with self._cv:
            while self._states[i] == int(BlockState.COPYING):
                self._cv.wait(timeout=1.0)
            return BlockState(int(self._states[i]))

    def wait_all_not_copying(self) -> None:
        """Wait until no block anywhere in the table is mid-copy.

        Sealing a snapshot (``copy_done``) promises every block is staged,
        but a parent-side ``sync_for_write`` can still hold a block in
        COPYING that every copier skipped (trylock lost in the main sweep,
        not UNCOPIED in the steal sweep). The sealer waits such stragglers
        out here; otherwise ``to_tree`` can serve a staging slot whose
        ``np.empty`` garbage was never overwritten."""
        copying = int(BlockState.COPYING)
        with self._cv:
            while bool((self._states == copying).any()):
                self._cv.wait(timeout=1.0)

    def rollback_leaf(self, leaf_id: int) -> int:
        """§4.4: make every non-final block of the leaf writable again."""
        base = int(self._leaf_base[leaf_id])
        hi = base + len(self.leaf_handles[leaf_id].blocks)
        with self._cv:
            sl = self._states[base:hi]
            live = np.isin(
                sl, (int(BlockState.UNCOPIED), int(BlockState.COPYING))
            )
            sl[live] = int(BlockState.PERSISTED)  # drop protection
            self._cv.notify_all()
            return int(live.sum())

    def leaf_states(self, leaf_id: int) -> np.ndarray:
        """Consistent int32 copy of one leaf's block states — the kernel
        flag mirror is this one array copy (no per-block lock rounds)."""
        base = int(self._leaf_base[leaf_id])
        hi = base + len(self.leaf_handles[leaf_id].blocks)
        with self._mu:
            return self._states[base:hi].copy()

    def coalesce_runs(
        self,
        leaf_id: int,
        *,
        exclude=frozenset(),
        max_blocks: Optional[int] = None,
        states: Optional[np.ndarray] = None,
    ) -> List[BlockRun]:
        """Merge adjacent same-state blocks of a leaf into :class:`BlockRun`s.

        ``exclude`` drops blocks (by key) entirely — a persist producer
        excludes inherited blocks so runs never straddle a delta hole.
        ``max_blocks`` caps run length (sinks gather one iovec per block).
        ``states`` reuses a previously taken :meth:`leaf_states` mirror;
        states move concurrently, so runs are a grouping heuristic — every
        consumer still takes each block through its own flag transitions.
        """
        handle = self.leaf_handles[leaf_id]
        if not handle.blocks:
            return []
        if states is None:
            states = self.leaf_states(leaf_id)
        runs: List[BlockRun] = []
        cur: List[BlockRef] = []
        cur_state = None

        def flush():
            if cur:
                runs.append(
                    BlockRun(leaf_id, cur[0].block_id, tuple(cur),
                             BlockState(int(cur_state)))
                )

        for ref in handle.blocks:
            if ref.key in exclude:
                flush()
                cur, cur_state = [], None
                continue
            st = states[ref.block_id]
            if cur and (
                st != cur_state or (max_blocks and len(cur) >= max_blocks)
            ):
                flush()
                cur = []
            cur.append(ref)
            cur_state = st
        flush()
        return runs

    def counts(self) -> Dict[str, int]:
        with self._mu:
            hist = np.bincount(self._states, minlength=len(BlockState))
        return {s.name: int(hist[int(s)]) for s in BlockState}

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def leaf_done(self, leaf_id: int) -> bool:
        """O(1) whole-leaf check via the two-way pointer (§4.3)."""
        return self.leaf_handles[leaf_id].twoway.closed
