"""Per-shard BGSAVE policy — full vs delta vs skip, per shard per epoch.

The paper takes one global decision per BGSAVE; PR 1 added one global
``incremental=`` flag. But shards dirty at different rates (the MVCC
virtual-snapshotting line of work makes the same observation for
partitions), so a single global mode either wastes sink bandwidth on cold
shards or pays the dirty-scan on shards that rewrite everything anyway.

:class:`BgsavePolicy` tracks a dirty-rate EMA per shard — fed by the
PR-1 dirty-block scan counts the ``BlockTable``/``_mark_clean_blocks``
path already produces (``inherited_blocks`` / ``total_blocks``) — and
decides, at every fork barrier, one of three modes per shard:

  * ``"full"``  — no usable base, the anchor interval expired
    (``full_every`` delta epochs since the last full), or the dirty EMA
    exceeds ``delta_threshold`` (a delta would carry most blocks anyway
    while still paying the O(state) dirty scan inside fork).
  * ``"delta"`` — dirty-scan against the shard's retained T0 image and
    persist only changed blocks.
  * ``"skip"``  — ZERO writes hit the shard since its last epoch's T0
    stamp (the coordinator's write counters prove it under the gate), so
    its previous image *is* its state at the new barrier: the epoch is
    zero-copy — no fork, no scan, no sink traffic; the composite manifest
    points at the previous epoch's shard directory. Skips do not advance
    the anchor clock (the restore chain does not grow).

The skip-soundness argument lives in DESIGN.md §8 and survives the
PR-5 striped write gates (DESIGN.md §9): every write to shard k routes
through ``before_write`` while holding *shard k's gate stripe*
(:class:`~repro.core.gates.GateSet`), shard k's counter
(:class:`ShardWriteCounters`) mutates only under that stripe and resets
under it at each T0 stamp, and the fork barrier holds ALL stripes — so
"shard k's counter == 0 at the barrier" still implies byte-identity with
the previous image, per shard, without any global serialization.

Across a reshard the per-shard state follows :meth:`ShardLayout.parents`:
an unchanged shard keeps its state; split children inherit the parent's
dirty EMA (their true rates will re-converge); a merged shard takes the
max of its parents' EMAs (conservative: prefer a full epoch after
uncertainty). Changed shards lose their retained base with their
snapshotter, so the decision degrades to "full" regardless.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set


class ShardWriteCounters:
    """Per-shard write counters backing the policy's skip proof and dirty
    estimate, sharded to match the striped write gates.

    Concurrency contract (the striping argument, DESIGN.md §9): slot ``k``
    is mutated only by a writer holding gate stripe ``k``; the barrier and
    layout-swap paths read/reset/remap every slot while holding ALL
    stripes. No slot is ever touched by two threads at once, so the plain
    lists need no locks of their own.

    ``touched`` holds the DISTINCT block ids the writes hit (global ids
    under a range layout) — the policy's full-epoch dirty estimate must
    not count a hot block once per write, or a write-skewed shard would
    pin its EMA at 1.0.
    """

    def __init__(self, n_shards: int):
        self.writes: List[int] = [0] * n_shards
        self.touched: List[Set[int]] = [set() for _ in range(n_shards)]

    def note(self, shard_id: int, block_id: int) -> None:
        """One write against ``shard_id`` touching ``block_id`` (caller
        holds stripe ``shard_id``)."""
        self.writes[shard_id] += 1
        self.touched[shard_id].add(block_id)

    def touched_count(self, shard_id: int) -> int:
        return len(self.touched[shard_id])

    def reset(self, shard_id: int) -> None:
        """Zero one shard's counters (at its T0 stamp, under the barrier)."""
        self.writes[shard_id] = 0
        self.touched[shard_id] = set()

    def remap(self, parents: Sequence[Sequence[int]], bounds: Sequence[int]) -> None:
        """Re-bucket across a layout swap (caller holds all stripes):
        write counts sum over each new shard's parents; touched sets hold
        global ids, so they re-bucket by the new ``bounds`` intervals."""
        self.writes = [
            sum(self.writes[p] for p in ps) for ps in parents
        ]
        all_touched: Set[int] = set().union(*self.touched) if self.touched else set()
        self.touched = [
            {g for g in all_touched if bounds[k] <= g < bounds[k + 1]}
            for k in range(len(parents))
        ]


@dataclasses.dataclass
class ShardPolicyState:
    """Mutable per-shard decision inputs the policy accumulates."""

    dirty_ema: float = 1.0       # start pessimistic: first epoch is full
    epochs_since_full: int = 0   # delta epochs since the last full anchor


@dataclasses.dataclass(frozen=True)
class ShardEpochView:
    """What the coordinator knows about a shard at decision time."""

    writes_since_epoch: int = 0
    has_base: bool = False        # retained, non-aborted T0 image to diff
    base_persisted: bool = False  # base epoch durable (skip may reference it)
    can_skip: bool = True         # caller veto (e.g. no recorded parent dir)


class BgsavePolicy:
    """Full-vs-delta-vs-skip decisions, one per shard per fork barrier."""

    def __init__(
        self,
        delta_threshold: float = 0.5,
        full_every: int = 8,
        ema_alpha: float = 0.5,
        allow_skip: bool = True,
    ):
        self.delta_threshold = float(delta_threshold)
        self.full_every = max(1, int(full_every))
        self.ema_alpha = float(ema_alpha)
        self.allow_skip = bool(allow_skip)
        self._state: List[ShardPolicyState] = []

    # -- state access ----------------------------------------------------
    def _ensure(self, n: int) -> None:
        while len(self._state) < n:
            self._state.append(ShardPolicyState())

    def state(self, shard_id: int) -> ShardPolicyState:
        self._ensure(shard_id + 1)
        return self._state[shard_id]

    # -- the decision rule (DESIGN.md §8) --------------------------------
    def decide(self, shard_id: int, view: ShardEpochView) -> str:
        st = self.state(shard_id)
        if not view.has_base:
            return "full"
        if (
            self.allow_skip
            and view.can_skip
            and view.base_persisted
            and view.writes_since_epoch == 0
        ):
            return "skip"
        if st.epochs_since_full >= self.full_every - 1:
            return "full"
        if st.dirty_ema > self.delta_threshold:
            return "full"
        return "delta"

    def observe(
        self, shard_id: int, mode: str, dirty_frac: Optional[float] = None
    ) -> None:
        """Fold one epoch's outcome back into the shard's state.

        ``dirty_frac`` is ``(total - inherited) / total`` from the delta
        epoch's dirty scan; full epochs may pass an estimate or ``None``
        (EMA untouched), skips are a certified dirty fraction of 0.
        """
        st = self.state(shard_id)
        if mode == "full":
            st.epochs_since_full = 0
        elif mode == "delta":
            st.epochs_since_full += 1
        if mode == "skip":
            dirty_frac = 0.0
        if dirty_frac is not None:
            a = self.ema_alpha
            st.dirty_ema = a * float(dirty_frac) + (1.0 - a) * st.dirty_ema

    # -- reshard ---------------------------------------------------------
    def remap(
        self, parents: Sequence[Sequence[int]], unchanged: Dict[int, int]
    ) -> None:
        """Re-key the per-shard state after a layout change.

        ``parents[k]`` lists the old shard indices overlapping new shard
        ``k`` (:meth:`ShardLayout.parents`); ``unchanged`` maps new→old for
        shards whose interval (and thus snapshotter + base) carried over.
        """
        n_old = max((max(ps) for ps in parents if ps), default=-1) + 1
        self._ensure(n_old)
        new_state: List[ShardPolicyState] = []
        for k, ps in enumerate(parents):
            if k in unchanged:
                new_state.append(self._state[unchanged[k]])
            else:
                ema = max((self._state[p].dirty_ema for p in ps), default=1.0)
                new_state.append(ShardPolicyState(dirty_ema=ema))
        self._state = new_state


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Thresholds driving the :class:`repro.core.catalog.ChainCompactor`.

    The BGSAVE policy above decides how each epoch is WRITTEN; this one
    decides when the maintenance plane rewrites what the write path left
    behind. A shard dir whose delta chain is deeper than ``max_chain``
    hops gets folded into a fresh full image in place (restores of it and
    of every skip epoch aliasing it stop walking the chain), after which
    its parent refs are released and the catalog GC can reclaim the
    ancestors nothing else pins. ``interval_s`` paces the background
    scan loop.
    """

    max_chain: int = 3
    interval_s: float = 0.05

    def should_compact(self, chain_depth: int) -> bool:
        return chain_depth > self.max_chain


@dataclasses.dataclass(frozen=True)
class ReplicationPolicy:
    """Pacing for :class:`repro.core.replicate.EpochReplicator`'s
    background ship loop.

    ``interval_s`` is how long the shipper sleeps between ``sync()``
    passes when there is nothing pending; ``epochs_per_sync`` bounds how
    many epochs one pass ships (0 = drain everything pending) so a cold
    standby catching up on a long history cannot monopolize the source
    disk. Transfer retry/backoff is a separate, orthogonal knob — pass a
    :class:`RetryPolicy` to the replicator for that.
    """

    interval_s: float = 0.05
    epochs_per_sync: int = 0


@dataclasses.dataclass(frozen=True)
class ScrubPolicy:
    """Pacing for :class:`repro.core.scrub.EpochScrubber`'s background
    crc pass (the low-duty dial: bit rot develops over days, so the
    scrubber only needs to cover the pool eventually, never quickly).

    ``interval_s`` paces the scan loop; ``dirs_per_scan`` bounds how many
    committed shard dirs one tick deep-verifies, so each tick's disk read
    burst stays small next to the serving plane's traffic.
    """

    interval_s: float = 0.05
    dirs_per_scan: int = 2


class CopierDutyController:
    """Feedback controller for the copier duty cycle (DESIGN.md §13).

    The duty cycle is the paper's central dial: copiers that run flat out
    shorten the copy window but steal memory bandwidth and gate time from
    foreground writers (the latency spikes §6.2 measures); copiers that
    sleep too much stretch the window and every writer pays CoW faults
    for longer. The seed picked a static ``0.3 / threads / sqrt(shards)``
    guess at construction and never looked back. This controller replaces
    the guess with a per-epoch multiplicative-increase /
    multiplicative-decrease loop over the signals each epoch already
    meters:

      * ``gate_wait_us`` over ``gate_wait_budget_us`` — foreground writers
        queued on the write gates while the epoch ran: the copiers (and
        the stager lane they feed) are crowding the hot path → back off.
      * ``copy_window_s`` exceeding ``sink_write_s`` — the flag machine,
        not the disk, is the long pole: the sink sits idle waiting for
        blocks to reach COPIED → push duty up so staging catches up.
      * ``dirty_frac`` under ``idle_dirty_frac`` with writers unbothered —
        a mostly-clean epoch needs little proactive copying → drift down
        and give the bandwidth back.

    One multiplicative ``step`` per epoch, clamped to
    ``[min_duty, max_duty]``, so a noisy epoch moves the dial one notch,
    not to the rail. ``reseed`` re-anchors after a reshard (the static
    formula's shard count changed under us); ``adjustments`` and
    ``last_reason`` make the loop observable in :class:`EngineReport`.
    """

    def __init__(self, duty: float, min_duty: float = 0.05,
                 max_duty: float = 1.0, step: float = 1.25,
                 gate_wait_budget_us: float = 500.0,
                 idle_dirty_frac: float = 0.1):
        self.min_duty = float(min_duty)
        self.max_duty = float(max_duty)
        self.step = float(step)
        self.gate_wait_budget_us = float(gate_wait_budget_us)
        self.idle_dirty_frac = float(idle_dirty_frac)
        self.duty = self._clamp(float(duty))
        self.adjustments = 0
        self.last_reason = "seed"

    def _clamp(self, duty: float) -> float:
        return max(self.min_duty, min(self.max_duty, duty))

    def reseed(self, duty: float) -> float:
        """Re-anchor after a reshard; keeps the adjustment history."""
        self.duty = self._clamp(float(duty))
        self.last_reason = "reseed"
        return self.duty

    def update(self, *, gate_wait_us: float = 0.0, stage_s: float = 0.0,
               sink_write_s: float = 0.0, copy_window_s: float = 0.0,
               dirty_frac: float = 0.0) -> float:
        """Fold one persisted epoch's signals in; returns the new duty."""
        prev = self.duty
        if gate_wait_us > self.gate_wait_budget_us:
            # Writers queued on the gates: copiers are the interference.
            self.duty = self._clamp(self.duty / self.step)
            self.last_reason = "gate_wait"
        elif copy_window_s > sink_write_s or stage_s > sink_write_s:
            # Staging (copy window or stager lane) is the long pole: the
            # sink starves waiting for COPIED blocks.
            self.duty = self._clamp(self.duty * self.step)
            self.last_reason = "copy_window"
        elif dirty_frac == dirty_frac and dirty_frac < self.idle_dirty_frac:
            # Mostly-clean epoch (NaN-safe check), writers unbothered:
            # give the bandwidth back.
            self.duty = self._clamp(self.duty / self.step)
            self.last_reason = "idle"
        else:
            self.last_reason = "hold"
        if self.duty != prev:
            self.adjustments += 1
        return self.duty


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-with-backoff for transient persist-sink ``OSError``s.

    The persist worker treats a sink-write failure as transient for up to
    ``max_retries`` re-attempts (positioned ``pwritev`` writes are
    idempotent, so replaying a run is safe), sleeping
    ``backoff_s * backoff_mult**attempt`` (capped at ``max_backoff_s``)
    between attempts. Once the budget is exhausted the failure escalates
    to the existing epoch abort. Only ``OSError`` is retried — anything
    else is a bug, not weather.
    """

    max_retries: int = 3
    backoff_s: float = 0.001
    backoff_mult: float = 2.0
    max_backoff_s: float = 0.05

    def backoff(self, attempt: int) -> Optional[float]:
        """Sleep before retry number ``attempt`` (0-based), or None when
        the budget is spent."""
        if attempt >= self.max_retries:
            return None
        return min(self.backoff_s * (self.backoff_mult ** attempt),
                   self.max_backoff_s)
