"""Parallel persist pipeline — the "RDB writer" generalized to a pool.

The paper's child persists the snapshot with a single sequential writer
(§5.2): one thread walks the block order, stages anything the copiers have
not reached yet, and streams it to the sink. That caps snapshot throughput
at one disk stream per instance. This module extracts that loop into a
:class:`PersistPipeline`: a bounded work queue feeding ``workers`` persister
threads that write **runs of contiguous blocks** out of order into the sink
(``FileSink``'s pwrite-style layout makes out-of-order writes safe), with
per-epoch jobs tracked so ``close()``/``abort()`` still fire exactly once
per sink.

The transfer unit is a :class:`~repro.core.blocks.BlockRun`, not a single
block: the producer coalesces adjacent same-leaf blocks of the persist
order (up to ``run_blocks``) so a worker stages each block through the
normal flag machine, then moves the whole run with ONE gathered sink write
(``write_run`` → ``pwritev``) and, for device staging, ONE batched D2H
transfer (``staged_run``) — instead of one syscall and one transfer per
block. ``run_blocks=1`` degenerates to the seed's per-block behavior.

A pipeline with ``workers=1`` behaves exactly like the paper's single
writer (same staging, same pacing against a slow sink); the sharded
coordinator shares one wider pipeline across all shard epochs so N shards
persist concurrently without N uncoordinated thread herds.

Workers are lazy: they spawn on the first job and exit after an idle
period with no jobs in flight, so short-lived snapshotters (one per
checkpoint save) do not leak threads.
"""
from __future__ import annotations

import atexit
import queue
import threading
import time
import weakref
from typing import List, Optional, Sequence

from repro.core.blocks import BlockRef, BlockRun, BlockState
from repro.core.faults import FaultInjector, fire as _fire_fault
from repro.core.policy import RetryPolicy
from repro.core.sinks import Sink

DEFAULT_RUN_BLOCKS = 16

# Live pipelines, so interpreter exit can retire their idle workers.
# A daemon worker waking from its timed queue wait DURING interpreter
# finalization dies via pthread_exit, which unwinds C++ frames (XLA's)
# and lands in std::terminate — an intermittent SIGABRT after a clean
# test run. The atexit hook runs before finalization proper, wakes every
# idle worker with a sentinel, and joins them while it is still safe.
_PIPELINES: "weakref.WeakSet" = weakref.WeakSet()


@atexit.register
def _retire_workers_at_exit() -> None:
    for pipe in list(_PIPELINES):
        pipe.shutdown(timeout=2.0)


class PersistJob:
    """One epoch's persist: a (snapshot, sink) pair plus completion tracking.

    ``_outstanding`` counts enqueued-but-unwritten runs; the job finishes
    (sink close/abort + ``persist_done``) when the producer has enqueued its
    whole order and the count drains to zero — regardless of which worker
    wrote the last run.

    ``persist_start`` is stamped when the sink opens: the interval from
    there to the last write is ``metrics.sink_write_s`` — pure sink IO
    when the image was fully staged before submit (blocking mode), sink
    IO plus residual worker-side staging otherwise — while
    ``metrics.persist_s`` keeps its fork→durable meaning. The seed
    stamped only the latter, which understated sink bandwidth by folding
    the whole copy window into the denominator.
    """

    def __init__(self, snap, sink, order: Optional[Sequence[BlockRef]],
                 on_finish=None):
        self.snap = snap
        self.sink = sink
        self.order = list(order) if order is not None else None
        self.failed = False
        self.persist_start: Optional[float] = None
        self._on_finish = on_finish
        self._mu = threading.Lock()
        self._outstanding = 0
        self._submitted_all = False

    # -- accounting (producer increments, workers decrement) ---------------
    def _run_enqueued(self) -> None:
        with self._mu:
            self._outstanding += 1

    def _run_finished(self) -> None:
        with self._mu:
            self._outstanding -= 1
            done = self._submitted_all and self._outstanding == 0
        if done:
            self._finish()

    def _all_enqueued(self) -> None:
        with self._mu:
            self._submitted_all = True
            done = self._outstanding == 0
        if done:
            self._finish()

    def fail(self, exc: BaseException) -> None:
        """§4.4 case 3 routed through the pipeline: abort the epoch; the
        job's remaining runs drain as no-ops and ``_finish`` cleans up."""
        with self._mu:
            first = not self.failed
            self.failed = True
        if first:
            self.snap.metrics.record_persist_abort()
        self.snap.abort(exc)

    def _finish(self) -> None:
        snap, sink = self.snap, self.sink
        try:
            if self.failed or snap.aborted:
                sink.abort()
            else:
                sink.close()
                now = time.perf_counter()
                snap.metrics.persist_s = now - snap.t0
                if self.persist_start is not None:
                    snap.metrics.sink_write_s = now - self.persist_start
        except BaseException as exc:
            snap.abort(exc)
            sink.abort()
        finally:
            snap.persist_done.set()
            if self._on_finish is not None:
                self._on_finish(self)


class PersistPipeline:
    """Bounded work queue + persister worker pool, shared across epochs."""

    def __init__(self, workers: int = 1, queue_depth: int = 64,
                 idle_timeout: float = 1.0,
                 run_blocks: int = DEFAULT_RUN_BLOCKS,
                 retry: Optional[RetryPolicy] = RetryPolicy(),
                 faults: Optional[FaultInjector] = None):
        self.workers = max(1, int(workers))
        self.queue_depth = max(1, int(queue_depth))
        self.idle_timeout = float(idle_timeout)
        self.run_blocks = max(1, int(run_blocks))
        self.retry = retry        # None disables persist-write retries
        self.faults = faults
        self._q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._mu = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._active_jobs = 0
        self._stopping = False
        _PIPELINES.add(self)

    def shutdown(self, timeout: float = 2.0) -> None:
        """Retire the worker pool (interpreter-exit path): wake every idle
        worker with a sentinel and join. In-flight runs complete; queued
        runs of unfinished jobs are dropped (the process is exiting)."""
        with self._mu:
            self._stopping = True
            threads = list(self._threads)
        for _ in threads:
            try:
                self._q.put_nowait(None)
            except queue.Full:
                break
        for t in threads:
            t.join(timeout)

    # ------------------------------------------------------------------ #
    def submit(self, snap, sink, order: Optional[Sequence[BlockRef]] = None) -> PersistJob:
        """Start persisting one epoch. Returns immediately; completion is
        signalled through ``snap.persist_done`` (and errors via
        ``snap.wait_persisted``), same contract as the old single persister."""
        job = PersistJob(snap, sink, order, on_finish=self._job_finished)
        with self._mu:
            self._active_jobs += 1
        self._ensure_workers()
        threading.Thread(target=self._produce, args=(job,), daemon=True).start()
        return job

    def _job_finished(self, job: PersistJob) -> None:
        with self._mu:
            self._active_jobs -= 1

    def _ensure_workers(self) -> None:
        with self._mu:
            self._threads = [t for t in self._threads if t.is_alive()]
            while len(self._threads) < self.workers:
                t = threading.Thread(target=self._worker, daemon=True)
                t.start()
                self._threads.append(t)

    # ------------------------------------------------------------------ #
    def _produce(self, job: PersistJob) -> None:
        """Open the sink, then feed the bounded queue (backpressure: a slow
        sink throttles staging exactly like the old sequential persister).

        The default (whole-table) order is coalesced leaf by leaf with
        :meth:`BlockTable.coalesce_runs` — adjacent blocks merge into runs
        capped at ``run_blocks``, breaking at inherited blocks and leaf
        boundaries, so a run always maps to one contiguous sink byte
        range. A caller-supplied custom order persists per-block (runs of
        one), since arbitrary orders need not be contiguous.
        """
        snap, sink = job.snap, job.sink
        try:
            sink.set_delta(snap.inherited)
            sink.open(snap.table.leaf_handles)
        except BaseException as exc:
            job.fail(exc)
            job._all_enqueued()
            return
        job.persist_start = time.perf_counter()

        def _runs():
            if job.order is not None:
                for ref in job.order:
                    if ref.key not in snap.inherited:
                        yield BlockRun(ref.leaf_id, ref.block_id, (ref,))
                return
            for h in snap.table.leaf_handles:
                yield from snap.table.coalesce_runs(
                    h.leaf_id, exclude=snap.inherited,
                    max_blocks=self.run_blocks,
                )

        for brun in _runs():
            if job.failed or snap.aborted:
                break
            job._run_enqueued()
            self._q.put((job, brun))
        job._all_enqueued()

    def _worker(self) -> None:
        me = threading.current_thread()
        while True:
            try:
                item = self._q.get(timeout=self.idle_timeout)
            except queue.Empty:
                item = None
            if item is None:  # idle timeout or shutdown sentinel
                with self._mu:
                    if self._active_jobs == 0 or self._stopping:
                        # Deregister BEFORE returning, atomically with the
                        # idle check: submit() increments _active_jobs under
                        # this same mutex, so it either sees us gone (and
                        # respawns) or we see its job (and keep running) —
                        # an exiting-but-alive thread can never absorb a
                        # worker slot while a job is pending.
                        if me in self._threads:
                            self._threads.remove(me)
                        return
                continue
            job, brun = item
            self._persist_run(job, brun)

    def _persist_run(self, job: PersistJob, brun: BlockRun) -> None:
        """The old persister's per-block body lifted to a run: take every
        block of the run through the normal staging flag machine (the
        child's shared-table read in CoW mode), then move the whole run
        with one gathered write — blocks stay individually locked during
        staging, only the data movement is batched (DESIGN.md §7)."""
        snap, sink = job.snap, job.sink
        table = snap.table
        try:
            for ref in brun.refs:
                if job.failed or snap.aborted:
                    break
                st = table.state(ref.key)
                while st in (BlockState.UNCOPIED, BlockState.COPYING):
                    if st == BlockState.UNCOPIED and table.try_acquire(ref.key):
                        snap.stage_block(ref)
                        table.mark(ref.key, BlockState.COPIED)
                        snap.metrics.copied_blocks_child += 1
                        st = BlockState.COPIED
                        break
                    st = table.wait_not_copying(ref.key)
            if not (job.failed or snap.aborted):
                arrays = snap.staged_run(brun.refs)
                self._write_with_retry(job, brun, arrays)
                table.mark_run(brun, BlockState.PERSISTED)
        except BaseException as exc:
            job.fail(exc)
        finally:
            job._run_finished()

    def _write_with_retry(self, job: PersistJob, brun: BlockRun,
                          arrays) -> None:
        """One run's sink write under the :class:`RetryPolicy`: a
        transient ``OSError`` replays the whole run (positioned writes
        are idempotent — same offsets, same bytes) after a backoff, up to
        the policy's budget; anything else, or a spent budget, escalates
        to the existing epoch abort in ``_persist_run``'s handler."""
        snap, sink = job.snap, job.sink
        attempt = 0
        while True:
            try:
                _fire_fault("persist.run",
                            f"leaf={brun.leaf_id}+{brun.start_block}",
                            self.faults)
                if type(sink).write_run is Sink.write_run:
                    # write_block-only sink: per-block writes with the
                    # REAL refs (row geometry intact)
                    for ref, arr in zip(brun.refs, arrays):
                        sink.write_block(ref, arr)
                else:
                    sink.write_run(brun.leaf_id, brun.start_block, arrays)
                return
            except OSError:
                delay = None if self.retry is None else \
                    self.retry.backoff(attempt)
                if delay is None or job.failed or snap.aborted:
                    raise
                attempt += 1
                snap.metrics.record_persist_retry()
                if delay:
                    time.sleep(delay)
