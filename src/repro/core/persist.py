"""Parallel persist pipeline — the "RDB writer" generalized to a pool.

The paper's child persists the snapshot with a single sequential writer
(§5.2): one thread walks the block order, stages anything the copiers have
not reached yet, and streams it to the sink. That caps snapshot throughput
at one disk stream per instance. This module extracts that loop into a
:class:`PersistPipeline`: a bounded work queue feeding ``workers`` persister
threads that write blocks **out of order** into the sink (``FileSink``'s
pwrite-style layout makes out-of-order writes safe), with per-epoch jobs
tracked so ``close()``/``abort()`` still fire exactly once per sink.

A pipeline with ``workers=1`` behaves exactly like the paper's single
writer (same staging, same pacing against a slow sink); the sharded
coordinator shares one wider pipeline across all shard epochs so N shards
persist concurrently without N uncoordinated thread herds.

Workers are lazy: they spawn on the first job and exit after an idle
period with no jobs in flight, so short-lived snapshotters (one per
checkpoint save) do not leak threads.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import List, Optional, Sequence

from repro.core.blocks import BlockRef, BlockState


class PersistJob:
    """One epoch's persist: a (snapshot, sink) pair plus completion tracking.

    ``_outstanding`` counts enqueued-but-unwritten blocks; the job finishes
    (sink close/abort + ``persist_done``) when the producer has enqueued its
    whole order and the count drains to zero — regardless of which worker
    wrote the last block.
    """

    def __init__(self, snap, sink, order: Sequence[BlockRef], on_finish=None):
        self.snap = snap
        self.sink = sink
        self.order = list(order)
        self.failed = False
        self._on_finish = on_finish
        self._mu = threading.Lock()
        self._outstanding = 0
        self._submitted_all = False

    # -- accounting (producer increments, workers decrement) ---------------
    def _block_enqueued(self) -> None:
        with self._mu:
            self._outstanding += 1

    def _block_finished(self) -> None:
        with self._mu:
            self._outstanding -= 1
            done = self._submitted_all and self._outstanding == 0
        if done:
            self._finish()

    def _all_enqueued(self) -> None:
        with self._mu:
            self._submitted_all = True
            done = self._outstanding == 0
        if done:
            self._finish()

    def fail(self, exc: BaseException) -> None:
        """§4.4 case 3 routed through the pipeline: abort the epoch; the
        job's remaining blocks drain as no-ops and ``_finish`` cleans up."""
        with self._mu:
            self.failed = True
        self.snap.abort(exc)

    def _finish(self) -> None:
        snap, sink = self.snap, self.sink
        try:
            if self.failed or snap.aborted:
                sink.abort()
            else:
                sink.close()
                snap.metrics.persist_s = time.perf_counter() - snap.t0
        except BaseException as exc:
            snap.abort(exc)
            sink.abort()
        finally:
            snap.persist_done.set()
            if self._on_finish is not None:
                self._on_finish(self)


class PersistPipeline:
    """Bounded work queue + persister worker pool, shared across epochs."""

    def __init__(self, workers: int = 1, queue_depth: int = 64,
                 idle_timeout: float = 1.0):
        self.workers = max(1, int(workers))
        self.queue_depth = max(1, int(queue_depth))
        self.idle_timeout = float(idle_timeout)
        self._q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._mu = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._active_jobs = 0

    # ------------------------------------------------------------------ #
    def submit(self, snap, sink, order: Optional[Sequence[BlockRef]] = None) -> PersistJob:
        """Start persisting one epoch. Returns immediately; completion is
        signalled through ``snap.persist_done`` (and errors via
        ``snap.wait_persisted``), same contract as the old single persister."""
        job = PersistJob(
            snap, sink,
            order if order is not None else snap.table.blocks,
            on_finish=self._job_finished,
        )
        with self._mu:
            self._active_jobs += 1
        self._ensure_workers()
        threading.Thread(target=self._produce, args=(job,), daemon=True).start()
        return job

    def _job_finished(self, job: PersistJob) -> None:
        with self._mu:
            self._active_jobs -= 1

    def _ensure_workers(self) -> None:
        with self._mu:
            self._threads = [t for t in self._threads if t.is_alive()]
            while len(self._threads) < self.workers:
                t = threading.Thread(target=self._worker, daemon=True)
                t.start()
                self._threads.append(t)

    # ------------------------------------------------------------------ #
    def _produce(self, job: PersistJob) -> None:
        """Open the sink, then feed the bounded queue (backpressure: a slow
        sink throttles staging exactly like the old sequential persister)."""
        snap, sink = job.snap, job.sink
        try:
            sink.set_delta(snap.inherited)
            sink.open(snap.table.leaf_handles)
        except BaseException as exc:
            job.fail(exc)
            job._all_enqueued()
            return
        for ref in job.order:
            if job.failed or snap.aborted:
                break
            if ref.key in snap.inherited:
                continue
            job._block_enqueued()
            self._q.put((job, ref))
        job._all_enqueued()

    def _worker(self) -> None:
        me = threading.current_thread()
        while True:
            try:
                job, ref = self._q.get(timeout=self.idle_timeout)
            except queue.Empty:
                with self._mu:
                    if self._active_jobs == 0:
                        # Deregister BEFORE returning, atomically with the
                        # idle check: submit() increments _active_jobs under
                        # this same mutex, so it either sees us gone (and
                        # respawns) or we see its job (and keep running) —
                        # an exiting-but-alive thread can never absorb a
                        # worker slot while a job is pending.
                        if me in self._threads:
                            self._threads.remove(me)
                        return
                continue
            self._persist_block(job, ref)

    def _persist_block(self, job: PersistJob, ref: BlockRef) -> None:
        """The old persister's per-block body: ensure the block is staged
        (the child's shared-table read in CoW mode), then write it out."""
        snap, sink = job.snap, job.sink
        try:
            if not (job.failed or snap.aborted):
                table = snap.table
                st = table.state(ref.key)
                while st in (BlockState.UNCOPIED, BlockState.COPYING):
                    if st == BlockState.UNCOPIED and table.try_acquire(ref.key):
                        snap.stage_block(ref)
                        table.mark(ref.key, BlockState.COPIED)
                        snap.metrics.copied_blocks_child += 1
                        st = BlockState.COPIED
                        break
                    st = table.wait_not_copying(ref.key)
                if not (job.failed or snap.aborted):
                    sink.write_block(ref, snap.staged_block(ref))
                    table.mark(ref.key, BlockState.PERSISTED)
        except BaseException as exc:
            job.fail(exc)
        finally:
            job._block_finished()
