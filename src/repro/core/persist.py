"""Parallel persist pipeline — the "RDB writer" generalized to a pool.

The paper's child persists the snapshot with a single sequential writer
(§5.2): one thread walks the block order, stages anything the copiers have
not reached yet, and streams it to the sink. That caps snapshot throughput
at one disk stream per instance. This module extracts that loop into a
:class:`PersistPipeline`: a bounded work queue feeding ``workers`` persister
threads that write **runs of contiguous blocks** out of order into the sink
(``FileSink``'s pwrite-style layout makes out-of-order writes safe), with
per-epoch jobs tracked so ``close()``/``abort()`` still fire exactly once
per sink.

The transfer unit is a :class:`~repro.core.blocks.BlockRun`, not a single
block: the producer coalesces adjacent same-leaf blocks of the persist
order (up to ``run_blocks``) so a worker stages each block through the
normal flag machine, then moves the whole run with ONE gathered sink write
(``write_run`` → ``pwritev``) and, for device staging, ONE batched D2H
transfer (``staged_run``) — instead of one syscall and one transfer per
block. ``run_blocks=1`` degenerates to the seed's per-block behavior.

With ``overlap=True`` (the default) each run crosses TWO lanes instead of
one thread doing both halves back to back:

  * the **stager lane** — the shared worker pool — takes a run through the
    flag machine and the batched D2H drain, then hands the staged host
    arrays to the job's bounded ring (``ring_depth`` runs, default 2: a
    double buffer);
  * the **writer lane** — one thread per job — drains the ring and issues
    the gathered sink write (pwritev + crc) before marking the run
    ``PERSISTED``.

Because the ring holds at most ``ring_depth`` staged runs, run N+1 stages
while run N writes, so device (D2H) bandwidth and disk bandwidth are in
flight at the same time instead of alternating; memory is bounded at
``ring_depth × run_blocks`` blocks of host copies per job. ``overlap=False``
keeps the seed's serial per-run behavior (stage then write in one worker),
which the ``persist_overlap`` bench cell uses as its baseline arm.
Exactly-once close/abort semantics are unchanged: the run count drains
through ``PersistJob._run_finished`` no matter which lane finishes a run,
and the writer lane exits on a sentinel pushed by ``PersistJob._finish``.

A pipeline with ``workers=1`` behaves exactly like the paper's single
writer (same staging, same pacing against a slow sink); the sharded
coordinator shares one wider pipeline across all shard epochs so N shards
persist concurrently without N uncoordinated thread herds.

Workers are lazy: they spawn on the first job and exit after an idle
period with no jobs in flight, so short-lived snapshotters (one per
checkpoint save) do not leak threads.
"""
from __future__ import annotations

import atexit
import queue
import threading
import time
import weakref
from typing import List, Optional, Sequence

from repro.core.blocks import BlockRef, BlockRun, BlockState
from repro.core.faults import FaultInjector, fire as _fire_fault
from repro.core.policy import RetryPolicy
from repro.core.sinks import Sink

DEFAULT_RUN_BLOCKS = 16

# Live pipelines, so interpreter exit can retire their idle workers.
# A daemon worker waking from its timed queue wait DURING interpreter
# finalization dies via pthread_exit, which unwinds C++ frames (XLA's)
# and lands in std::terminate — an intermittent SIGABRT after a clean
# test run. The atexit hook runs before finalization proper, wakes every
# idle worker with a sentinel, and joins them while it is still safe.
_PIPELINES: "weakref.WeakSet" = weakref.WeakSet()


@atexit.register
def _retire_workers_at_exit() -> None:
    for pipe in list(_PIPELINES):
        pipe.shutdown(timeout=2.0)


class PersistJob:
    """One epoch's persist: a (snapshot, sink) pair plus completion tracking.

    ``_outstanding`` counts enqueued-but-unwritten runs; the job finishes
    (sink close/abort + ``persist_done``) when the producer has enqueued its
    whole order and the count drains to zero — regardless of which worker
    wrote the last run.

    ``persist_start`` is stamped when the sink opens: the interval from
    there to the last write is ``metrics.sink_write_s`` — pure sink IO
    when the image was fully staged before submit (blocking mode), sink
    IO plus residual worker-side staging otherwise — while
    ``metrics.persist_s`` keeps its fork→durable meaning. The seed
    stamped only the latter, which understated sink bandwidth by folding
    the whole copy window into the denominator.
    """

    def __init__(self, snap, sink, order: Optional[Sequence[BlockRef]],
                 on_finish=None):
        self.snap = snap
        self.sink = sink
        self.order = list(order) if order is not None else None
        self.failed = False
        self.persist_start: Optional[float] = None
        self._on_finish = on_finish
        self._mu = threading.Lock()
        self._outstanding = 0
        self._submitted_all = False
        # Two-lane mode: bounded ring of staged runs + the writer thread
        # draining it. Both stay None in serial (overlap=False) mode.
        self._ring: Optional["queue.Queue"] = None
        self._writer: Optional[threading.Thread] = None

    # -- accounting (producer increments, workers decrement) ---------------
    def _run_enqueued(self) -> None:
        with self._mu:
            self._outstanding += 1

    def _run_finished(self) -> None:
        with self._mu:
            self._outstanding -= 1
            done = self._submitted_all and self._outstanding == 0
        if done:
            self._finish()

    def _all_enqueued(self) -> None:
        with self._mu:
            self._submitted_all = True
            done = self._outstanding == 0
        if done:
            self._finish()

    def fail(self, exc: BaseException) -> None:
        """§4.4 case 3 routed through the pipeline: abort the epoch; the
        job's remaining runs drain as no-ops and ``_finish`` cleans up."""
        with self._mu:
            first = not self.failed
            self.failed = True
        if first:
            self.snap.metrics.record_persist_abort()
        self.snap.abort(exc)

    def _finish(self) -> None:
        snap, sink = self.snap, self.sink
        try:
            if self.failed or snap.aborted:
                sink.abort()
            else:
                sink.close()
                now = time.perf_counter()
                snap.metrics.persist_s = now - snap.t0
                if self.persist_start is not None:
                    snap.metrics.sink_write_s = now - self.persist_start
        except BaseException as exc:
            snap.abort(exc)
            sink.abort()
        finally:
            snap.persist_done.set()
            if self._ring is not None:
                # Retire the writer lane. The ring is empty here (the run
                # count only drains after the writer consumed every staged
                # run), so the sentinel never blocks — even when _finish
                # itself runs in the writer thread.
                self._ring.put(None)
            if self._on_finish is not None:
                self._on_finish(self)


class PersistPipeline:
    """Bounded work queue + persister worker pool, shared across epochs."""

    def __init__(self, workers: int = 1, queue_depth: int = 64,
                 idle_timeout: float = 1.0,
                 run_blocks: int = DEFAULT_RUN_BLOCKS,
                 retry: Optional[RetryPolicy] = RetryPolicy(),
                 faults: Optional[FaultInjector] = None,
                 overlap: bool = True, ring_depth: int = 2):
        self.workers = max(1, int(workers))
        self.queue_depth = max(1, int(queue_depth))
        self.idle_timeout = float(idle_timeout)
        self.run_blocks = max(1, int(run_blocks))
        self.retry = retry        # None disables persist-write retries
        self.faults = faults
        self.overlap = bool(overlap)
        self.ring_depth = max(1, int(ring_depth))
        self._q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth)
        self._mu = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._active_jobs = 0
        self._stopping = False
        _PIPELINES.add(self)

    def shutdown(self, timeout: float = 2.0) -> None:
        """Retire the worker pool (interpreter-exit path): wake every idle
        worker with a sentinel and join. In-flight runs complete; queued
        runs of unfinished jobs are dropped (the process is exiting)."""
        with self._mu:
            self._stopping = True
            threads = list(self._threads)
        for _ in threads:
            try:
                self._q.put_nowait(None)
            except queue.Full:
                break
        for t in threads:
            t.join(timeout)

    # ------------------------------------------------------------------ #
    def submit(self, snap, sink, order: Optional[Sequence[BlockRef]] = None) -> PersistJob:
        """Start persisting one epoch. Returns immediately; completion is
        signalled through ``snap.persist_done`` (and errors via
        ``snap.wait_persisted``), same contract as the old single persister."""
        job = PersistJob(snap, sink, order, on_finish=self._job_finished)
        if self.overlap:
            job._ring = queue.Queue(maxsize=self.ring_depth)
            job._writer = threading.Thread(
                target=self._write_lane, args=(job,), daemon=True)
            job._writer.start()
        with self._mu:
            self._active_jobs += 1
        self._ensure_workers()
        threading.Thread(target=self._produce, args=(job,), daemon=True).start()
        return job

    def _job_finished(self, job: PersistJob) -> None:
        with self._mu:
            self._active_jobs -= 1

    def _ensure_workers(self) -> None:
        with self._mu:
            self._threads = [t for t in self._threads if t.is_alive()]
            while len(self._threads) < self.workers:
                t = threading.Thread(target=self._worker, daemon=True)
                t.start()
                self._threads.append(t)

    # ------------------------------------------------------------------ #
    def _produce(self, job: PersistJob) -> None:
        """Open the sink, then feed the bounded queue (backpressure: a slow
        sink throttles staging exactly like the old sequential persister).

        The default (whole-table) order is coalesced leaf by leaf with
        :meth:`BlockTable.coalesce_runs` — adjacent blocks merge into runs
        capped at ``run_blocks``, breaking at inherited blocks and leaf
        boundaries, so a run always maps to one contiguous sink byte
        range. A caller-supplied custom order persists per-block (runs of
        one), since arbitrary orders need not be contiguous.
        """
        snap, sink = job.snap, job.sink
        try:
            sink.set_delta(snap.inherited)
            sink.open(snap.table.leaf_handles)
        except BaseException as exc:
            job.fail(exc)
            job._all_enqueued()
            return
        job.persist_start = time.perf_counter()

        def _runs():
            if job.order is not None:
                for ref in job.order:
                    if ref.key not in snap.inherited:
                        yield BlockRun(ref.leaf_id, ref.block_id, (ref,))
                return
            for h in snap.table.leaf_handles:
                yield from snap.table.coalesce_runs(
                    h.leaf_id, exclude=snap.inherited,
                    max_blocks=self.run_blocks,
                )

        for brun in _runs():
            if job.failed or snap.aborted:
                break
            job._run_enqueued()
            self._q.put((job, brun))
        job._all_enqueued()

    def _worker(self) -> None:
        me = threading.current_thread()
        while True:
            try:
                item = self._q.get(timeout=self.idle_timeout)
            except queue.Empty:
                item = None
            if item is None:  # idle timeout or shutdown sentinel
                with self._mu:
                    if self._active_jobs == 0 or self._stopping:
                        # Deregister BEFORE returning, atomically with the
                        # idle check: submit() increments _active_jobs under
                        # this same mutex, so it either sees us gone (and
                        # respawns) or we see its job (and keep running) —
                        # an exiting-but-alive thread can never absorb a
                        # worker slot while a job is pending.
                        if me in self._threads:
                            self._threads.remove(me)
                        return
                continue
            job, brun = item
            if job._ring is not None:
                self._stage_run(job, brun)
            else:
                self._persist_run(job, brun)

    # -- serial lane (overlap=False): stage + write in one worker ---------- #
    def _persist_run(self, job: PersistJob, brun: BlockRun) -> None:
        """The old persister's per-block body lifted to a run: take every
        block of the run through the normal staging flag machine (the
        child's shared-table read in CoW mode), then move the whole run
        with one gathered write — blocks stay individually locked during
        staging, only the data movement is batched (DESIGN.md §7)."""
        snap = job.snap
        try:
            arrays = self._stage_with_retry(job, brun)
            if arrays is not None:
                self._write_with_retry(job, brun, arrays)
                snap.table.mark_run(brun, BlockState.PERSISTED)
        except BaseException as exc:
            job.fail(exc)
        finally:
            job._run_finished()

    # -- stager lane (overlap=True): flag machine + D2H, hand to ring ------ #
    def _stage_run(self, job: PersistJob, brun: BlockRun) -> None:
        """Stager-lane half of a run: stage through the flag machine, drain
        the staged bytes to host arrays, and hand them to the job's ring.
        A stager-side failure finishes the run itself (the writer lane
        never sees it); otherwise the run's ``_run_finished`` is owed by
        the writer lane, which is why the ring put is safe — the writer
        cannot have received its shutdown sentinel while this run still
        holds a slot in the outstanding count."""
        snap = job.snap
        # Writer-lane backpressure without head-of-line blocking: with
        # several jobs in flight, a full ring on THIS job must not park
        # the shared stager in a blocking put while another job's writer
        # lane starves — rotate the run to the queue tail (positioned
        # writes make intra-job run order irrelevant) and serve whatever
        # is next. The 1ms pause bounds the spin when every live ring is
        # full; with a single job the blocking put below is the designed
        # memory throttle (ring_depth x run_blocks staged blocks).
        if job._ring.full() and self._active_jobs > 1:
            self._q.put((job, brun))
            time.sleep(0.001)
            return
        try:
            arrays = self._stage_with_retry(job, brun)
        except BaseException as exc:
            job.fail(exc)
            job._run_finished()
            return
        if arrays is None:      # epoch already failed/aborted: drain as no-op
            job._run_finished()
            return
        job._ring.put((brun, arrays))

    def _write_lane(self, job: PersistJob) -> None:
        """Per-job writer lane: drain the ring, one gathered sink write per
        staged run, until ``_finish`` pushes the ``None`` sentinel."""
        snap = job.snap
        while True:
            item = job._ring.get()
            if item is None:
                return
            brun, arrays = item
            try:
                if not (job.failed or snap.aborted):
                    self._write_with_retry(job, brun, arrays)
                    snap.table.mark_run(brun, BlockState.PERSISTED)
            except BaseException as exc:
                job.fail(exc)
            finally:
                job._run_finished()

    def _stage_with_retry(self, job: PersistJob, brun: BlockRun):
        """One run's staging under the :class:`RetryPolicy`: the flag
        machine is idempotent (already-COPIED blocks are skipped, the
        staged image is read-only after marking) and ``staged_run`` is a
        pure read, so a transient ``OSError`` — or the armed
        ``persist.stage`` fault, which fires BEFORE any trylock is taken —
        replays the whole attempt after a backoff. Returns the staged host
        arrays, or ``None`` when the epoch failed/aborted mid-run (the
        caller drains the run as a no-op). Stage wall time accumulates
        into ``metrics.stage_s``.

        Blocks the lane wins are staged in contiguous SPANS through
        ``stage_run`` — one kernel launch / memcpy per span instead of one
        per block (on device staging a per-block flag loop costs a whole
        kernel round-trip per block, which made worker-side staging the
        epoch's long pole). Spans break where a peer holds a block; those
        are waited out per block as before."""
        snap = job.snap
        table = snap.table
        attempt = 0
        t0 = time.perf_counter()
        snap.metrics.lane_enter("stage", t0)

        claimed: List[BlockRef] = []

        def _flush_claimed() -> None:
            if not claimed:
                return
            snap.stage_run(claimed)
            table.mark_run(
                BlockRun(brun.leaf_id, claimed[0].block_id, tuple(claimed)),
                BlockState.COPIED,
            )
            snap.metrics.copied_blocks_child += len(claimed)
            claimed.clear()

        def _release_claimed() -> None:
            # Abort/retry unwinding: claimed-but-unstaged blocks go back
            # to UNCOPIED (not COPIED — their content was never moved), so
            # peers waiting in wait_not_copying can't hang on a span this
            # attempt abandoned, and a replayed attempt can re-claim them.
            if not claimed:
                return
            table.mark_run(
                BlockRun(brun.leaf_id, claimed[0].block_id, tuple(claimed)),
                BlockState.UNCOPIED, count_done=False,
            )
            claimed.clear()

        try:
            while True:
                try:
                    _fire_fault("persist.stage",
                                f"leaf={brun.leaf_id}+{brun.start_block}",
                                self.faults)
                    for ref in brun.refs:
                        if job.failed or snap.aborted:
                            return None
                        st = table.state(ref.key)
                        if st == BlockState.UNCOPIED and \
                                table.try_acquire(ref.key):
                            # consecutive wins accumulate; the span stays
                            # contiguous because it flushes at every block
                            # we did NOT claim
                            claimed.append(ref)
                            continue
                        _flush_claimed()
                        while st in (BlockState.UNCOPIED, BlockState.COPYING):
                            if st == BlockState.UNCOPIED and \
                                    table.try_acquire(ref.key):
                                snap.stage_block(ref)
                                table.mark(ref.key, BlockState.COPIED)
                                snap.metrics.copied_blocks_child += 1
                                st = BlockState.COPIED
                                break
                            st = table.wait_not_copying(ref.key)
                    _flush_claimed()
                    if job.failed or snap.aborted:
                        return None
                    return snap.staged_run(brun.refs)
                except OSError:
                    _release_claimed()
                    delay = None if self.retry is None else \
                        self.retry.backoff(attempt)
                    if delay is None or job.failed or snap.aborted:
                        raise
                    attempt += 1
                    snap.metrics.record_persist_retry()
                    if delay:
                        time.sleep(delay)
        finally:
            _release_claimed()
            now = time.perf_counter()
            snap.metrics.lane_exit("stage", now)
            snap.metrics.record_stage(now - t0)

    def _write_with_retry(self, job: PersistJob, brun: BlockRun,
                          arrays) -> None:
        """One run's sink write under the :class:`RetryPolicy`: a
        transient ``OSError`` replays the whole run (positioned writes
        are idempotent — same offsets, same bytes) after a backoff, up to
        the policy's budget; anything else, or a spent budget, escalates
        to the existing epoch abort in the calling lane's handler.
        Writer-lane busy time accumulates into ``metrics.write_busy_s``."""
        snap, sink = job.snap, job.sink
        attempt = 0
        t0 = time.perf_counter()
        snap.metrics.lane_enter("write", t0)
        try:
            while True:
                try:
                    _fire_fault("persist.run",
                                f"leaf={brun.leaf_id}+{brun.start_block}",
                                self.faults)
                    # Bound-method identity, not class-attribute identity:
                    # a wrapper sink that delegates write_run via
                    # __getattr__/composition must keep run-capable
                    # detection, while a genuine write_block-only subclass
                    # (whose write_run IS the base stub) still demotes to
                    # per-block writes below.
                    if getattr(sink.write_run, "__func__", None) \
                            is Sink.write_run:
                        # write_block-only sink: per-block writes with the
                        # REAL refs (row geometry intact)
                        for ref, arr in zip(brun.refs, arrays):
                            sink.write_block(ref, arr)
                    else:
                        sink.write_run(brun.leaf_id, brun.start_block, arrays)
                    return
                except OSError:
                    delay = None if self.retry is None else \
                        self.retry.backoff(attempt)
                    if delay is None or job.failed or snap.aborted:
                        raise
                    attempt += 1
                    snap.metrics.record_persist_retry()
                    if delay:
                        time.sleep(delay)
        finally:
            now = time.perf_counter()
            snap.metrics.lane_exit("write", now)
            snap.metrics.record_write_busy(now - t0)
