"""Staging backends — where a snapshot epoch's T0 image physically lives.

The snapshot protocol (flag machine, proactive synchronization, persister)
is backend-agnostic; a ``StagingBackend`` owns only the data movement of
``stage_block`` and the layout of the staged image:

  * ``HostStaging``   — the original path: one host numpy buffer per leaf,
    blocks staged with a device->host memcpy under the provider leaf lock.
  * ``DeviceStaging`` — the T0 image stays on device as blocked
    ``jax.Array``s; each stage runs the Pallas ``snapcopy`` kernel with the
    ``BlockTable`` flag vector mirrored into the kernel's ``flags`` input,
    so blocks the parent already proactively copied are skipped inside the
    kernel — the device-level implementation of §4.2's "eliminating
    unnecessary synchronizations". On TPU this is an HBM->HBM copy that
    never round-trips through the host until a sink asks for bytes.

Both backends expose ``blocked_image`` (the (n_blocks, block_elems) layout
the ``dirty`` kernel compares across epochs) and ``adopt`` (inherit clean
blocks from the previous epoch's retained image — incremental snapshots).
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockRef, BlockState, BlockTable
from repro.core.provider import PyTreeProvider
from repro.kernels.ops import flags_to_device, snapcopy_op, to_blocked


def mirror_flags(table: BlockTable, leaf_id: int,
                 force_uncopied: Optional[int] = None) -> np.ndarray:
    """Mirror one leaf's BlockTable states into a kernel flag vector.

    One vectorized array copy under the table lock
    (:meth:`BlockTable.leaf_states`) — the seed looped ``table.state`` per
    block, paying O(n_blocks) lock round-trips per kernel launch.

    ``force_uncopied`` re-opens one block a caller holds in COPYING (the
    trylock) so the kernel won't skip it. ``DeviceStaging._stage_ids``
    forces its own (possibly multi-block) set instead; the parameter
    remains for single-block callers taking ad-hoc mirrors.
    """
    flags = table.leaf_states(leaf_id)
    if force_uncopied is not None:
        flags[force_uncopied] = int(BlockState.UNCOPIED)
    return flags


class StagingBackend:
    """Per-epoch T0 image storage + block copy mechanics."""

    name = "base"

    def __init__(self, table: BlockTable, provider: PyTreeProvider):
        self.table = table
        self.provider = provider

    def stage_block(self, ref: BlockRef) -> None:  # pragma: no cover
        raise NotImplementedError

    def stage_run(self, refs: Sequence[BlockRef]) -> None:
        """Stage a contiguous same-leaf run. Caller holds every block of
        the run in COPYING state. Default: per-block stages; both concrete
        backends override with one data movement per run (the run-aware
        proactive sync path, DESIGN.md §8)."""
        for r in refs:
            self.stage_block(r)

    def staged_block(self, ref: BlockRef):  # pragma: no cover
        raise NotImplementedError

    def staged_run(self, refs: Sequence[BlockRef]) -> list:
        """Staged content for a contiguous same-leaf run, one array per
        block. Default: per-block reads. ``DeviceStaging`` overrides with
        ONE batched D2H transfer for the whole run; the caller must have
        staged every block of the run first."""
        return [self.staged_block(r) for r in refs]

    def leaf_array(self, leaf_id: int) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def blocked_image(self, leaf_id: int):  # pragma: no cover
        raise NotImplementedError

    def adopt(self, leaf_id: int, prev_blocked,
              block_ids: Sequence[int]) -> None:  # pragma: no cover
        raise NotImplementedError


class HostStaging(StagingBackend):
    """Numpy staging buffers on the host (the seed implementation)."""

    name = "host"

    def __init__(self, table: BlockTable, provider: PyTreeProvider):
        super().__init__(table, provider)
        self._staging: Dict[int, np.ndarray] = {}
        self._lock = threading.Lock()

    def _leaf_staging(self, leaf_id: int) -> np.ndarray:
        with self._lock:
            buf = self._staging.get(leaf_id)
            if buf is None:
                h = self.table.leaf_handles[leaf_id]
                shape = h.shape if h.shape else (1,)
                buf = np.empty(shape, dtype=h.dtype)
                self._staging[leaf_id] = buf
        return buf

    def stage_block(self, ref: BlockRef) -> None:
        buf = self._leaf_staging(ref.leaf_id)
        if self.table.leaf_handles[ref.leaf_id].shape:
            self.provider.read_block_into(ref, buf[ref.start : ref.stop])
        else:
            self.provider.read_block_into(
                ref, buf[0:1].reshape(()) if buf.ndim else buf
            )

    def stage_run(self, refs: Sequence[BlockRef]) -> None:
        """One memcpy for the whole contiguous row range of the run —
        adjacent blocks occupy adjacent rows of the leaf and of the
        staging buffer, so a synthetic ref spanning the run reads it all
        in a single ``read_block_into``."""
        h = self.table.leaf_handles[refs[0].leaf_id]
        if len(refs) == 1 or not h.shape:
            for r in refs:
                self.stage_block(r)
            return
        buf = self._leaf_staging(refs[0].leaf_id)
        start, stop = refs[0].start, refs[-1].stop
        span = BlockRef(refs[0].leaf_id, refs[0].block_id, start, stop,
                        sum(r.nbytes for r in refs))
        self.provider.read_block_into(span, buf[start:stop])

    def staged_block(self, ref: BlockRef) -> np.ndarray:
        buf = self._staging[ref.leaf_id]
        h = self.table.leaf_handles[ref.leaf_id]
        return buf[ref.start : ref.stop] if h.shape else buf[0]

    def leaf_array(self, leaf_id: int) -> np.ndarray:
        h = self.table.leaf_handles[leaf_id]
        buf = self._staging.get(leaf_id)
        if buf is None:  # zero-block leaf
            buf = np.empty(h.shape if h.shape else (1,), dtype=h.dtype)
        return buf if h.shape else buf[0]

    def blocked_image(self, leaf_id: int) -> Optional[np.ndarray]:
        h = self.table.leaf_handles[leaf_id]
        g = h.geometry()
        if g is None or leaf_id not in self._staging:
            return None
        flat = np.ascontiguousarray(self._staging[leaf_id]).reshape(-1)
        pad = g.n_blocks * g.block_elems - flat.shape[0]
        if pad:
            flat = np.concatenate([flat, np.zeros((pad,), flat.dtype)])
        return flat.reshape(g.n_blocks, g.block_elems)

    def adopt(self, leaf_id: int, prev_blocked, block_ids: Sequence[int]) -> None:
        if not block_ids:
            return
        h = self.table.leaf_handles[leaf_id]
        g = h.geometry()
        buf = self._leaf_staging(leaf_id)
        pb = np.asarray(prev_blocked)
        for b in block_ids:
            ref = h.blocks[b]
            rows = ref.stop - ref.start
            if h.shape:
                buf[ref.start : ref.stop] = pb[b, : rows * g.row_elems].reshape(
                    (rows,) + h.shape[1:]
                )
            else:
                buf[0] = pb[b, 0]


class DeviceStaging(StagingBackend):
    """Blocked ``jax.Array`` staging driven by the ``snapcopy`` kernel.

    Each leaf's image is a (n_blocks, block_elems) device array; a stage is
    one kernel launch whose flag vector mirrors the BlockTable, with only
    the staged block forced open. The whole launch runs under the provider
    leaf lock so a donated update can neither free the source buffer
    mid-copy nor interleave with another stage of the same leaf (stages of
    one leaf are read-modify-write on its image).
    """

    name = "device"

    def __init__(self, table: BlockTable, provider: PyTreeProvider):
        super().__init__(table, provider)
        self._dst: Dict[int, jnp.ndarray] = {}
        self._staged: Dict[int, np.ndarray] = {}  # bool per block, in dst
        self._lock = threading.Lock()

    def _ensure(self, leaf_id: int):
        with self._lock:
            dst = self._dst.get(leaf_id)
            if dst is None:
                h = self.table.leaf_handles[leaf_id]
                g = h.geometry()
                dst = jnp.zeros((g.n_blocks, g.block_elems), dtype=h.dtype)
                self._dst[leaf_id] = dst
                self._staged[leaf_id] = np.zeros((g.n_blocks,), bool)
        return dst

    def stage_block(self, ref: BlockRef) -> None:
        self._stage_ids(ref.leaf_id, [ref.block_id])

    def stage_run(self, refs: Sequence[BlockRef]) -> None:
        """ONE snapcopy launch staging every block of the run — the
        run-aware proactive sync path: a large batched write's touched
        set costs one kernel round-trip instead of ``len(refs)``."""
        self._stage_ids(refs[0].leaf_id, [r.block_id for r in refs])

    def _stage_ids(self, leaf_id: int, block_ids: Sequence[int]) -> None:
        h = self.table.leaf_handles[leaf_id]
        g = h.geometry()
        self._ensure(leaf_id)
        ids = np.asarray(block_ids, dtype=np.int64)

        def _stage(leaf):
            # Blocks copied opportunistically by an earlier launch already
            # hold final T0 content (they were UNCOPIED under this same
            # lock when copied) — their official stage is then a no-op,
            # which makes total staging work O(leaf) instead of one
            # full-leaf kernel round-trip per block.
            want = ids[~self._staged[leaf_id][ids]]
            if want.size == 0:
                return
            # The flag mirror MUST be taken under the leaf lock: only there
            # does UNCOPIED provably imply live-content == T0 (a parent
            # write needs this same lock, and its proactive sync marks the
            # block before the donated update commits). A mirror taken
            # earlier could see a block as UNCOPIED that a peer has since
            # staged and the parent has since overwritten.
            host_flags = mirror_flags(self.table, leaf_id)
            # Blocks already sitting in dst (staged or opportunistically
            # copied on an earlier launch) are skipped: their content is
            # final T0, and recopying them every launch would make staging
            # O(n_blocks^2) in kernel copy work. The caller holds every
            # ``want`` block in COPYING — force those open for the kernel.
            already = self._staged[leaf_id]
            host_flags[already] = int(BlockState.COPIED)
            host_flags[want] = int(BlockState.UNCOPIED)
            src = to_blocked(leaf, g.n_blocks, g.block_elems)
            new_dst, _ = snapcopy_op(src, self._dst[leaf_id],
                                     flags_to_device(host_flags))
            new_dst.block_until_ready()  # copy must finish before unlock
            self._dst[leaf_id] = new_dst
            self._staged[leaf_id] |= host_flags == int(BlockState.UNCOPIED)

        self.provider.with_leaf(leaf_id, _stage)

    def staged_block(self, ref: BlockRef):
        h = self.table.leaf_handles[ref.leaf_id]
        g = h.geometry()
        blk = self._dst[ref.leaf_id][ref.block_id]
        if not h.shape:
            return blk[0]
        rows = ref.stop - ref.start
        return blk[: rows * g.row_elems].reshape((rows,) + h.shape[1:])

    def drain(self, leaf_id: int, start_block: int = 0,
              stop_block: Optional[int] = None) -> np.ndarray:
        """One batched D2H transfer of ``[start_block, stop_block)`` of the
        leaf's blocked image (the ROADMAP's device-staging persist path).

        Returns a host ``(stop - start, block_elems)`` array in the blocked
        layout. Only blocks the caller has staged hold T0 content — the
        persister drains exactly the runs it staged, so it never reads the
        zero-initialized remainder.
        """
        dst = self._dst.get(leaf_id)
        if dst is None:
            raise KeyError(f"leaf {leaf_id} has no staged device image")
        if stop_block is None:
            stop_block = dst.shape[0]
        return np.asarray(dst[start_block:stop_block])

    def staged_run(self, refs: Sequence[BlockRef]) -> list:
        """Run read = ONE D2H transfer via :meth:`drain`, then host-side
        views per block — instead of ``len(refs)`` single-block transfers
        issued by however many persist workers touch the leaf."""
        first = refs[0]
        h = self.table.leaf_handles[first.leaf_id]
        g = h.geometry()
        host = self.drain(first.leaf_id, first.block_id,
                          refs[-1].block_id + 1)
        out = []
        for i, ref in enumerate(refs):
            blk = host[i]
            if not h.shape:
                out.append(blk[0])
                continue
            rows = ref.stop - ref.start
            out.append(blk[: rows * g.row_elems].reshape((rows,) + h.shape[1:]))
        return out

    def leaf_array(self, leaf_id: int) -> np.ndarray:
        h = self.table.leaf_handles[leaf_id]
        g = h.geometry()
        if g is None or leaf_id not in self._dst:
            arr = np.empty(h.shape if h.shape else (1,), dtype=h.dtype)
            return arr if h.shape else arr[0]
        flat = np.asarray(self._dst[leaf_id]).reshape(-1)[: g.total_elems]
        return flat.reshape(h.shape) if h.shape else flat.reshape(())

    def blocked_image(self, leaf_id: int):
        return self._dst.get(leaf_id)

    def adopt(self, leaf_id: int, prev_blocked, block_ids: Sequence[int]) -> None:
        if not block_ids:
            return
        dst = self._ensure(leaf_id)
        idx = jnp.asarray(np.asarray(block_ids, np.int32))
        src = jnp.asarray(prev_blocked, dtype=dst.dtype)
        self._dst[leaf_id] = dst.at[idx].set(src[idx])
        self._staged[leaf_id][np.asarray(block_ids)] = True


STAGING_BACKENDS = {
    "host": HostStaging,
    "device": DeviceStaging,
}


def make_staging(name: str, table: BlockTable, provider: PyTreeProvider) -> StagingBackend:
    try:
        cls = STAGING_BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown staging backend {name!r}; pick from {sorted(STAGING_BACKENDS)}"
        )
    return cls(table, provider)
