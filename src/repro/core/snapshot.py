"""Snapshotters — default fork, ODF-style CoW fork, and Async-fork.

This is the paper's primary contribution rebuilt as a JAX state-snapshot
substrate (see DESIGN.md §2 for the full mapping). Three implementations
share one protocol:

  * ``BlockingSnapshotter``  — the default ``fork``: the parent copies every
    block synchronously inside ``fork()`` (§3.1: page-table copy dominates).
  * ``CowSnapshotter``       — the shared-page-table / On-Demand-Fork
    baseline (§3.2): ``fork()`` is O(metadata); the parent is interrupted by
    a synchronous block copy on its **first write to every block for the
    entire persist window** (tens of seconds).
  * ``AsyncForkSnapshotter`` — the paper (§4): ``fork()`` is O(metadata);
    a pool of copier threads (the child + kernel threads, §5.1) stages
    blocks in the background; the parent is interrupted only by *proactive
    synchronization* of blocks it writes **while the copier is still
    running** (hundreds of milliseconds).

Engine contract: call ``snapshotter.before_write(leaf_id, rows)`` before
every donated (destructive) update; take snapshots with ``fork()``.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import BlockRef, BlockState, BlockTable
from repro.core.metrics import SnapshotMetrics
from repro.core.provider import PyTreeProvider
from repro.core.sinks import Sink

import jax


class SnapshotError(RuntimeError):
    pass


class SnapshotHandle:
    """One in-flight snapshot epoch ("the child process")."""

    def __init__(self, table: BlockTable, provider: PyTreeProvider, mode: str):
        self.table = table
        self.provider = provider
        self.mode = mode
        self.metrics = SnapshotMetrics()
        self.error: Optional[BaseException] = None
        self.aborted = False
        self.t0 = time.perf_counter()
        self.copy_done = threading.Event()     # child finished PMD/PTE copy
        self.persist_done = threading.Event()  # snapshot durable ("RDB written")
        self._staging: Dict[int, np.ndarray] = {}
        self._staging_lock = threading.Lock()
        self._abort_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    # staging                                                            #
    # ------------------------------------------------------------------ #
    def _leaf_staging(self, leaf_id: int) -> np.ndarray:
        with self._staging_lock:
            buf = self._staging.get(leaf_id)
            if buf is None:
                h = self.table.leaf_handles[leaf_id]
                shape = h.shape if h.shape else (1,)
                buf = np.empty(shape, dtype=h.dtype)
                self._staging[leaf_id] = buf
        return buf

    def stage_block(self, ref: BlockRef) -> None:
        """Copy one block's T0 content into the snapshot's private staging.

        Caller must hold the block in COPYING state (the trylock). Errors
        propagate; the caller routes them into :meth:`abort` (§4.4).
        """
        buf = self._leaf_staging(ref.leaf_id)
        if self.table.leaf_handles[ref.leaf_id].shape:
            self.provider.read_block_into(ref, buf[ref.start : ref.stop])
        else:
            self.provider.read_block_into(ref, buf[0:1].reshape(()) if buf.ndim else buf)

    def staged_block(self, ref: BlockRef) -> np.ndarray:
        buf = self._staging[ref.leaf_id]
        h = self.table.leaf_handles[ref.leaf_id]
        return buf[ref.start : ref.stop] if h.shape else buf[0]

    # ------------------------------------------------------------------ #
    # parent-side proactive synchronization (§4.2)                        #
    # ------------------------------------------------------------------ #
    def _interruptible(self) -> bool:
        if self.aborted:
            return False
        if self.mode == "asyncfork" or self.mode == "blocking":
            return not self.copy_done.is_set()
        return not self.persist_done.is_set()  # cow: whole persist window

    def blocks_for_rows(self, leaf_id: int, rows) -> List[BlockRef]:
        handle = self.table.leaf_handles[leaf_id]
        if rows is None:
            return list(handle.blocks)
        if not handle.blocks:
            return []
        span = handle.blocks[0].stop - handle.blocks[0].start
        wanted = sorted({min(int(r) // span, len(handle.blocks) - 1) for r in rows})
        return [handle.blocks[b] for b in wanted]

    def sync_for_write(self, leaf_id: int, rows=None) -> Tuple[int, float]:
        """Proactively copy the to-be-modified blocks (parent side).

        Returns (blocks copied by the parent, stall seconds). Fast paths:
        snapshot aborted / outside the interruption window / the leaf's
        two-way pointer is closed (whole VMA already copied, §4.3).
        """
        if not self._interruptible():
            return 0, 0.0
        if self.table.leaf_done(leaf_id):
            return 0, 0.0
        t_start = time.perf_counter()
        copied = 0
        waited = False
        for ref in self.blocks_for_rows(leaf_id, rows):
            st = self.table.state(ref.key)
            if st in (BlockState.COPIED, BlockState.PERSISTED):
                continue
            if self.table.try_acquire(ref.key):
                try:
                    self.stage_block(ref)
                except BaseException as exc:  # §4.4 case 3
                    self.abort(exc, rollback_leaf=ref.leaf_id)
                    break
                self.table.mark(ref.key, BlockState.COPIED)
                copied += 1
            else:
                self.table.wait_not_copying(ref.key)
                waited = True
        dur = time.perf_counter() - t_start
        if copied or waited:
            self.metrics.record_interruption(t_start - self.t0, dur, copied)
        return copied, dur

    def complete_leaf(self, leaf_id: int) -> int:
        """§5.2 consecutive snapshots: parent finishes a whole VMA's copy."""
        copied, _ = self.sync_for_write(leaf_id, rows=None)
        return copied

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def abort(self, exc: BaseException, rollback_leaf: Optional[int] = None) -> None:
        """§4.4 error handling: drop all write protection, kill the child."""
        with self._abort_lock:
            if self.aborted:
                return
            self.aborted = True
            self.error = exc
        if rollback_leaf is not None:
            self.table.rollback_leaf(rollback_leaf)
            self.table.leaf_handles[rollback_leaf].twoway.set_error(exc)
        for h in self.table.leaf_handles:
            self.table.rollback_leaf(h.leaf_id)
            h.twoway.set_error(exc)
        self.copy_done.set()
        self.persist_done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        ok = self.copy_done.wait(timeout)
        if self.error is not None:
            raise SnapshotError(f"snapshot aborted: {self.error!r}") from self.error
        return ok

    def wait_persisted(self, timeout: Optional[float] = None) -> bool:
        ok = self.persist_done.wait(timeout)
        if self.error is not None:
            raise SnapshotError(f"snapshot aborted: {self.error!r}") from self.error
        return ok

    def materialize(self) -> None:
        """Stage every still-uncopied block (used by CoW mode with no
        persister, and by tests that want the full T0 image)."""
        for ref in self.table.blocks:
            if self.aborted:
                return
            st = self.table.state(ref.key)
            while st in (BlockState.UNCOPIED, BlockState.COPYING):
                if st == BlockState.UNCOPIED and self.table.try_acquire(ref.key):
                    try:
                        self.stage_block(ref)
                    except BaseException as exc:
                        self.abort(exc)
                        return
                    self.table.mark(ref.key, BlockState.COPIED)
                    self.metrics.copied_blocks_child += 1  # ODF child read
                    break
                st = self.table.wait_not_copying(ref.key)

    def finish(self) -> None:
        """Close a manual (sink-less) snapshot window: materialize + seal."""
        self.materialize()
        if not self.copy_done.is_set():
            self.metrics.copy_window_s = time.perf_counter() - self.t0
            self.copy_done.set()
        if not self.persist_done.is_set():
            self.metrics.persist_s = time.perf_counter() - self.t0
            self.persist_done.set()

    def to_tree(self):
        """Reassemble the T0 pytree from staging (host numpy leaves)."""
        if self.mode == "cow" and not self.persist_done.is_set():
            self.finish()
        self.wait()
        leaves = []
        for h in self.table.leaf_handles:
            buf = self._staging.get(h.leaf_id)
            if buf is None:  # zero-block leaf
                buf = np.empty(h.shape if h.shape else (1,), dtype=h.dtype)
            leaves.append(buf if h.shape else buf[0])
        return jax.tree_util.tree_unflatten(self.table.treedef, leaves)

    @property
    def ok(self) -> bool:
        return not self.aborted


def _persister(snap: SnapshotHandle, sink: Sink, order: Sequence[BlockRef]) -> None:
    """The child's IO loop: ensure each block is staged, then write it out.

    In CoW mode this thread *is* what keeps the snapshot window open: a
    block that the parent never writes is staged here (ODF's child reading
    the shared table) right before persisting.
    """
    try:
        sink.open(snap.table.leaf_handles)
        for ref in order:
            if snap.aborted:
                sink.abort()
                return
            st = snap.table.state(ref.key)
            while st == BlockState.UNCOPIED or st == BlockState.COPYING:
                if st == BlockState.UNCOPIED and snap.table.try_acquire(ref.key):
                    snap.stage_block(ref)
                    snap.table.mark(ref.key, BlockState.COPIED)
                    snap.metrics.copied_blocks_child += 1  # child's shared read
                    st = BlockState.COPIED
                    break
                st = snap.table.wait_not_copying(ref.key)
            if snap.aborted:
                sink.abort()
                return
            sink.write_block(ref, snap.staged_block(ref))
            snap.table.mark(ref.key, BlockState.PERSISTED)
        sink.close()
        snap.metrics.persist_s = time.perf_counter() - snap.t0
    except BaseException as exc:
        snap.abort(exc)
        sink.abort()
    finally:
        snap.persist_done.set()


class Snapshotter:
    """Factory + registry for snapshot epochs over one engine state.

    ``block_bytes`` is the copy granularity ("512 PTEs"); ``copier_threads``
    maps to the paper's child-side kernel threads (§5.1, Figs 14/15).
    """

    mode = "base"

    def __init__(
        self,
        provider: PyTreeProvider,
        block_bytes: int = 4 << 20,
        copier_threads: int = 1,
        yield_every: int = 1,
        copier_duty: float = 1.0,
    ):
        """``copier_duty`` < 1 throttles child-side copier threads to that
        fraction of a core. On a single-core host (this container) the
        paper's assumption — the child copies on *idle* cores while the
        parent serves — does not hold; a duty cycle emulates the dedicated
        core by stretching the copy window instead of starving the parent.
        Set to 1.0 on multi-core hosts. (See DESIGN.md §2, changed
        assumptions.)"""
        self.provider = provider
        self.block_bytes = int(block_bytes)
        self.copier_threads = int(copier_threads)
        self.yield_every = int(yield_every)
        self.copier_duty = float(copier_duty)
        self._active: List[SnapshotHandle] = []
        self._active_lock = threading.Lock()
        self.forks = 0

    # -- engine-facing ---------------------------------------------------
    def before_write(self, leaf_id: int, rows=None) -> float:
        """Proactive synchronization hook. Returns stall seconds."""
        total = 0.0
        for snap in self.active():
            _, dur = snap.sync_for_write(leaf_id, rows)
            total += dur
        return total

    def active(self) -> List[SnapshotHandle]:
        with self._active_lock:
            return [
                s
                for s in self._active
                if not (s.copy_done.is_set() and s.persist_done.is_set())
            ]

    def _register(self, snap: SnapshotHandle) -> None:
        with self._active_lock:
            self._active = [
                s for s in self._active
                if not (s.copy_done.is_set() and s.persist_done.is_set())
            ]
            self._active.append(snap)

    def _serialize_previous(self) -> None:
        """§5.2: one child per VMA at a time — the parent proactively
        completes any previous in-flight copy before the next fork."""
        for prev in self.active():
            if not prev.copy_done.is_set():
                for h in prev.table.leaf_handles:
                    if not prev.table.leaf_done(h.leaf_id):
                        prev.complete_leaf(h.leaf_id)

    # -- implemented by subclasses ----------------------------------------
    def fork(self, sink: Optional[Sink] = None) -> SnapshotHandle:  # pragma: no cover
        raise NotImplementedError


class BlockingSnapshotter(Snapshotter):
    """The default ``fork``: parent copies the whole "page table" inline."""

    mode = "blocking"

    def fork(self, sink: Optional[Sink] = None) -> SnapshotHandle:
        t0 = time.perf_counter()
        self._serialize_previous()
        table = BlockTable(self.provider.tree(), self.block_bytes)
        snap = SnapshotHandle(table, self.provider, self.mode)
        for ref in table.blocks:  # synchronous level-by-level copy (§3.1)
            if table.try_acquire(ref.key):
                try:
                    snap.stage_block(ref)
                except BaseException as exc:
                    snap.abort(exc)
                    raise SnapshotError("fork failed") from exc
                table.mark(ref.key, BlockState.COPIED)
        snap.metrics.copied_blocks_child = table.n_blocks
        snap.copy_done.set()
        snap.metrics.fork_s = time.perf_counter() - t0
        snap.metrics.copy_window_s = snap.metrics.fork_s
        self.forks += 1
        self._register(snap)
        self._start_persist(snap, sink)
        return snap

    def _start_persist(self, snap: SnapshotHandle, sink: Optional[Sink]) -> None:
        if sink is None:
            snap.persist_done.set()
            snap.metrics.persist_s = snap.metrics.fork_s
            return
        threading.Thread(
            target=_persister, args=(snap, sink, snap.table.blocks), daemon=True
        ).start()


class CowSnapshotter(Snapshotter):
    """Shared-page-table (ODF) model: zero-cost fork, CoW faults in the
    parent for the whole persist window (§3.2, Table 1 discussion)."""

    mode = "cow"

    def fork(self, sink: Optional[Sink] = None) -> SnapshotHandle:
        t0 = time.perf_counter()
        self._serialize_previous()
        table = BlockTable(self.provider.tree(), self.block_bytes)
        snap = SnapshotHandle(table, self.provider, self.mode)
        snap.copy_done.set()  # no child-side table copy at all
        snap.metrics.fork_s = time.perf_counter() - t0
        self.forks += 1
        self._register(snap)
        if sink is not None:
            threading.Thread(
                target=_persister, args=(snap, sink, snap.table.blocks), daemon=True
            ).start()
        # with sink=None the CoW window stays open until snap.finish()
        return snap


class AsyncForkSnapshotter(Snapshotter):
    """The paper: metadata-only fork + child-side parallel copy +
    proactive synchronization in the parent (§4, §5.1)."""

    mode = "asyncfork"

    def fork(self, sink: Optional[Sink] = None) -> SnapshotHandle:
        t0 = time.perf_counter()
        self._serialize_previous()
        # Parent copies PGD/PUD (tree metadata) and write-protects PMDs
        # (flag init) — this is ALL the parent does inside fork().
        table = BlockTable(self.provider.tree(), self.block_bytes)
        snap = SnapshotHandle(table, self.provider, self.mode)
        self.forks += 1
        self._register(snap)
        snap.metrics.fork_s = time.perf_counter() - t0

        # cond_resched() analogue at the interpreter level: don't let a
        # copier hold the GIL for the default 5 ms while the parent serves.
        if sys.getswitchinterval() > 1e-3:
            sys.setswitchinterval(5e-4)

        n = max(1, self.copier_threads)
        shards = [table.blocks[i::n] for i in range(n)]
        pending = [threading.Event() for _ in range(n)]

        duty = min(1.0, max(0.01, self.copier_duty))

        def copier(shard: List[BlockRef], done_evt: threading.Event) -> None:
            # "the child process copies PMD entries and PTEs" (Alg. 1, L15-24)
            # Debt-based duty throttle: accumulate busy time, pay it back in
            # >=2ms sleeps so syscall overhead doesn't stretch the window.
            busy = 0.0
            slept = 0.0
            try:
                for i, ref in enumerate(shard):
                    if snap.aborted:
                        return
                    if self.yield_every and i % self.yield_every == 0:
                        time.sleep(0)  # cond_resched()
                    if not table.try_acquire(ref.key):
                        continue  # parent proactively copied it already
                    t_blk = time.perf_counter()
                    snap.stage_block(ref)
                    table.mark(ref.key, BlockState.COPIED)
                    snap.metrics.copied_blocks_child += 1
                    busy += time.perf_counter() - t_blk
                    if duty < 1.0:  # dedicated-core emulation
                        debt = busy * (1.0 - duty) / duty - slept
                        if debt > 2e-3:
                            time.sleep(debt)
                            slept += debt
                # straggler mitigation: finished copiers steal leftover
                # blocks from slower shards (trylock makes this race-free)
                for ref in table.blocks:
                    if snap.aborted:
                        return
                    if table.state(ref.key) == BlockState.UNCOPIED and \
                            table.try_acquire(ref.key):
                        snap.stage_block(ref)
                        table.mark(ref.key, BlockState.COPIED)
                        snap.metrics.copied_blocks_child += 1
            except BaseException as exc:  # §4.4 case 2 (SIGKILL the child)
                snap.abort(exc)
            finally:
                done_evt.set()
                if all(e.is_set() for e in pending):
                    snap.metrics.copy_window_s = time.perf_counter() - snap.t0
                    snap.copy_done.set()

        for shard, evt in zip(shards, pending):
            threading.Thread(target=copier, args=(shard, evt), daemon=True).start()

        if sink is None:
            def _mark_persisted():
                snap.copy_done.wait()
                snap.metrics.persist_s = time.perf_counter() - snap.t0
                snap.persist_done.set()
            threading.Thread(target=_mark_persisted, daemon=True).start()
        else:
            threading.Thread(
                target=_persister, args=(snap, sink, snap.table.blocks), daemon=True
            ).start()
        return snap


SNAPSHOTTERS = {
    "blocking": BlockingSnapshotter,
    "cow": CowSnapshotter,
    "asyncfork": AsyncForkSnapshotter,
}


def make_snapshotter(mode: str, provider: PyTreeProvider, **kw) -> Snapshotter:
    try:
        cls = SNAPSHOTTERS[mode]
    except KeyError:
        raise ValueError(f"unknown snapshotter mode {mode!r}; pick from {sorted(SNAPSHOTTERS)}")
    return cls(provider, **kw)
