"""Snapshotters — default fork, ODF-style CoW fork, and Async-fork.

This is the paper's primary contribution rebuilt as a JAX state-snapshot
substrate (see DESIGN.md §2 for the full mapping). Three implementations
share one protocol:

  * ``BlockingSnapshotter``  — the default ``fork``: the parent copies every
    block synchronously inside ``fork()`` (§3.1: page-table copy dominates).
  * ``CowSnapshotter``       — the shared-page-table / On-Demand-Fork
    baseline (§3.2): ``fork()`` is O(metadata); the parent is interrupted by
    a synchronous block copy on its **first write to every block for the
    entire persist window** (tens of seconds).
  * ``AsyncForkSnapshotter`` — the paper (§4): ``fork()`` is O(metadata);
    a pool of copier threads (the child + kernel threads, §5.1) stages
    blocks in the background; the parent is interrupted only by *proactive
    synchronization* of blocks it writes **while the copier is still
    running** (hundreds of milliseconds).

Engine contract: call ``snapshotter.before_write(leaf_id, rows)`` before
every donated (destructive) update; take snapshots with ``fork()``.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import List, Optional, Tuple

from repro.core.blocks import BlockRef, BlockState, BlockTable, coalesce_refs
from repro.core.metrics import SnapshotMetrics
from repro.core.persist import PersistPipeline
from repro.core.provider import PyTreeProvider
from repro.core.sinks import Sink
from repro.core.staging import HostStaging, StagingBackend, make_staging
from repro.kernels.ops import dirty_op, flags_from_device, to_blocked

import jax
import jax.numpy as jnp


class SnapshotError(RuntimeError):
    pass


class SnapshotHandle:
    """One in-flight snapshot epoch ("the child process")."""

    def __init__(
        self,
        table: BlockTable,
        provider: PyTreeProvider,
        mode: str,
        backend: Optional[StagingBackend] = None,
    ):
        self.table = table
        self.provider = provider
        self.mode = mode
        self.backend = backend if backend is not None else HostStaging(table, provider)
        self.metrics = SnapshotMetrics()
        self.error: Optional[BaseException] = None
        self.aborted = False
        self.t0 = time.perf_counter()
        self.fork_start = self.t0  # overwritten by Snapshotter.fork() entry
        self.inherited: set = set()  # block keys carried from the base epoch
        self.copy_done = threading.Event()     # child finished PMD/PTE copy
        self.persist_done = threading.Event()  # snapshot durable ("RDB written")
        self._abort_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    # staging (delegated to the pluggable backend)                       #
    # ------------------------------------------------------------------ #
    def stage_block(self, ref: BlockRef) -> None:
        """Copy one block's T0 content into the snapshot's private staging.

        Caller must hold the block in COPYING state (the trylock). Errors
        propagate; the caller routes them into :meth:`abort` (§4.4).
        """
        self.backend.stage_block(ref)

    def staged_block(self, ref: BlockRef):
        """Staged content of one block — host numpy (HostStaging) or a
        device array (DeviceStaging); sinks accept either."""
        return self.backend.staged_block(ref)

    def staged_run(self, refs):
        """Staged content of a contiguous same-leaf run, one array per
        block. Device staging services the whole run with one batched D2H
        transfer (``DeviceStaging.drain``); every block must already be
        staged (COPIED or later)."""
        return self.backend.staged_run(refs)

    def stage_run(self, refs) -> None:
        """Stage a contiguous same-leaf run in one data movement (one
        kernel launch on device staging, one memcpy on host staging).

        Caller must hold EVERY block of the run in COPYING state — the
        run-granular proactive sync acquires all its trylocks first, then
        moves the data once (DESIGN.md §8, run-aware proactive sync)."""
        self.backend.stage_run(refs)

    # ------------------------------------------------------------------ #
    # parent-side proactive synchronization (§4.2)                        #
    # ------------------------------------------------------------------ #
    def _interruptible(self) -> bool:
        if self.aborted:
            return False
        if self.mode == "asyncfork" or self.mode == "blocking":
            return not self.copy_done.is_set()
        return not self.persist_done.is_set()  # cow: whole persist window

    def blocks_for_rows(self, leaf_id: int, rows) -> List[BlockRef]:
        handle = self.table.leaf_handles[leaf_id]
        if rows is None:
            return list(handle.blocks)
        if not handle.blocks:
            return []
        span = handle.blocks[0].stop - handle.blocks[0].start
        wanted = sorted({min(int(r) // span, len(handle.blocks) - 1) for r in rows})
        return [handle.blocks[b] for b in wanted]

    def sync_for_write(self, leaf_id: int, rows=None) -> Tuple[int, float]:
        """Proactively copy the to-be-modified blocks (parent side).

        Returns (blocks copied by the parent, stall seconds). Fast paths:
        snapshot aborted / outside the interruption window / the leaf's
        two-way pointer is closed (whole VMA already copied, §4.3).
        """
        if not self._interruptible():
            return 0, 0.0
        if self.table.leaf_done(leaf_id):
            return 0, 0.0
        t_start = time.perf_counter()
        copied = 0
        waited = False
        # Run-aware sync: win every trylock first, then coalesce the won
        # blocks into contiguous runs and move each run with ONE staging
        # operation (one kernel launch / one memcpy) instead of per-block
        # round trips. Protection-state transitions stay per-block (each
        # trylock is individual; a concurrent copier that beat us to a
        # block simply keeps it), so the §5 invariant is untouched — only
        # the data movement is batched.
        acquired: List[BlockRef] = []
        busy: List[BlockRef] = []
        for ref in self.blocks_for_rows(leaf_id, rows):
            st = self.table.state(ref.key)
            if st in (BlockState.COPIED, BlockState.PERSISTED):
                continue
            if self.table.try_acquire(ref.key):
                acquired.append(ref)
            else:
                busy.append(ref)
        for run in coalesce_refs(acquired):
            try:
                self.stage_run(run.refs)
            except BaseException as exc:  # §4.4 case 3
                self.abort(exc, rollback_leaf=leaf_id)
                dur = time.perf_counter() - t_start
                self.metrics.record_interruption(t_start - self.t0, dur, copied)
                return copied, dur
            self.table.mark_run(run, BlockState.COPIED)
            copied += len(run.refs)
        for ref in busy:
            self.table.wait_not_copying(ref.key)
            waited = True
        dur = time.perf_counter() - t_start
        if copied or waited:
            self.metrics.record_interruption(t_start - self.t0, dur, copied)
        return copied, dur

    def complete_leaf(self, leaf_id: int) -> int:
        """§5.2 consecutive snapshots: parent finishes a whole VMA's copy."""
        copied, _ = self.sync_for_write(leaf_id, rows=None)
        return copied

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def abort(self, exc: BaseException, rollback_leaf: Optional[int] = None) -> None:
        """§4.4 error handling: drop all write protection, kill the child."""
        with self._abort_lock:
            if self.aborted:
                return
            self.aborted = True
            self.error = exc
        if rollback_leaf is not None:
            self.table.rollback_leaf(rollback_leaf)
            self.table.leaf_handles[rollback_leaf].twoway.set_error(exc)
        for h in self.table.leaf_handles:
            self.table.rollback_leaf(h.leaf_id)
            h.twoway.set_error(exc)
        self.copy_done.set()
        self.persist_done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        ok = self.copy_done.wait(timeout)
        if self.error is not None:
            raise SnapshotError(f"snapshot aborted: {self.error!r}") from self.error
        return ok

    def wait_persisted(self, timeout: Optional[float] = None) -> bool:
        ok = self.persist_done.wait(timeout)
        if self.error is not None:
            raise SnapshotError(f"snapshot aborted: {self.error!r}") from self.error
        return ok

    def materialize(self) -> None:
        """Stage every still-uncopied block (used by CoW mode with no
        persister, and by tests that want the full T0 image)."""
        for ref in self.table.blocks:
            if self.aborted:
                return
            st = self.table.state(ref.key)
            while st in (BlockState.UNCOPIED, BlockState.COPYING):
                if st == BlockState.UNCOPIED and self.table.try_acquire(ref.key):
                    try:
                        self.stage_block(ref)
                    except BaseException as exc:
                        self.abort(exc)
                        return
                    self.table.mark(ref.key, BlockState.COPIED)
                    self.metrics.copied_blocks_child += 1  # ODF child read
                    break
                st = self.table.wait_not_copying(ref.key)

    def finish(self) -> None:
        """Close a manual (sink-less) snapshot window: materialize + seal."""
        self.materialize()
        if not self.copy_done.is_set():
            self.metrics.copy_window_s = time.perf_counter() - self.t0
            self.copy_done.set()
        if not self.persist_done.is_set():
            self.metrics.persist_s = time.perf_counter() - self.t0
            self.persist_done.set()

    def to_tree(self):
        """Reassemble the T0 pytree from staging (host numpy leaves)."""
        if self.mode == "cow" and not self.persist_done.is_set():
            self.finish()
        self.wait()
        leaves = [self.backend.leaf_array(h.leaf_id) for h in self.table.leaf_handles]
        return jax.tree_util.tree_unflatten(self.table.treedef, leaves)

    @property
    def ok(self) -> bool:
        return not self.aborted


class Snapshotter:
    """Factory + registry for snapshot epochs over one engine state.

    ``block_bytes`` is the copy granularity ("512 PTEs"); ``copier_threads``
    maps to the paper's child-side kernel threads (§5.1, Figs 14/15).

    The persister ("the child writing the RDB file") lives in
    :mod:`repro.core.persist`: every sink-backed epoch is submitted to a
    :class:`PersistPipeline`. ``persist_workers=1`` (the default) is the
    paper's single sequential writer; more workers write blocks out of
    order in parallel (the sharded coordinator shares one pipeline across
    shards by assigning :attr:`persist_pipeline`).
    """

    mode = "base"

    def __init__(
        self,
        provider: PyTreeProvider,
        block_bytes: int = 4 << 20,
        copier_threads: int = 1,
        yield_every: int = 1,
        copier_duty: float = 1.0,
        backend: str = "host",
        retain_images: bool = False,
        persist_workers: int = 1,
        persist_queue_depth: int = 64,
    ):
        """``copier_duty`` < 1 throttles child-side copier threads to that
        fraction of a core. On a single-core host (this container) the
        paper's assumption — the child copies on *idle* cores while the
        parent serves — does not hold; a duty cycle emulates the dedicated
        core by stretching the copy window instead of starving the parent.
        Set to 1.0 on multi-core hosts. (See DESIGN.md §2, changed
        assumptions.)

        ``backend`` picks where the T0 image is staged ("host" numpy
        buffers or "device" blocked jax.Arrays driven by the Pallas
        snapcopy kernel). ``retain_images`` keeps a reference to the most
        recent epoch so ``fork(incremental=True)`` can diff against it."""
        self.provider = provider
        self.block_bytes = int(block_bytes)
        self.copier_threads = int(copier_threads)
        self.yield_every = int(yield_every)
        self.copier_duty = float(copier_duty)
        self.backend = backend
        self.retain_images = bool(retain_images)
        self.persist_workers = max(1, int(persist_workers))
        self.persist_queue_depth = int(persist_queue_depth)
        self.persist_pipeline: Optional[PersistPipeline] = None  # lazy/injected
        self._last_snap: Optional[SnapshotHandle] = None
        self._active: List[SnapshotHandle] = []
        self._active_lock = threading.Lock()
        self.forks = 0

    def _pipeline(self) -> PersistPipeline:
        if self.persist_pipeline is None:
            self.persist_pipeline = PersistPipeline(
                workers=self.persist_workers, queue_depth=self.persist_queue_depth
            )
        return self.persist_pipeline

    # -- retained-base lifecycle (incremental diffs / policy skips) -------
    def retained_base(self) -> Optional[SnapshotHandle]:
        """The epoch retained as the next incremental diff base, or None.
        Owned here so policy layers need not reach into ``_last_snap``."""
        return self._last_snap if self.retain_images else None

    def drop_retained(self) -> None:
        """Forget the retained base: the next ``fork(incremental=True)``
        degrades to a full epoch. Call when the provider's state was
        replaced out-of-band (a restore) and the image no longer describes
        anything reachable."""
        self._last_snap = None

    # -- engine-facing ---------------------------------------------------
    def before_write(self, leaf_id: int, rows=None) -> float:
        """Proactive synchronization hook. Returns stall seconds."""
        total = 0.0
        for snap in self.active():
            _, dur = snap.sync_for_write(leaf_id, rows)
            total += dur
        return total

    def note_gate_wait(self, wait_s: float) -> None:
        """Charge one write's CONTENDED gate-acquisition wait to the
        newest in-flight epoch's metrics. Under striped gates a writer
        only waits when its OWN shard's stripe is contended (a barrier, a
        layout swap, or another writer on the same shard) — recording it
        next to the proactive-sync stalls makes gate contention
        observable in the same per-epoch summaries (``gate_wait_us``).
        One wall-clock wait is one epoch's record: charging every active
        epoch would multiply-count the same stall whenever consecutive
        snapshots overlap, unlike interruptions (distinct per-epoch sync
        work that legitimately sums)."""
        snaps = self.active()
        if snaps:
            snaps[-1].metrics.record_gate_wait(wait_s)

    def note_read_event(self, retries: int, shared_wait_s: float) -> None:
        """Charge one read's seqlock churn (fast-path retries and any
        shared-stripe fallback wait) to the newest in-flight epoch, under
        the same single-epoch convention as :meth:`note_gate_wait`."""
        snaps = self.active()
        if snaps:
            snaps[-1].metrics.record_read_event(retries, shared_wait_s)

    def active(self) -> List[SnapshotHandle]:
        with self._active_lock:
            return [
                s
                for s in self._active
                if not (s.copy_done.is_set() and s.persist_done.is_set())
            ]

    def _register(self, snap: SnapshotHandle) -> None:
        with self._active_lock:
            self._active = [
                s for s in self._active
                if not (s.copy_done.is_set() and s.persist_done.is_set())
            ]
            self._active.append(snap)

    def _serialize_previous(self) -> None:
        """§5.2: one child per VMA at a time — the parent proactively
        completes any previous in-flight copy before the next fork."""
        for prev in self.active():
            if not prev.copy_done.is_set():
                for h in prev.table.leaf_handles:
                    if not prev.table.leaf_done(h.leaf_id):
                        prev.complete_leaf(h.leaf_id)

    # -- shared fork machinery ---------------------------------------------
    def _begin(
        self,
        fork_start: float,
        incremental: bool = False,
        base: Optional[SnapshotHandle] = None,
    ) -> SnapshotHandle:
        """Common fork prologue: serialize the previous epoch, build the
        block table + staging backend, and (incremental) mark clean blocks
        PERSISTED so neither copier nor persister ever touches them."""
        self._serialize_previous()
        table = BlockTable(self.provider.tree(), self.block_bytes)
        snap = SnapshotHandle(
            table, self.provider, self.mode,
            backend=make_staging(self.backend, table, self.provider),
        )
        snap.fork_start = fork_start
        snap.metrics.total_blocks = table.n_blocks
        snap.metrics.policy_mode = "delta" if incremental else "full"
        if incremental:
            self._mark_clean_blocks(snap, base or self._last_snap)
        return snap

    def _finish_fork(self, snap: SnapshotHandle) -> None:
        self.forks += 1
        self._register(snap)
        if self.retain_images:
            self._last_snap = snap

    def _mark_clean_blocks(
        self, snap: SnapshotHandle, base: Optional[SnapshotHandle]
    ) -> None:
        """Incremental epoch: run the ``dirty`` kernel against the base
        epoch's retained T0 image and adopt every unchanged block.

        Clean blocks are marked PERSISTED at fork time — the strongest
        flag, so the parent never proactively syncs them, the copier's
        trylock never wins them, and the persister skips them (they go
        into the sink's delta manifest instead). A missing/aborted base or
        a geometry mismatch degrades to a full snapshot for that leaf.
        """
        if base is None or base.aborted:
            return
        # The base image must be fully staged before we can diff against
        # it. An incomplete or failed base image (timeout / abort) would
        # diff against uninitialized staging memory, so any such epoch
        # degrades to a full snapshot instead. A cow base only finishes
        # staging when its sink-paced persist window closes — waiting for
        # that here would stall fork() (the serving thread) for the whole
        # window, so a still-persisting cow base also degrades to full
        # rather than blocking.
        if base.mode == "cow":
            if not base.persist_done.is_set() or base.error is not None:
                return
        else:
            try:
                if not base.wait(600):
                    return
            except SnapshotError:
                return
        for h in snap.table.leaf_handles:
            g = h.geometry()
            if g is None:
                continue
            if h.leaf_id >= len(base.table.leaf_handles):
                continue
            bh = base.table.leaf_handles[h.leaf_id]
            bg = bh.geometry()
            if (
                bg is None
                or not g.matches(bg)
                or bh.shape != h.shape
                or bh.dtype != h.dtype
                or bh.path != h.path
            ):
                continue  # reshaped leaf: every block is dirty
            prev = base.backend.blocked_image(h.leaf_id)
            if prev is None:
                continue
            prev_dev = jnp.asarray(prev)
            cur = self.provider.with_leaf(
                h.leaf_id,
                lambda leaf: to_blocked(leaf, g.n_blocks, g.block_elems),
            )
            dirty = flags_from_device(dirty_op(prev_dev, cur))
            clean_ids = [b for b in range(g.n_blocks) if not dirty[b]]
            if not clean_ids:
                continue
            snap.backend.adopt(h.leaf_id, prev, clean_ids)
            for b in clean_ids:
                ref = h.blocks[b]
                snap.table.mark(ref.key, BlockState.PERSISTED)
                snap.inherited.add(ref.key)
            snap.metrics.inherited_blocks += len(clean_ids)

    # -- two-phase fork ----------------------------------------------------
    def fork_prepare(
        self,
        incremental: bool = False,
        base: Optional[SnapshotHandle] = None,
    ) -> SnapshotHandle:
        """Phase 1 ("stamp T0"): serialize the previous epoch, build the
        write-protected block table, register the epoch. After this call
        every parent write routes through proactive synchronization, but no
        copier or persister has started — the sharded coordinator prepares
        ALL shards before committing any, so the union of shard images is a
        single point-in-time cut (DESIGN.md §6)."""
        snap = self._begin(time.perf_counter(), incremental, base)
        self._finish_fork(snap)
        return snap

    def fork_commit(
        self, snap: SnapshotHandle, sink: Optional[Sink] = None
    ) -> SnapshotHandle:  # pragma: no cover
        """Phase 2: mode-specific copy/copier launch + persist start."""
        raise NotImplementedError

    def fork(
        self,
        sink: Optional[Sink] = None,
        incremental: bool = False,
        base: Optional[SnapshotHandle] = None,
    ) -> SnapshotHandle:
        return self.fork_commit(self.fork_prepare(incremental, base), sink)


class BlockingSnapshotter(Snapshotter):
    """The default ``fork``: parent copies the whole "page table" inline."""

    mode = "blocking"

    def fork_commit(
        self, snap: SnapshotHandle, sink: Optional[Sink] = None
    ) -> SnapshotHandle:
        table = snap.table
        for ref in table.blocks:  # synchronous level-by-level copy (§3.1)
            if table.try_acquire(ref.key):
                try:
                    snap.stage_block(ref)
                except BaseException as exc:
                    snap.abort(exc)
                    raise SnapshotError("fork failed") from exc
                table.mark(ref.key, BlockState.COPIED)
                snap.metrics.copied_blocks_child += 1
        if not snap.aborted:  # lost trylocks: wait the holder's stage out
            table.wait_all_not_copying()
        snap.copy_done.set()
        snap.metrics.fork_s = time.perf_counter() - snap.fork_start
        snap.metrics.copy_window_s = snap.metrics.fork_s
        self._start_persist(snap, sink)
        return snap

    def _start_persist(self, snap: SnapshotHandle, sink: Optional[Sink]) -> None:
        if sink is None:
            snap.persist_done.set()
            snap.metrics.persist_s = snap.metrics.fork_s
            return
        self._pipeline().submit(snap, sink)


class CowSnapshotter(Snapshotter):
    """Shared-page-table (ODF) model: zero-cost fork, CoW faults in the
    parent for the whole persist window (§3.2, Table 1 discussion)."""

    mode = "cow"

    def fork_commit(
        self, snap: SnapshotHandle, sink: Optional[Sink] = None
    ) -> SnapshotHandle:
        snap.copy_done.set()  # no child-side table copy at all
        snap.metrics.fork_s = time.perf_counter() - snap.fork_start
        if sink is not None:
            self._pipeline().submit(snap, sink)
        # with sink=None the CoW window stays open until snap.finish()
        return snap


class AsyncForkSnapshotter(Snapshotter):
    """The paper: metadata-only fork + child-side parallel copy +
    proactive synchronization in the parent (§4, §5.1)."""

    mode = "asyncfork"

    def fork_commit(
        self, snap: SnapshotHandle, sink: Optional[Sink] = None
    ) -> SnapshotHandle:
        # Parent copies PGD/PUD (tree metadata) and write-protects PMDs
        # (flag init) in fork_prepare — that is ALL the parent does inside
        # fork(); an incremental fork additionally ran the dirty scan there.
        table = snap.table
        snap.metrics.fork_s = time.perf_counter() - snap.fork_start

        # cond_resched() analogue at the interpreter level: don't let a
        # copier hold the GIL for the default 5 ms while the parent serves.
        if sys.getswitchinterval() > 1e-3:
            sys.setswitchinterval(5e-4)

        n = max(1, self.copier_threads)
        shards = [table.blocks[i::n] for i in range(n)]
        pending = [threading.Event() for _ in range(n)]

        duty = min(1.0, max(0.01, self.copier_duty))

        def copier(shard: List[BlockRef], done_evt: threading.Event) -> None:
            # "the child process copies PMD entries and PTEs" (Alg. 1, L15-24)
            # Debt-based duty throttle: accumulate busy time, pay it back in
            # >=2ms sleeps so syscall overhead doesn't stretch the window.
            busy = 0.0
            slept = 0.0
            try:
                for i, ref in enumerate(shard):
                    if snap.aborted:
                        return
                    if self.yield_every and i % self.yield_every == 0:
                        time.sleep(0)  # cond_resched()
                    if not table.try_acquire(ref.key):
                        continue  # parent proactively copied it already
                    t_blk = time.perf_counter()
                    snap.stage_block(ref)
                    table.mark(ref.key, BlockState.COPIED)
                    snap.metrics.copied_blocks_child += 1
                    busy += time.perf_counter() - t_blk
                    if duty < 1.0:  # dedicated-core emulation
                        debt = busy * (1.0 - duty) / duty - slept
                        if debt > 2e-3:
                            time.sleep(debt)
                            slept += debt
                # straggler mitigation: finished copiers steal leftover
                # blocks from slower shards (trylock makes this race-free)
                for ref in table.blocks:
                    if snap.aborted:
                        return
                    if table.state(ref.key) == BlockState.UNCOPIED and \
                            table.try_acquire(ref.key):
                        snap.stage_block(ref)
                        table.mark(ref.key, BlockState.COPIED)
                        snap.metrics.copied_blocks_child += 1
            except BaseException as exc:  # §4.4 case 2 (SIGKILL the child)
                snap.abort(exc)
            finally:
                done_evt.set()
                if all(e.is_set() for e in pending):
                    # Both copier sweeps skip a block the parent's
                    # sync_for_write holds in COPYING; its stage may still
                    # be in flight, so wait it out before sealing.
                    if not snap.aborted:
                        table.wait_all_not_copying()
                    snap.metrics.copy_window_s = time.perf_counter() - snap.t0
                    snap.copy_done.set()

        for shard, evt in zip(shards, pending):
            threading.Thread(target=copier, args=(shard, evt), daemon=True).start()

        if sink is None:
            def _mark_persisted():
                snap.copy_done.wait()
                snap.metrics.persist_s = time.perf_counter() - snap.t0
                snap.persist_done.set()
            threading.Thread(target=_mark_persisted, daemon=True).start()
        else:
            self._pipeline().submit(snap, sink)
        return snap


SNAPSHOTTERS = {
    "blocking": BlockingSnapshotter,
    "cow": CowSnapshotter,
    "asyncfork": AsyncForkSnapshotter,
}


def make_snapshotter(mode: str, provider: PyTreeProvider, **kw) -> Snapshotter:
    try:
        cls = SNAPSHOTTERS[mode]
    except KeyError:
        raise ValueError(f"unknown snapshotter mode {mode!r}; pick from {sorted(SNAPSHOTTERS)}")
    return cls(provider, **kw)
