"""Fault injection for the persist/commit/maintenance planes.

The crash-safety argument (DESIGN.md §12) is only as good as the failures
it has actually been tested against. This module gives tests and the
``faults`` benchmark cell a way to script *real* failures at the exact
points where the durable-commit protocol claims to tolerate them:

  * ``sink.write``     — inside :meth:`FileSink.write_run`, before the
                         gathered ``pwritev`` (a transient disk error on
                         the data path; the persist worker's
                         :class:`~repro.core.policy.RetryPolicy` covers it)
  * ``sink.fsync``     — before each durable-mode ``fsync`` in
                         :meth:`FileSink.close`
  * ``sink.rename``    — before the shard manifest's tmp→final rename
                         (the per-shard commit point)
  * ``persist.run``    — at the top of each writer-lane write attempt
                         (:meth:`PersistPipeline._write_with_retry`)
  * ``persist.stage``  — at the top of each stager-lane attempt, before
                         the flag-machine staging + batched D2H drain
                         (:meth:`PersistPipeline._stage_with_retry`);
                         staging is idempotent, so the same
                         :class:`~repro.core.policy.RetryPolicy` covers it
  * ``bgsave.commit``  — inside :func:`write_composite_manifest`, before
                         the composite manifest rename (the epoch's
                         single linearization point)
  * ``compactor.swap`` — in :meth:`SnapshotCatalog.compact_dir`, between
                         building the folded image and the rename swap
  * ``catalog.gc``     — in :meth:`SnapshotCatalog._decref`, before the
                         refcount-zero ``rmtree`` (and again in the
                         scrubber's retry of a logged GC orphan)
  * ``replicate.read`` — in :meth:`EpochReplicator._read_range`, before
                         each positioned read of primary run bytes (a
                         transient source-side transfer fault; retried
                         under the replicator's RetryPolicy)
  * ``replicate.write``— in :meth:`EpochReplicator._write_range`, before
                         each positioned write into the replica pool
                         (destination-side transfer fault, same retry)
  * ``replicate.commit``— in :meth:`EpochReplicator` just before the
                         replica-side manifest tmp→final rename (the
                         replica epoch's single commit point; a crash
                         here leaves a torn replica dir for
                         ``SnapshotCatalog.from_dir`` to quarantine)

Modes: ``raise`` (raise ``exc`` for the first ``times`` hits — raise-once
is ``times=1``, raise-N is ``times=N``), ``delay`` (sleep ``delay_s`` per
hit), and ``crash`` (``os._exit`` — the SIGKILL-equivalent: no cleanup,
no atexit, no flushed buffers; the subprocess crash harness asserts on
the exit code). ``after`` skips the first N hits before acting, so a
crash can land mid-stream rather than on the first write.

Threading: tests either pass a :class:`FaultInjector` explicitly to
``FileSink``/``PersistPipeline`` or ``install()`` one process-wide (the
coordinator's composite commit and the catalog's maintenance sites read
the installed injector). ``fire()`` is a no-op while nothing is armed, so
the production hot path pays one attribute load per site.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, Optional

# Exit code the crash mode dies with; chosen to match SIGKILL's shell
# convention (128 + 9) so the harness can tell "crash site fired" from
# any ordinary python failure.
CRASH_EXIT_CODE = 137

SITES = (
    "sink.write",
    "sink.fsync",
    "sink.rename",
    "persist.run",
    "persist.stage",
    "bgsave.commit",
    "compactor.swap",
    "catalog.gc",
    "replicate.read",
    "replicate.write",
    "replicate.commit",
)


class _Plan:
    __slots__ = ("mode", "times", "exc", "delay_s", "after", "hits", "acted")

    def __init__(self, mode: str, times: Optional[int], exc, delay_s: float,
                 after: int):
        self.mode = mode
        self.times = times          # None = unbounded (delay mode)
        self.exc = exc
        self.delay_s = delay_s
        self.after = after
        self.hits = 0               # fire() calls seen at this site
        self.acted = 0              # raises/delays actually delivered


class FaultInjector:
    """Named injection sites with raise-once / raise-N / delay / crash."""

    def __init__(self):
        self._mu = threading.Lock()
        self._plans: Dict[str, _Plan] = {}
        self._hits: Dict[str, int] = {}

    # -- arming -----------------------------------------------------------
    def arm(self, site: str, mode: str = "raise", times: Optional[int] = 1,
            exc=OSError, delay_s: float = 0.0, after: int = 0) -> None:
        """Arm one site. ``mode``: "raise" | "delay" | "crash".
        ``times`` bounds how many hits act (None = every hit); ``after``
        skips that many hits first."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; pick from {SITES}")
        if mode not in ("raise", "delay", "crash"):
            raise ValueError(f"unknown fault mode {mode!r}")
        with self._mu:
            self._plans[site] = _Plan(mode, times, exc, float(delay_s),
                                      int(after))

    def disarm(self, site: Optional[str] = None) -> None:
        with self._mu:
            if site is None:
                self._plans.clear()
            else:
                self._plans.pop(site, None)

    # -- accounting -------------------------------------------------------
    def hits(self, site: str) -> int:
        """fire() calls seen at ``site`` (armed or not)."""
        with self._mu:
            return self._hits.get(site, 0)

    def acted(self, site: str) -> int:
        """Faults actually delivered at ``site``."""
        with self._mu:
            plan = self._plans.get(site)
            return plan.acted if plan is not None else 0

    # -- the injection point ----------------------------------------------
    def fire(self, site: str, detail: str = "") -> None:
        with self._mu:
            self._hits[site] = self._hits.get(site, 0) + 1
            plan = self._plans.get(site)
            if plan is None:
                return
            plan.hits += 1
            if plan.hits <= plan.after:
                return
            if plan.times is not None and plan.acted >= plan.times:
                return
            plan.acted += 1
            mode, exc, delay_s = plan.mode, plan.exc, plan.delay_s
        if mode == "crash":
            # SIGKILL-equivalent: no unwinding, no atexit, nothing flushed
            os._exit(CRASH_EXIT_CODE)
        if mode == "delay":
            time.sleep(delay_s)
            return
        raise exc(f"injected fault at {site}" + (f" ({detail})" if detail else ""))


# -- process-wide injector (subprocess harness / whole-engine tests) ------
_INSTALLED: Optional[FaultInjector] = None


def install(injector: Optional[FaultInjector]) -> Optional[FaultInjector]:
    """Install (or, with None, remove) the process-wide injector; returns
    the previous one so tests can restore it."""
    global _INSTALLED
    prev = _INSTALLED
    _INSTALLED = injector
    return prev


def installed() -> Optional[FaultInjector]:
    return _INSTALLED


def fire(site: str, detail: str = "",
         faults: Optional[FaultInjector] = None) -> None:
    """Hit one site: the explicitly threaded injector wins, else the
    installed process-wide one, else no-op."""
    inj = faults if faults is not None else _INSTALLED
    if inj is not None:
        inj.fire(site, detail)
