"""Snapshot catalog — epochs as a queryable, refcounted product surface.

PRs 1-6 made snapshots cheap to *take*; nothing could *query* them.
Retained base images were write-side plumbing (dirty-scan inputs), skip
epochs aliased old shard directories forever, and delta chains grew until
a ``full_every`` anchor happened to land. This module turns the snapshot
lifecycle into a first-class catalog:

* :class:`SnapshotCatalog` registers every committed
  ``CoordinatedSnapshot``/BGSAVE directory as an **epoch** and tracks a
  refcount per shard directory. A dir is held by (a) every epoch whose
  composite manifest points at it — its own epoch plus every skip epoch
  aliasing it — and (b) every child dir whose delta chain names it as
  parent. Dropping an epoch releases its holds; a dir whose count hits
  zero is GC'd from disk and releases its own parent, cascading up the
  chain.

* :class:`EpochRef` is a **pinned read handle** on one epoch
  (``catalog.pin(epoch_id)``). While any pin is live the epoch cannot be
  released, so every shard image it references stays valid. Reads
  resolve **zero-copy against the retained in-memory staging buffers**
  while the snapshot is resident (staged images are immutable once
  ``copy_done`` — the copier never rewrites a staged block and commits
  donate *provider* buffers, not staging), and against **memory-mapped
  manifests** otherwise. The same handle hands out per-block views for
  writable branches (``KVEngine.branch``).

* :class:`ChainCompactor` is the maintenance plane: a background worker
  that folds delta chains deeper than :class:`~repro.core.policy.
  CompactionPolicy` ``max_chain`` into fresh full images **in place**
  (the dir keeps its path, so skip aliases and delta children stay
  valid byte-for-byte) and then releases the parent refs that pinned
  the ancestor dirs, letting the refcount GC reclaim them.

Consistency argument: DESIGN.md §11.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import fire as _fire_fault
from repro.core.policy import CompactionPolicy
from repro.core.sinks import (
    RestorePool,
    _read_snapshot_dir,
    snapshot_chain_depth,
)


def _norm(path: str) -> str:
    return os.path.realpath(os.path.abspath(path))


class _DirNode:
    """One shard directory in the reference graph."""

    __slots__ = ("path", "refs", "parent", "owned")

    def __init__(self, path: str, owned: bool):
        self.path = path
        self.refs = 0
        self.parent: Optional[str] = None
        # only dirs the catalog saw being written (an epoch's own shard
        # dir) are ever rmtree'd; foreign parents are released but left
        # on disk
        self.owned = owned


class _EpochRecord:
    """Internal per-epoch record (reach it through ``pin``)."""

    __slots__ = (
        "epoch_id", "snap", "layout", "modes", "directory",
        "shard_dirs", "held_dirs", "pins", "dropped", "images",
    )

    def __init__(self, epoch_id: int, snap, layout, modes):
        self.epoch_id = epoch_id
        self.snap = snap                     # live CoordinatedSnapshot
        self.layout = layout                 # ShardLayout at the barrier
        self.modes = modes                   # per-shard full/delta/skip
        self.directory: Optional[str] = None  # composite dir (durable)
        self.shard_dirs: List[Optional[str]] = []
        self.held_dirs: List[str] = []       # dirs this epoch refcounts
        self.pins = 0
        self.dropped = False
        self.images: Dict[int, List[np.ndarray]] = {}  # shard -> blocks


class EpochRef:
    """A pinned, refcounted read handle on one cataloged epoch.

    Usable as a context manager; reads against a released ref raise.
    ``shard_rows``/``shard_blocks`` resolve through the catalog: the
    retained in-memory image while the epoch is resident (zero-copy),
    the memmapped on-disk manifest chain otherwise.
    """

    def __init__(self, catalog: "SnapshotCatalog", record: _EpochRecord):
        self._catalog = catalog
        self._record = record
        self._released = False

    # -- metadata --------------------------------------------------------
    @property
    def epoch_id(self) -> int:
        return self._record.epoch_id

    @property
    def layout(self):
        return self._record.layout

    @property
    def modes(self) -> List[str]:
        return list(self._record.modes)

    @property
    def live(self) -> bool:
        """True while the epoch's in-memory staging images are resident."""
        return self._record.snap is not None

    @property
    def directory(self) -> Optional[str]:
        return self._record.directory

    @property
    def released(self) -> bool:
        return self._released

    # -- reads -----------------------------------------------------------
    def shard_blocks(self, shard_id: int) -> List[np.ndarray]:
        """Per-block immutable images of one shard at this epoch.

        Live epochs hand out the staging buffers themselves (zero-copy);
        durable epochs hand out memmapped (or chain-resolved) block
        arrays. Callers must treat every array as read-only.
        """
        if self._released:
            raise ValueError(
                f"EpochRef(epoch={self.epoch_id}) has been released"
            )
        return self._catalog._shard_blocks(self._record, shard_id)

    def shard_rows(self, shard_id: int, rows) -> np.ndarray:
        """Gather shard-local ``rows`` from this epoch's image."""
        blocks = self.shard_blocks(shard_id)
        rows = np.asarray(rows, dtype=np.int64).reshape(-1)
        br = int(blocks[0].shape[0])
        out = np.empty((rows.size,) + blocks[0].shape[1:],
                       dtype=blocks[0].dtype)
        bids = rows // br
        offs = rows - bids * br
        for b in np.unique(bids):
            m = bids == b
            out[m] = blocks[int(b)][offs[m]]
        return out

    # -- lifecycle -------------------------------------------------------
    def release(self) -> None:
        if not self._released:
            self._released = True
            self._catalog._unpin(self._record)

    def __enter__(self) -> "EpochRef":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SnapshotCatalog:
    """Epoch registry + shard-directory refcount graph + GC.

    Thread-safety: one internal lock guards the registry and the ref
    graph; block-image resolution happens outside it (reads may be slow)
    with a per-record publish under the lock.
    """

    def __init__(self, pool: Optional[RestorePool] = None,
                 live_wait_s: float = 120.0):
        self._lock = threading.RLock()
        self._records: Dict[int, _EpochRecord] = {}
        self._dirs: Dict[str, _DirNode] = {}
        self._composites: set = set()
        self._next_id = 0
        self._pool = pool if pool is not None else RestorePool()
        self.live_wait_s = float(live_wait_s)
        # dir removals that failed (fault-injected or racing an external
        # delete): the orphan stays on disk for recovery to quarantine.
        # gc_error_log holds the (path, reason) behind each count so the
        # scrubber's retry-then-quarantine loop can consume them.
        self.gc_errors = 0
        self.gc_error_log: List[Tuple[str, str]] = []
        # dirs the scrubber moved (never deleted) into quarantine/
        self.quarantined_dirs: List[Tuple[str, str]] = []
        # stamped by SnapshotCatalog.from_dir (a RecoveryReport)
        self.last_recovery = None
        # standby-pool hook (attach_replica): refetch_dir delegates here
        self._replicator = None

    # -- registration (called by the coordinator) ------------------------
    def register_epoch(self, snap) -> int:
        """Register a committed snapshot as an epoch; returns its id and
        stamps it on ``snap.epoch_id``. The catalog holds the snapshot
        strongly until the epoch is dropped (or ``evict_live``)."""
        with self._lock:
            eid = self._next_id
            self._next_id += 1
            rec = _EpochRecord(
                eid, snap,
                getattr(snap, "layout", None),
                list(getattr(snap, "modes", None) or []),
            )
            self._records[eid] = rec
        try:
            snap.epoch_id = eid
        except Exception:
            pass
        return eid

    def register_durable_epoch(
        self,
        directory: str,
        shard_dirs: Sequence[str],
        parents: Sequence[Optional[str]],
        modes: Optional[Sequence[str]] = None,
        layout=None,
    ) -> int:
        """Register an epoch that exists ONLY on disk (the recovery path
        across a process restart): the same refcount wiring as
        ``register_epoch`` + ``attach_dirs``, but with no live snapshot —
        pins resolve every read through the on-disk manifest chains."""
        with self._lock:
            eid = self._next_id
            self._next_id += 1
            rec = _EpochRecord(eid, None, layout, list(modes or []))
            self._records[eid] = rec
        self.attach_dirs(eid, directory, shard_dirs, parents, modes=modes)
        return eid

    @classmethod
    def from_dir(cls, pool_dir: str, deep_verify: bool = True,
                 quarantine: bool = True,
                 pool: Optional[RestorePool] = None) -> "SnapshotCatalog":
        """Rebuild a catalog from a pool directory at process startup:
        scan every epoch dir under ``pool_dir``, validate manifests (and,
        with ``deep_verify``, every carried block's checksum), quarantine
        torn or orphaned dirs into ``pool_dir/quarantine/``, and register
        exactly the fully-committed epochs — ``restore_checkpoint``,
        ``get_at`` and ``branch`` then work across restarts. The
        :class:`~repro.core.recovery.RecoveryReport` lands on
        ``catalog.last_recovery``."""
        from repro.core.recovery import RecoveryManager
        cat = cls(pool=pool)
        cat.last_recovery = RecoveryManager(
            pool_dir, deep_verify=deep_verify, quarantine=quarantine,
        ).recover_into(cat)
        return cat

    def attach_dirs(
        self,
        snap_or_id,
        directory: str,
        shard_dirs: Sequence[str],
        parents: Sequence[Optional[str]],
        modes: Optional[Sequence[str]] = None,
    ) -> None:
        """Record an epoch's durable layout: its composite ``directory``,
        the shard dir each entry resolves to (a skip entry passes the
        ALIASED previous dir) and each dir's delta parent (``None`` for
        full images and for aliases — the alias target already holds its
        own parent). Every listed dir gains one reference from this
        epoch; parent links gain one reference from their child."""
        eid = snap_or_id if isinstance(snap_or_id, int) \
            else getattr(snap_or_id, "epoch_id")
        with self._lock:
            rec = self._records[eid]
            rec.directory = _norm(directory)
            self._composites.add(rec.directory)
            modes = list(modes) if modes is not None else rec.modes
            rec.shard_dirs = []
            for k, sd in enumerate(shard_dirs):
                sd = _norm(sd)
                par = parents[k]
                own = not modes or k >= len(modes) or modes[k] != "skip"
                self._ensure_dir(
                    sd, _norm(par) if par is not None else None, own
                )
                self._dirs[sd].refs += 1
                rec.held_dirs.append(sd)
                rec.shard_dirs.append(sd)

    # -- queries ---------------------------------------------------------
    def epochs(self) -> List[int]:
        with self._lock:
            return sorted(self._records)

    def refcount(self, path: str) -> int:
        with self._lock:
            node = self._dirs.get(_norm(path))
            return node.refs if node is not None else 0

    def dir_depth(self, path: str) -> int:
        """Delta hops below ``path`` (0 = full image), from the in-memory
        ref graph when registered, the on-disk manifests otherwise."""
        with self._lock:
            path = _norm(path)
            node = self._dirs.get(path)
            if node is None:
                try:
                    return snapshot_chain_depth(path)
                except (ValueError, OSError):
                    return 0
            depth = 0
            seen = set()
            while node is not None and node.parent is not None:
                if node.path in seen:
                    break
                seen.add(node.path)
                depth += 1
                node = self._dirs.get(node.parent)
            return depth

    def deep_dirs(self, max_chain: int) -> List[str]:
        """Registered dirs whose chain exceeds ``max_chain`` AND whose
        whole chain is durable (every manifest on disk) — the compactor's
        work list. Mid-persist chains are skipped, not raced."""
        with self._lock:
            out = []
            for path, node in self._dirs.items():
                if node.parent is None:
                    continue
                if self.dir_depth(path) <= max_chain:
                    continue
                cur, ok, seen = node, True, set()
                while cur is not None:
                    if cur.path in seen:
                        ok = False
                        break
                    seen.add(cur.path)
                    if not os.path.exists(
                        os.path.join(cur.path, "manifest.json")
                    ):
                        ok = False
                        break
                    cur = (self._dirs.get(cur.parent)
                           if cur.parent is not None else None)
                if ok:
                    out.append(path)
            return sorted(out)

    def durable_epochs(self) -> List[Tuple[int, str]]:
        """``(epoch_id, composite_dir)`` for every live epoch whose commit
        point has fired (``attach_dirs`` runs strictly after the
        composite-manifest rename), in epoch-id order — which is the
        order delta parents and skip-alias targets precede their
        dependents, i.e. the replicator's ship order."""
        with self._lock:
            return [
                (eid, rec.directory)
                for eid, rec in sorted(self._records.items())
                if rec.directory is not None and not rec.dropped
            ]

    def committed_dirs(self) -> List[str]:
        """Owned shard dirs with a durable manifest on disk — the
        scrubber's work list. Foreign parents (dirs another store owns)
        and mid-persist dirs are excluded."""
        with self._lock:
            paths = sorted(
                p for p, node in self._dirs.items() if node.owned
            )
        return [
            p for p in paths
            if os.path.exists(os.path.join(p, "manifest.json"))
        ]

    def occupancy(self) -> Dict[str, float]:
        """Catalog footprint on disk: committed dirs, their total bytes,
        chain-depth max/mean, and the quarantine/orphan backlogs — the
        observability slice replication lag and scrub coverage are
        judged against."""
        dirs = self.committed_dirs()
        total = 0
        for d in dirs:
            try:
                with os.scandir(d) as it:
                    for entry in it:
                        try:
                            total += entry.stat().st_size
                        except OSError:
                            pass
            except OSError:
                continue
        depths = [self.dir_depth(d) for d in dirs]
        with self._lock:
            quarantined = len(self.quarantined_dirs)
            orphans = len(self.gc_error_log)
        return {
            "dirs": float(len(dirs)),
            "bytes": float(total),
            "chain_depth_max": float(max(depths, default=0)),
            "chain_depth_mean": (
                float(sum(depths)) / len(depths) if depths else 0.0
            ),
            "quarantined": float(quarantined),
            "gc_orphans": float(orphans),
        }

    # -- maintenance-plane hooks (scrubber / replicator) -----------------
    def gc_orphans(self) -> List[Tuple[str, str]]:
        """Drain the ``(path, reason)`` log behind ``gc_errors``. The
        caller (the scrubber) owns the drained entries: retry the
        removal, then quarantine what still will not die."""
        with self._lock:
            out = list(self.gc_error_log)
            self.gc_error_log = []
            return out

    def note_quarantined(self, path: str, reason: str) -> None:
        with self._lock:
            self.quarantined_dirs.append((path, reason))

    def attach_replica(self, replicator) -> None:
        """Register the standby-pool shipper as this catalog's repair
        source: ``refetch_dir`` (the scrubber's corrupt-dir path) then
        stages verified copies out of the replica pool."""
        with self._lock:
            self._replicator = replicator

    def refetch_dir(self, path: str) -> Optional[str]:
        """Stage a deep-verified copy of shard dir ``path`` from the
        attached replica at ``path + '.fetch'``; returns the staged path,
        or None when no replica is attached / the replica has no good
        copy. The caller performs the quarantine + rename swap."""
        with self._lock:
            rep = self._replicator
        if rep is None:
            return None
        return rep.fetch_dir(path)

    def invalidate_images(self, path: str) -> None:
        """Drop cached block images of one shard dir after its files were
        swapped (compaction fold or scrub repair). Readers holding mmaps
        of the old inodes stay byte-valid; fresh pins reload from the new
        files."""
        with self._lock:
            path = _norm(path)
            for rec in self._records.values():
                if path in (rec.shard_dirs or []):
                    for k, sd in enumerate(rec.shard_dirs):
                        if sd == path:
                            rec.images.pop(k, None)

    # -- pin / drop ------------------------------------------------------
    def pin(self, epoch_id: int) -> EpochRef:
        with self._lock:
            rec = self._records.get(int(epoch_id))
            if rec is None or rec.dropped:
                raise ValueError(f"unknown or dropped epoch {epoch_id}")
            rec.pins += 1
            return EpochRef(self, rec)

    def drop_epoch(self, epoch_id: int) -> List[str]:
        """Release the catalog's hold on an epoch. Returns the shard dirs
        the cascading GC removed from disk (empty while pins — or other
        epochs/children — still hold the dirs; the release then happens
        when the last pin drops)."""
        with self._lock:
            rec = self._records.get(int(epoch_id))
            if rec is None:
                return []
            rec.dropped = True
            if rec.pins > 0:
                return []
            return self._release(rec)

    def evict_live(self, epoch_id: int) -> None:
        """Drop the in-memory snapshot (staging images) of an epoch,
        forcing subsequent reads through the on-disk manifest chain.
        Refcounts are untouched."""
        with self._lock:
            rec = self._records.get(int(epoch_id))
            if rec is not None:
                rec.snap = None
                rec.images = {}

    # -- compaction (called by ChainCompactor) ---------------------------
    def compact_dir(self, path: str,
                    pool: Optional[RestorePool] = None) -> List[str]:
        """Fold the delta chain under one shard dir into a fresh full
        image **at the same path**, then release its parent ref. The dir's
        logical content is unchanged (the chain resolution it previously
        required is now baked in), so every composite manifest pointing at
        it — its epoch and any skip aliases — stays valid. Returns the
        ancestor dirs the ref release GC'd."""
        pool = pool if pool is not None else self._pool
        with self._lock:
            path = _norm(path)
            node = self._dirs.get(path)
            if node is None or node.parent is None:
                return []
            # resolve the chain and rewrite in place while holding the
            # lock: a concurrent drop/compact must not race the rename
            flat = _read_snapshot_dir(path, pool, lazy=False)
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            tmp = path + ".compact"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)

            def _write_leaf(leaf):
                arr = np.ascontiguousarray(
                    np.asarray(flat[leaf["path"]]),
                    dtype=np.dtype(leaf["dtype"]),
                )
                arr.tofile(os.path.join(tmp, leaf["file"]))
                if not leaf.get("blocks"):
                    return None
                # the fold rewrites every block, so the folded manifest
                # carries a fresh full-coverage crc32 list
                buf = arr.reshape(-1).view(np.uint8)
                bounds = np.cumsum([0] + [b[2] for b in leaf["blocks"]])
                return [
                    int(zlib.crc32(buf[int(bounds[i]):int(bounds[i + 1])]))
                    for i in range(len(leaf["blocks"]))
                ]

            leaf_crcs = pool.map(_write_leaf, manifest["leaves"])
            new_manifest = dict(manifest)
            new_manifest.pop("parent", None)
            new_manifest["compacted"] = True
            new_manifest["leaves"] = [
                dict(leaf, carried=list(range(len(leaf["blocks"]))),
                     crc32=crcs)
                if leaf.get("blocks") else dict(leaf)
                for leaf, crcs in zip(manifest["leaves"], leaf_crcs)
            ]
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(new_manifest, f)
            # atomic-enough swap: readers hold fds/mmaps, which survive
            # the rename+unlink on Linux; new opens see the full image.
            # Crash repair (DESIGN.md §12): a dead process here leaves
            # either path intact + a leftover .compact (roll the leftover
            # away), or path missing with a complete .compact (roll
            # forward) or an intact .old (roll back) — RecoveryManager
            # handles all three.
            old = path + ".old"
            _fire_fault("compactor.swap", path)
            os.rename(path, old)
            os.rename(tmp, path)
            shutil.rmtree(old, ignore_errors=True)
            old_parent = node.parent
            node.parent = None
            # cached block images of this dir stay byte-valid (mmaps pin
            # the old inodes) but drop them so fresh pins read the new
            # files rather than hold deleted inodes alive
            self.invalidate_images(path)
            return self._decref(old_parent)

    # -- internals -------------------------------------------------------
    def _ensure_dir(self, path: str, parent: Optional[str],
                    owned: bool) -> None:
        node = self._dirs.get(path)
        if node is None:
            node = _DirNode(path, owned)
            self._dirs[path] = node
        elif owned:
            node.owned = True
        if parent is not None and node.parent is None and parent != path:
            self._ensure_dir(parent, None, False)
            node.parent = parent
            self._dirs[parent].refs += 1

    def _decref(self, path: str) -> List[str]:
        removed: List[str] = []
        node = self._dirs.get(path)
        if node is None:
            return removed
        node.refs -= 1
        if node.refs <= 0:
            del self._dirs[path]
            if node.owned:
                try:
                    _fire_fault("catalog.gc", path)
                    if os.path.lexists(path):
                        shutil.rmtree(path)
                    removed.append(path)
                except OSError as exc:
                    # an already-gone dir is tolerated above (ENOENT is
                    # not an error — someone beat us to it); anything
                    # else leaves an orphan on disk, logged for the
                    # scrubber's retry-then-quarantine loop (or, absent a
                    # scrubber, for recovery to quarantine at restart),
                    # and the catalog keeps serving
                    self.gc_errors += 1
                    self.gc_error_log.append(
                        (path, getattr(exc, "strerror", None) or str(exc))
                    )
            if node.parent is not None:
                removed.extend(self._decref(node.parent))
            self._cleanup_composite(os.path.dirname(path))
        return removed

    def _cleanup_composite(self, directory: str) -> None:
        """Remove a composite manifest (and its dir, if empty) once the
        last shard dir under it is gone — other epochs' refs may keep
        sibling shard dirs (skip aliases) alive arbitrarily long."""
        if directory not in self._composites:
            return
        prefix = directory.rstrip(os.sep) + os.sep
        if any(p.startswith(prefix) for p in self._dirs):
            return
        try:
            os.remove(os.path.join(directory, "manifest.json"))
        except OSError:
            pass
        try:
            os.rmdir(directory)
        except OSError:
            pass

    def _unpin(self, rec: _EpochRecord) -> None:
        with self._lock:
            rec.pins -= 1
            if rec.dropped and rec.pins <= 0 \
                    and rec.epoch_id in self._records:
                self._release(rec)

    def _release(self, rec: _EpochRecord) -> List[str]:
        removed: List[str] = []
        for d in rec.held_dirs:
            removed.extend(self._decref(d))
        rec.held_dirs = []
        rec.snap = None
        rec.images = {}
        self._records.pop(rec.epoch_id, None)
        if rec.directory is not None:
            self._cleanup_composite(rec.directory)
        return removed

    def _shard_blocks(self, rec: _EpochRecord,
                      shard_id: int) -> List[np.ndarray]:
        with self._lock:
            cached = rec.images.get(shard_id)
            snap = rec.snap
        if cached is not None:
            return cached
        blocks: Optional[List[np.ndarray]] = None
        if snap is not None:
            handle = (snap.shard_handle(shard_id)
                      if hasattr(snap, "shard_handle") else snap)
            if handle is not None:
                # staged images are immutable once copy_done (donated
                # commits replace PROVIDER buffers; the copier writes a
                # block at most once): wait for the copy window to close,
                # then the buffers are a frozen point-in-time cut
                handle.wait(self.live_wait_s)
                leaves = sorted(handle.table.leaf_handles,
                                key=lambda h: h.leaf_id)
                blocks = [np.asarray(handle.backend.leaf_array(h.leaf_id))
                          for h in leaves]
        if blocks is None:
            sdirs = rec.shard_dirs
            if not sdirs or shard_id >= len(sdirs) \
                    or sdirs[shard_id] is None:
                raise ValueError(
                    f"epoch {rec.epoch_id} shard {shard_id} is neither "
                    "resident in memory nor attached to a snapshot "
                    "directory; nothing to read"
                )
            flat = _read_snapshot_dir(sdirs[shard_id], self._pool,
                                      lazy=True)

            def _block_id(p: str) -> int:
                try:
                    return int(p.rsplit("/", 1)[-1])
                except ValueError:
                    return -1

            blocks = [arr for _, arr in
                      sorted(flat.items(), key=lambda kv: _block_id(kv[0]))]
        with self._lock:
            rec.images[shard_id] = blocks
        return blocks


class ChainCompactor:
    """Background maintenance worker folding deep delta chains.

    ``scan_once`` walks the catalog's ref graph for dirs whose chain
    exceeds ``policy.max_chain`` and compacts each in place through the
    catalog (chain reads fan out on the shared :class:`RestorePool`, leaf
    writes on the same pool). ``start``/``stop`` run the scan on a
    daemon thread every ``policy.interval_s``.
    """

    def __init__(self, catalog: SnapshotCatalog,
                 policy: Optional[CompactionPolicy] = None,
                 pool: Optional[RestorePool] = None):
        self.catalog = catalog
        self.policy = policy if policy is not None else CompactionPolicy()
        self.pool = pool
        self.compacted: List[str] = []   # dirs folded to full images
        self.released: List[str] = []    # ancestor dirs the GC reclaimed
        self.compactor_errors = 0        # failed folds/scans (kept scanning)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def scan_once(self) -> List[str]:
        done: List[str] = []
        for path in self.catalog.deep_dirs(self.policy.max_chain):
            try:
                freed = self.catalog.compact_dir(path, pool=self.pool)
            except Exception:
                # one dir's failed fold (an rmtree racing an external
                # delete, a chain torn underfoot) must not starve the
                # rest of the work list: count it, keep scanning, retry
                # on the next tick
                self.compactor_errors += 1
                continue
            done.append(path)
            self.released.extend(freed)
        self.compacted.extend(done)
        return done

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.policy.interval_s):
                try:
                    self.scan_once()
                except Exception:
                    # scan-level failure (the catalog mutating underfoot)
                    # must never kill the maintenance thread — count it
                    # and keep the loop alive
                    self.compactor_errors += 1

        self._thread = threading.Thread(
            target=_loop, name="chain-compactor", daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=30)
        self._thread = None
