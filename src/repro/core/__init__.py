# The paper's primary contribution: Async-fork as a snapshot substrate for
# sharded JAX state (see DESIGN.md for the page-table -> block-table mapping).
from repro.core.blocks import (
    BlockGeometry,
    BlockRef,
    BlockRun,
    BlockState,
    BlockTable,
    LeafHandle,
    TwoWayPointer,
    coalesce_refs,
)
from repro.core.catalog import ChainCompactor, EpochRef, SnapshotCatalog
from repro.core.coordinator import (
    AggregateMetrics,
    CoordinatedSnapshot,
    ShardedSnapshotCoordinator,
)
from repro.core.faults import FaultInjector, install as install_faults
from repro.core.gates import GateRetired, GateSet, SharedGate
from repro.core.layout import ShardLayout
from repro.core.metrics import MaintenanceMetrics, SnapshotMetrics
from repro.core.persist import PersistJob, PersistPipeline
from repro.core.policy import (
    BgsavePolicy,
    CompactionPolicy,
    CopierDutyController,
    ReplicationPolicy,
    RetryPolicy,
    ScrubPolicy,
    ShardEpochView,
    ShardPolicyState,
    ShardWriteCounters,
)
from repro.core.provider import FailingProvider, PyTreeProvider
from repro.core.recovery import (
    RecoveryManager,
    RecoveryReport,
    validate_sink_dir,
)
from repro.core.replicate import EpochReplicator, ReplicationError
from repro.core.scrub import EpochScrubber
from repro.core.sinks import (
    FileSink,
    MemorySink,
    NullSink,
    RestorePool,
    Sink,
    read_file_snapshot,
    read_snapshot_layout,
    snapshot_chain_depth,
    verify_snapshot_dir,
    write_composite_manifest,
)
from repro.core.staging import (
    STAGING_BACKENDS,
    DeviceStaging,
    HostStaging,
    StagingBackend,
    make_staging,
)
from repro.core.snapshot import (
    SNAPSHOTTERS,
    AsyncForkSnapshotter,
    BlockingSnapshotter,
    CowSnapshotter,
    SnapshotError,
    SnapshotHandle,
    Snapshotter,
    make_snapshotter,
)

__all__ = [
    "AggregateMetrics",
    "ChainCompactor",
    "CoordinatedSnapshot",
    "EpochRef",
    "SnapshotCatalog",
    "ShardedSnapshotCoordinator",
    "ShardLayout",
    "GateSet",
    "GateRetired",
    "SharedGate",
    "BgsavePolicy",
    "CompactionPolicy",
    "CopierDutyController",
    "RetryPolicy",
    "FaultInjector",
    "install_faults",
    "RecoveryManager",
    "RecoveryReport",
    "validate_sink_dir",
    "EpochReplicator",
    "ReplicationError",
    "EpochScrubber",
    "ReplicationPolicy",
    "ScrubPolicy",
    "MaintenanceMetrics",
    "ShardEpochView",
    "ShardPolicyState",
    "ShardWriteCounters",
    "PersistJob",
    "PersistPipeline",
    "coalesce_refs",
    "read_snapshot_layout",
    "write_composite_manifest",
    "BlockGeometry",
    "StagingBackend",
    "HostStaging",
    "DeviceStaging",
    "STAGING_BACKENDS",
    "make_staging",
    "BlockRef",
    "BlockRun",
    "BlockState",
    "BlockTable",
    "LeafHandle",
    "TwoWayPointer",
    "SnapshotMetrics",
    "PyTreeProvider",
    "FailingProvider",
    "Sink",
    "NullSink",
    "MemorySink",
    "FileSink",
    "RestorePool",
    "read_file_snapshot",
    "snapshot_chain_depth",
    "verify_snapshot_dir",
    "Snapshotter",
    "SnapshotHandle",
    "SnapshotError",
    "BlockingSnapshotter",
    "CowSnapshotter",
    "AsyncForkSnapshotter",
    "SNAPSHOTTERS",
    "make_snapshotter",
]
