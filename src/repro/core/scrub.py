"""Background scrubbing — bit-rot detection before a restore needs the
bytes, and the repair loop that closes it (DESIGN.md §14).

Durable commits (§12) prove an epoch was correct when written; nothing
re-checks it while it sits cold, so the first reader to notice rot would
have been a restore — the worst possible moment. :class:`EpochScrubber`
reuses :class:`~repro.core.catalog.ChainCompactor`'s paced background-
thread mold to run the recovery scan's deep-verify crc pass
(:func:`~repro.core.recovery.validate_sink_dir`) over the catalog's
committed dirs at low duty: ``ScrubPolicy.dirs_per_scan`` dirs per tick,
round-robin, so the pool is covered eventually without competing with
the serving plane.

The state machine for a dir that fails verification:

    committed ──crc mismatch──▶ corrupt ──replica has a verified copy──▶
    quarantined (moved, NEVER deleted — it is evidence) + the re-fetched
    copy renamed into the original path ──▶ committed again

The swap mirrors ``compact_dir``'s: readers holding mmaps of the old
files keep byte-valid views (the inodes survive the rename), the
catalog's cached images are invalidated so fresh pins read the repaired
files, and the dir keeps its path so every composite manifest, skip
alias and delta child pointing at it stays correct. Without a replica
(or when the replica's copy fails verification too) the dir is left in
place and reported — destroying the only copy is never an improvement.

``catalog.gc_errors`` orphans feed the same loop: each drained
``(path, reason)`` gets one retried ``rmtree`` (through the same
``catalog.gc`` fault site, so tests can fail the retry too); what still
will not die is moved to quarantine instead of leaking forever.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import List, Optional, Tuple

from repro.core.faults import fire as _fire_fault
from repro.core.metrics import MaintenanceMetrics
from repro.core.policy import ScrubPolicy
from repro.core.recovery import (
    _load_manifest,
    quarantine_dest,
    validate_sink_dir,
)


def _pool_of(sdir: str) -> str:
    """The pool dir whose ``quarantine/`` a shard dir belongs to: a
    composite shard (``pool/epN/shard_k``) quarantines at the POOL level
    (its epoch dir is not a pool), a flat epoch dir one level up."""
    parent = os.path.dirname(sdir)
    if _load_manifest(parent) is not None or \
            os.path.basename(sdir).startswith("shard_"):
        return os.path.dirname(parent)
    return parent


def _quarantine_name(sdir: str) -> str:
    """Unique-ish quarantine basename: composite shards prefix their
    epoch dir (many epochs have a ``shard_0``)."""
    pool = _pool_of(sdir)
    parent = os.path.dirname(sdir)
    if parent != pool:
        return f"{os.path.basename(parent)}.{os.path.basename(sdir)}"
    return os.path.basename(sdir)


class EpochScrubber:
    """Low-duty crc pass over committed dirs + the orphan retry loop.

    Same lifecycle as ``ChainCompactor``: call :meth:`scan_once`
    synchronously (tests, benchmarks) or :meth:`start`/:meth:`stop` the
    paced daemon thread. Errors are counted, never raised — a scrubber
    that kills the process defeats its purpose.
    """

    def __init__(self, catalog, policy: Optional[ScrubPolicy] = None,
                 metrics: Optional[MaintenanceMetrics] = None):
        self.catalog = catalog
        self.policy = policy if policy is not None else ScrubPolicy()
        self.metrics = metrics if metrics is not None else MaintenanceMetrics()
        # dirs that failed verification and could NOT be repaired
        self.corrupt: List[Tuple[str, str]] = []
        self.scrub_errors = 0
        self._cursor = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- one tick ---------------------------------------------------------
    def scan_once(self) -> List[Tuple[str, str]]:
        """One maintenance tick: drain GC orphans, then deep-verify up to
        ``dirs_per_scan`` committed dirs. Returns the ``(dir, reason)``
        corruption found this tick (repaired or not)."""
        self._drain_orphans()
        found: List[Tuple[str, str]] = []
        dirs = self.catalog.committed_dirs()
        if not dirs:
            return found
        n = min(len(dirs), max(1, int(self.policy.dirs_per_scan)))
        start = self._cursor % len(dirs)
        for i in range(n):
            d = dirs[(start + i) % len(dirs)]
            try:
                problem, blocks = validate_sink_dir(
                    d, valid_dirs=None, deep_verify=True)
            except Exception:
                self.scrub_errors += 1
                continue
            self.metrics.record_scrub(blocks)
            if problem is not None:
                self.metrics.record_corrupt()
                found.append((d, problem))
                self._repair(d, problem)
        self._cursor = (start + n) % len(dirs)
        return found

    # -- gc orphans: retry once, then quarantine --------------------------
    def _drain_orphans(self) -> None:
        for path, reason in self.catalog.gc_orphans():
            try:
                # same fault site as the original attempt, so tests can
                # script the retry failing too
                _fire_fault("catalog.gc", path)
                if os.path.lexists(path):
                    shutil.rmtree(path)
                self.metrics.record_orphan(removed=True)
            except OSError:
                if self._quarantine(path, f"gc orphan ({reason})"):
                    self.metrics.record_orphan(removed=False)

    # -- corrupt dir: quarantine + re-fetch -------------------------------
    def _repair(self, sdir: str, reason: str) -> bool:
        """Quarantine a corrupt dir and swap in a verified replica copy.
        Returns True when the repair landed; on False the dir was left
        untouched (no replica / fetch failed verification) and is
        recorded on ``self.corrupt``."""
        staged = self.catalog.refetch_dir(sdir)
        if staged is None:
            self.corrupt.append((sdir, reason))
            return False
        try:
            dest = quarantine_dest(_pool_of(sdir), _quarantine_name(sdir))
            os.rename(sdir, dest)
            os.rename(staged, sdir)
        except OSError:
            self.scrub_errors += 1
            shutil.rmtree(staged, ignore_errors=True)
            self.corrupt.append((sdir, reason))
            return False
        # readers holding mmaps of the corrupt files keep their (already
        # wrong) bytes until they re-pin; everything resident stays
        # exact because live epochs serve from staging images, not disk.
        # Invalidate the cache so fresh pins read the repaired files.
        self.catalog.invalidate_images(sdir)
        self.catalog.note_quarantined(dest, reason)
        self.metrics.record_quarantine()
        self.metrics.record_repair()
        return True

    def _quarantine(self, path: str, reason: str) -> bool:
        try:
            dest = quarantine_dest(_pool_of(path), _quarantine_name(path))
            os.rename(path, dest)
        except OSError:
            self.scrub_errors += 1
            return False
        self.catalog.note_quarantined(dest, reason)
        self.metrics.record_quarantine()
        return True

    # -- lifecycle (the ChainCompactor mold) ------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(self.policy.interval_s):
                try:
                    self.scan_once()
                except Exception:
                    self.scrub_errors += 1

        self._thread = threading.Thread(
            target=_loop, name="epoch-scrubber", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
