"""Striped write gates — one reentrant gate per shard, plus an ordered
all-gate barrier.

PR 2's write gate was a single global ``threading.RLock``: every write on
every shard serialized against every other writer AND against any shard's
fork barrier, proactive sync, or layout swap. That re-created the paper's
out-of-service problem in miniature — snapshot machinery on one shard
stalled the serving path on all of them. Fine-granular per-partition
synchronization (Sharma et al.'s high-frequency virtual snapshotting,
CIDER's per-object pessimistic locks) is how related systems keep
snapshot bookkeeping off the hot path; :class:`GateSet` is that idea for
our coordinator:

  * **writers** take only the stripe of the shard they commit to
    (:meth:`acquire`/``release`` on the returned gate) — writes to
    different shards never contend;
  * **barrier-class operations** (the BGSAVE fork barrier, ``set_layout``,
    ``load``, ``set_copier_duty``) take ALL stripes in deterministic index
    order (:meth:`all`) — the generalization of DESIGN.md §6: "no commit
    *on shard k* between shard k's T0 stamp and barrier release";
  * **layout swaps** resize the stripe set in place (:meth:`resize`,
    called while the swapper holds all gates): unchanged shards keep their
    gate object, changed shards get fresh gates created *already held* by
    the swapping thread, and dropped gates are released at barrier exit so
    writers blocked on them wake, fail validation, and re-route.

Deadlock freedom: a writer holds at most ONE stripe at a time (a
multi-shard batch commits shard groups sequentially, releasing between
groups), and every all-gate acquirer takes stripes in ascending index
order — no hold-and-wait cycle exists. Acquisition is epoch-validated:
both paths re-check that the stripe list they snapshotted is still the
live one after locking, and retry/raise otherwise, so a writer can never
commit under a stripe that a concurrent reshard retired.

``striped=False`` aliases every stripe to one shared lock — byte-for-byte
the PR-2 global gate, kept as the baseline arm of the ``gate_contention``
benchmark.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple


class GateRetired(RuntimeError):
    """The requested stripe index no longer exists (a concurrent layout
    swap shrank the gate set); the caller must re-route and retry."""


class _AllGates:
    """Context manager over :meth:`GateSet.acquire_all` — fresh per use so
    ``with coord.write_gate:`` composes and nests (stripes are RLocks)."""

    def __init__(self, gates: "GateSet"):
        self._gates = gates

    def __enter__(self) -> "GateSet":
        self._gates.acquire_all()
        return self._gates

    def __exit__(self, *exc) -> None:
        self._gates.release_all()


class GateSet:
    """N per-shard reentrant write gates with an ordered all-gate barrier,
    in-place resizing across layout swaps, and per-stripe wait metering."""

    def __init__(self, n_gates: int, striped: bool = True):
        if n_gates < 1:
            raise ValueError("need at least one gate")
        self.striped = bool(striped)
        if self.striped:
            self._gates: List[threading.RLock] = [
                threading.RLock() for _ in range(n_gates)
            ]
        else:
            g = threading.RLock()  # the PR-2 global gate, aliased N ways
            self._gates = [g] * n_gates
        self._wait_s = [0.0] * n_gates
        self._waits = [0] * n_gates
        self._tl = threading.local()  # all-hold depth + dropped-gate debts

    @property
    def n_gates(self) -> int:
        return len(self._gates)

    # -- single-stripe path (writers) ------------------------------------
    def acquire(self, k: int) -> Tuple[threading.RLock, float]:
        """Acquire stripe ``k``; returns ``(gate, wait_seconds)`` — the
        caller releases via ``gate.release()``. ``wait_seconds`` is 0.0
        when the stripe was uncontended (non-blocking fast path), so it
        measures actual CONTENTION, not acquire-call overhead.

        Validated against concurrent resizes: if the stripe list changed
        while we blocked, the (possibly retired) gate is released and the
        acquisition retries against the live list. While the returned gate
        is held the list CANNOT change (a resize needs all stripes), so
        the caller may read layout-swapped state race-free. Raises
        :class:`GateRetired` when ``k`` fell off the end of the set."""
        t0 = time.perf_counter()
        blocked = False
        while True:
            gates = self._gates
            if k >= len(gates):
                raise GateRetired(f"stripe {k} >= {len(gates)} gates")
            g = gates[k]
            if not g.acquire(blocking=False):
                blocked = True
                g.acquire()
            if self._gates is gates:
                wait = (time.perf_counter() - t0) if blocked else 0.0
                # slot k is only written while holding stripe k
                self._wait_s[k] += wait
                self._waits[k] += 1
                return g, wait
            g.release()

    # -- all-gate barrier -------------------------------------------------
    def all(self) -> _AllGates:
        return _AllGates(self)

    def acquire_all(self) -> None:
        """Take every stripe in ascending index order (reentrant)."""
        while True:
            gates = self._gates
            uniq = list(dict.fromkeys(gates))  # striped=False aliases
            for g in uniq:
                g.acquire()
            if self._gates is gates:
                break
            for g in reversed(uniq):
                g.release()
        tl = self._tl
        tl.depth = getattr(tl, "depth", 0) + 1
        if not hasattr(tl, "dropped"):
            tl.dropped = []

    def release_all(self) -> None:
        """Release the CURRENT stripe list (which a nested :meth:`resize`
        may have replaced since acquisition) plus one debt payment on each
        gate a resize dropped — so writers blocked on retired stripes wake
        exactly when the barrier that retired them exits."""
        tl = self._tl
        if getattr(tl, "depth", 0) < 1:
            raise RuntimeError("release_all without matching acquire_all")
        for g in reversed(list(dict.fromkeys(self._gates))):
            g.release()
        still = []
        for debt in tl.dropped:
            debt[0].release()
            debt[1] -= 1
            if debt[1] > 0:
                still.append(debt)
        tl.dropped = still
        tl.depth -= 1

    # -- resize (layout swaps) --------------------------------------------
    def resize(self, n_gates: int, carry: Optional[Dict[int, int]] = None) -> None:
        """Replace the stripe set for a resharded layout. Must be called
        while holding all gates (:meth:`acquire_all`); the swap is only
        visible to writers once this thread's outermost barrier releases.

        ``carry`` maps ``{new_index: old_index}`` for shards whose block
        interval is unchanged — they keep their gate object, so a writer
        queued on that stripe contends with the right shard after the
        swap. New stripes are created ALREADY HELD at the caller's current
        barrier depth (a fresh unlocked gate would let a writer slip into
        the critical section mid-swap); dropped stripes are recorded as
        per-release debts paid off by :meth:`release_all`."""
        tl = self._tl
        depth = getattr(tl, "depth", 0)
        if depth < 1:
            raise RuntimeError("resize requires holding all gates")
        old = self._gates
        if not self.striped:
            new = [old[0]] * n_gates
        else:
            carry = carry or {}
            new = []
            for k in range(n_gates):
                p = carry.get(k)
                if p is not None and 0 <= p < len(old):
                    new.append(old[p])
                else:
                    g = threading.RLock()
                    for _ in range(depth):
                        g.acquire()
                    new.append(g)
        live = {id(g) for g in new}
        for g in dict.fromkeys(old):
            if id(g) not in live:
                tl.dropped.append([g, depth])
        self._wait_s = [
            self._wait_s[carry[k]] if carry and k in carry else 0.0
            for k in range(n_gates)
        ] if self.striped else [0.0] * n_gates
        self._waits = [
            self._waits[carry[k]] if carry and k in carry else 0
            for k in range(n_gates)
        ] if self.striped else [0] * n_gates
        self._gates = new

    # -- observability -----------------------------------------------------
    def wait_summary(self) -> Dict[str, float]:
        """Cumulative per-write acquisition wait across current stripes
        (stripes dropped by a resize take their counts with them)."""
        return {
            "gate_wait_us": sum(self._wait_s) * 1e6,
            "gate_acquires": float(sum(self._waits)),
        }
