"""Striped write gates — one reentrant gate per shard, plus an ordered
all-gate barrier.

PR 2's write gate was a single global ``threading.RLock``: every write on
every shard serialized against every other writer AND against any shard's
fork barrier, proactive sync, or layout swap. That re-created the paper's
out-of-service problem in miniature — snapshot machinery on one shard
stalled the serving path on all of them. Fine-granular per-partition
synchronization (Sharma et al.'s high-frequency virtual snapshotting,
CIDER's per-object pessimistic locks) is how related systems keep
snapshot bookkeeping off the hot path; :class:`GateSet` is that idea for
our coordinator:

  * **writers** take only the stripe of the shard they commit to
    (:meth:`acquire`/``release`` on the returned gate) — writes to
    different shards never contend;
  * **barrier-class operations** (the BGSAVE fork barrier, ``set_layout``,
    ``load``, ``set_copier_duty``) take ALL stripes in deterministic index
    order (:meth:`all`) — the generalization of DESIGN.md §6: "no commit
    *on shard k* between shard k's T0 stamp and barrier release";
  * **layout swaps** resize the stripe set in place (:meth:`resize`,
    called while the swapper holds all gates): unchanged shards keep their
    gate object, changed shards get fresh gates created *already held* by
    the swapping thread, and dropped gates are released at barrier exit so
    writers blocked on them wake, fail validation, and re-route;
  * **readers** (PR 6) may take a stripe in SHARED mode
    (:meth:`acquire_shared`): many readers overlap each other on the same
    stripe and overlap writers on *other* stripes, while the stripe's own
    writer — and every barrier-class op — still excludes them. The
    concurrent read plane only falls back to shared acquisition when its
    seqlock detects churn (``ShardedKVStore.get_concurrent``), so the
    uncontended read path takes no lock at all.

Deadlock freedom: a writer holds at most ONE stripe at a time (a
multi-shard batch commits shard groups sequentially, releasing between
groups), and every all-gate acquirer takes stripes in ascending index
order — no hold-and-wait cycle exists. Acquisition is epoch-validated:
both paths re-check that the stripe list they snapshotted is still the
live one after locking, and retry/raise otherwise, so a writer can never
commit under a stripe that a concurrent reshard retired.

``striped=False`` aliases every stripe to one shared lock — byte-for-byte
the PR-2 global gate, kept as the baseline arm of the ``gate_contention``
benchmark.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple


class GateRetired(RuntimeError):
    """The requested stripe index no longer exists (a concurrent layout
    swap shrank the gate set); the caller must re-route and retry."""


class SharedGate:
    """One gate stripe: an RLock-compatible exclusive side plus a shared
    (reader) mode.

    Exclusive side — ``acquire([blocking])`` / ``release`` — is reentrant
    per thread, exactly like the :class:`threading.RLock` stripes it
    replaces, so the ordered all-gate barrier, nested ``bgsave_to_dir``
    barriers, and :meth:`GateSet.resize`'s born-held fresh stripes all
    work unchanged. Shared side — :meth:`acquire_shared` /
    :meth:`release_shared` — admits any number of concurrent readers
    while no writer holds the gate.

    Writer preference (window-bounded): once a waiting writer's ticket
    ages past :data:`BARGE_WINDOW_S`, new shared acquisitions block, so
    a continuous stream of short readers cannot starve a fork barrier —
    but a FRESH ticket does not turn readers away, so reader tail
    latency never convoys behind every passing writer (readers never
    nest shared holds — the read plane holds at most one stripe at a
    time — so preference cannot deadlock them). A
    thread that holds the gate exclusively may still acquire shared
    (counts as a reader it must release); the reverse upgrade (shared →
    exclusive on the same thread) is a deadlock and must never be coded.

    Bounded exclusive-side starvation: uncontended acquisition barges
    (like the ``RLock`` it replaces — a just-releasing hot writer may
    re-take a free gate ahead of sleeping waiters, which is what keeps
    contended p99 low: fast writers burst through instead of waiting
    out a round-robin of slow ones), BUT only while the longest-blocked
    waiter is younger than :data:`BARGE_WINDOW_S`. Past that, the fast
    path defers and queued writers drain in FIFO ticket order, so a
    blocked all-gate barrier is served within one barge window plus one
    critical section. A bare Condition with no ticketing lets a looping
    writer win every wakeup race forever (running threads always beat
    threads that must first reacquire the condition lock) — that
    starved the fork barrier for MINUTES under a tight commit loop.

    Shared-side contended waits are metered inside the gate (readers are
    concurrent, so per-\\ ``GateSet`` slot accounting would race); the
    exclusive side keeps PR 5's slot-k-under-stripe-k accounting in
    :class:`GateSet`.
    """

    #: how long the oldest queued writer may be barged past (seconds).
    #: Large enough that sub-ms commit critical sections still burst
    #: through instead of FIFO round-robining; small enough that a
    #: barrier blocked behind a hot commit loop is served promptly and
    #: that readers (locked out of shared mode while any writer ticket
    #: is queued — writer preference) never convoy for tens of ms.
    BARGE_WINDOW_S = 0.01

    __slots__ = ("_cv", "_writer", "_depth", "_readers", "_tickets",
                 "_next_ticket", "shared_wait_s", "shared_waits")

    def __init__(self):
        self._cv = threading.Condition(threading.Lock())
        self._writer: Optional[int] = None  # owning thread ident
        self._depth = 0                     # exclusive reentrance depth
        self._readers = 0                   # live shared holders
        self._tickets: Dict[int, float] = {}  # FIFO: ticket -> enqueue time
        self._next_ticket = 0
        self.shared_wait_s = 0.0
        self.shared_waits = 0

    def _may_barge(self) -> bool:
        """True while no queued writer has aged past the barge window.
        (Tickets are issued in increasing order and the dict preserves
        insertion order, so the first entry is the oldest.)"""
        if not self._tickets:
            return True
        oldest = next(iter(self._tickets.values()))
        return (time.monotonic() - oldest) < self.BARGE_WINDOW_S

    # -- exclusive (writer / barrier) side --------------------------------
    def acquire(self, blocking: bool = True) -> bool:
        me = threading.get_ident()
        with self._cv:
            if self._writer == me:
                self._depth += 1
                return True
            if (self._writer is None and self._readers == 0
                    and self._may_barge()):
                self._writer, self._depth = me, 1
                return True
            if not blocking:
                return False
            ticket = self._next_ticket
            self._next_ticket += 1
            self._tickets[ticket] = time.monotonic()
            try:
                while (self._writer is not None or self._readers
                       or next(iter(self._tickets)) != ticket):
                    # timeout so the oldest waiter re-checks even if every
                    # barging acquirer keeps losing the notify race
                    self._cv.wait(self.BARGE_WINDOW_S)
                self._writer, self._depth = me, 1
                return True
            finally:
                self._tickets.pop(ticket, None)
                # an abandoned oldest ticket (interrupted wait) must not
                # wedge the queue behind it
                self._cv.notify_all()

    def release(self) -> None:
        with self._cv:
            if self._writer != threading.get_ident():
                raise RuntimeError("release() of a gate this thread "
                                   "does not hold exclusively")
            self._depth -= 1
            if self._depth == 0:
                self._writer = None
                self._cv.notify_all()

    def __enter__(self) -> "SharedGate":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # -- shared (reader) side ---------------------------------------------
    def acquire_shared(self, blocking: bool = True) -> bool:
        """Join the stripe's reader group; returns False (non-blocking)
        or blocks while a writer holds OR waits for the stripe."""
        me = threading.get_ident()
        t0 = time.perf_counter()
        with self._cv:
            if self._writer == me:
                # barrier/writer thread reading under its own exclusive
                # hold: count it as a reader it must release_shared()
                self._readers += 1
                return True
            if self._writer is None and self._may_barge():
                self._readers += 1
                return True
            if not blocking:
                return False
            # writer preference is window-bounded like the exclusive fast
            # path: only a ticket older than BARGE_WINDOW_S turns readers
            # away, so reader tails never convoy behind every fresh
            # writer ticket while the barrier stays starvation-bounded
            while self._writer is not None or not self._may_barge():
                self._cv.wait(self.BARGE_WINDOW_S)
            self._readers += 1
            self.shared_wait_s += time.perf_counter() - t0
            self.shared_waits += 1
            return True

    def release_shared(self) -> None:
        with self._cv:
            if self._readers < 1:
                raise RuntimeError("release_shared() without a shared hold")
            self._readers -= 1
            if self._readers == 0:
                self._cv.notify_all()


class _AllGates:
    """Context manager over :meth:`GateSet.acquire_all` — fresh per use so
    ``with coord.write_gate:`` composes and nests (stripes are RLocks)."""

    def __init__(self, gates: "GateSet"):
        self._gates = gates

    def __enter__(self) -> "GateSet":
        self._gates.acquire_all()
        return self._gates

    def __exit__(self, *exc) -> None:
        self._gates.release_all()


class GateSet:
    """N per-shard reentrant write gates with an ordered all-gate barrier,
    in-place resizing across layout swaps, and per-stripe wait metering."""

    def __init__(self, n_gates: int, striped: bool = True):
        if n_gates < 1:
            raise ValueError("need at least one gate")
        self.striped = bool(striped)
        if self.striped:
            self._gates: List[SharedGate] = [
                SharedGate() for _ in range(n_gates)
            ]
        else:
            g = SharedGate()  # the PR-2 global gate, aliased N ways
            self._gates = [g] * n_gates
        self._wait_s = [0.0] * n_gates
        self._waits = [0] * n_gates
        self._tl = threading.local()  # all-hold depth + dropped-gate debts

    @property
    def n_gates(self) -> int:
        return len(self._gates)

    # -- single-stripe path (writers) ------------------------------------
    def acquire(self, k: int) -> Tuple[SharedGate, float]:
        """Acquire stripe ``k``; returns ``(gate, wait_seconds)`` — the
        caller releases via ``gate.release()``. ``wait_seconds`` is 0.0
        when the stripe was uncontended (non-blocking fast path), so it
        measures actual CONTENTION, not acquire-call overhead.

        Validated against concurrent resizes: if the stripe list changed
        while we blocked, the (possibly retired) gate is released and the
        acquisition retries against the live list. While the returned gate
        is held the list CANNOT change (a resize needs all stripes), so
        the caller may read layout-swapped state race-free. Raises
        :class:`GateRetired` when ``k`` fell off the end of the set."""
        t0 = time.perf_counter()
        blocked = False
        while True:
            gates = self._gates
            if k >= len(gates):
                raise GateRetired(f"stripe {k} >= {len(gates)} gates")
            g = gates[k]
            if not g.acquire(blocking=False):
                blocked = True
                g.acquire()
            if self._gates is gates:
                wait = (time.perf_counter() - t0) if blocked else 0.0
                # slot k is only written while holding stripe k
                self._wait_s[k] += wait
                self._waits[k] += 1
                return g, wait
            g.release()

    # -- shared-stripe path (readers) ------------------------------------
    def acquire_shared(self, k: int) -> Tuple[SharedGate, float]:
        """Acquire stripe ``k`` in SHARED mode; returns ``(gate,
        wait_seconds)`` — the caller releases via ``gate.release_shared()``.
        Readers overlap each other on the stripe and overlap writers on
        every other stripe; the stripe's own writer and any all-gate
        barrier exclude them (and vice versa).

        Epoch-validated like :meth:`acquire`: a shared hold on stripe
        ``k`` blocks any resize (a resize needs every stripe exclusively),
        so once validated the stripe list — and the routing view a layout
        swap would replace — cannot change while the hold lasts. Raises
        :class:`GateRetired` when ``k`` fell off the end of the set."""
        t0 = time.perf_counter()
        blocked = False
        while True:
            gates = self._gates
            if k >= len(gates):
                raise GateRetired(f"stripe {k} >= {len(gates)} gates")
            g = gates[k]
            if not g.acquire_shared(blocking=False):
                blocked = True
                g.acquire_shared()
            if self._gates is gates:
                return g, (time.perf_counter() - t0) if blocked else 0.0
            g.release_shared()

    # -- all-gate barrier -------------------------------------------------
    def all(self) -> _AllGates:
        return _AllGates(self)

    def acquire_all(self) -> None:
        """Take every stripe in ascending index order (reentrant)."""
        while True:
            gates = self._gates
            uniq = list(dict.fromkeys(gates))  # striped=False aliases
            for g in uniq:
                g.acquire()
            if self._gates is gates:
                break
            for g in reversed(uniq):
                g.release()
        tl = self._tl
        tl.depth = getattr(tl, "depth", 0) + 1
        if not hasattr(tl, "dropped"):
            tl.dropped = []

    def release_all(self) -> None:
        """Release the CURRENT stripe list (which a nested :meth:`resize`
        may have replaced since acquisition) plus one debt payment on each
        gate a resize dropped — so writers blocked on retired stripes wake
        exactly when the barrier that retired them exits."""
        tl = self._tl
        if getattr(tl, "depth", 0) < 1:
            raise RuntimeError("release_all without matching acquire_all")
        for g in reversed(list(dict.fromkeys(self._gates))):
            g.release()
        still = []
        for debt in tl.dropped:
            debt[0].release()
            debt[1] -= 1
            if debt[1] > 0:
                still.append(debt)
        tl.dropped = still
        tl.depth -= 1

    # -- resize (layout swaps) --------------------------------------------
    def resize(self, n_gates: int, carry: Optional[Dict[int, int]] = None) -> None:
        """Replace the stripe set for a resharded layout. Must be called
        while holding all gates (:meth:`acquire_all`); the swap is only
        visible to writers once this thread's outermost barrier releases.

        ``carry`` maps ``{new_index: old_index}`` for shards whose block
        interval is unchanged — they keep their gate object, so a writer
        queued on that stripe contends with the right shard after the
        swap. New stripes are created ALREADY HELD at the caller's current
        barrier depth (a fresh unlocked gate would let a writer slip into
        the critical section mid-swap); dropped stripes are recorded as
        per-release debts paid off by :meth:`release_all`."""
        tl = self._tl
        depth = getattr(tl, "depth", 0)
        if depth < 1:
            raise RuntimeError("resize requires holding all gates")
        old = self._gates
        if not self.striped:
            new = [old[0]] * n_gates
        else:
            carry = carry or {}
            new = []
            for k in range(n_gates):
                p = carry.get(k)
                if p is not None and 0 <= p < len(old):
                    new.append(old[p])
                else:
                    g = SharedGate()
                    for _ in range(depth):
                        g.acquire()
                    new.append(g)
        live = {id(g) for g in new}
        for g in dict.fromkeys(old):
            if id(g) not in live:
                tl.dropped.append([g, depth])
        self._wait_s = [
            self._wait_s[carry[k]] if carry and k in carry else 0.0
            for k in range(n_gates)
        ] if self.striped else [0.0] * n_gates
        self._waits = [
            self._waits[carry[k]] if carry and k in carry else 0
            for k in range(n_gates)
        ] if self.striped else [0] * n_gates
        self._gates = new

    # -- observability -----------------------------------------------------
    def wait_summary(self) -> Dict[str, float]:
        """Cumulative per-write acquisition wait across current stripes
        (stripes dropped by a resize take their counts with them). Shared
        (reader) waits are metered inside each stripe — readers are
        concurrent, so slot-per-stripe accounting would race — and summed
        over the distinct live gates here."""
        uniq = list(dict.fromkeys(self._gates))
        return {
            "gate_wait_us": sum(self._wait_s) * 1e6,
            "gate_acquires": float(sum(self._waits)),
            "shared_wait_us": sum(g.shared_wait_s for g in uniq) * 1e6,
            "shared_waits": float(sum(g.shared_waits for g in uniq)),
        }
