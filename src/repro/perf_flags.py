"""Perf-iteration feature flags (§Perf hillclimb).

The baseline sweep runs with no flags; each hypothesis toggles one flag so
before/after lowerings are controlled experiments:

  REPRO_PERF_OPT=attn_flat,pv_bf16,ssm_chunk,batch_shard

  attn_flat   — expand K/V to flat q-head space + head-shard the score
                einsum (kills per-layer f32 partial-sum all-reduces at the
                SP/TP boundary)
  pv_bf16     — probs@V einsum in bf16 (softmax stays f32)
  ssm_chunk   — time-chunked remat for mLSTM/Mamba2 scans (store chunk
                boundaries, recompute inside chunks on backward)
  batch_shard — recurrent models shard batch over the model axis too
"""
from __future__ import annotations

import os

_FLAGS = frozenset(
    f.strip() for f in os.environ.get("REPRO_PERF_OPT", "").split(",") if f.strip()
)


def enabled(name: str) -> bool:
    return name in _FLAGS


ATTN_FLAT = enabled("attn_flat")
ATTN_QSEQ = enabled("attn_qseq")   # q seq-sharded + K/V replicated (bf16
                                   # all-gather instead of f32 all-reduce)
ATTN_TP = enabled("attn_tp")       # K/V head-sharded like Q (classic TP
                                   # attention; falls back when kv-heads
                                   # don't divide the model axis)
PV_BF16 = enabled("pv_bf16")
SSM_CHUNK = enabled("ssm_chunk")
BATCH_SHARD = enabled("batch_shard")
