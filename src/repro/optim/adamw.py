"""AdamW in pure JAX with global-norm clipping.

Moments are fp32 regardless of param dtype (the production layout: bf16
params + fp32 m/v); the state pytree mirrors the param tree so the
checkpoint manager's block table covers it uniformly.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Dict
    v: Dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Tuple[Dict, AdamWState]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
