"""Step factories: the jit-able train / prefill / decode steps that the
launcher shards and the dry-run lowers.

train_step donates (params, opt_state) — on TPU this is what makes the
async-fork checkpoint protection necessary: the pre-step buffers die at
every step boundary (see repro.checkpoint.manager).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


def make_train_step(model, *, peak_lr: float = 3e-4, donate: bool = True):
    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        lr = cosine_schedule(opt_state.step, peak_lr=peak_lr)
        new_params, new_opt = adamw_update(params, grads, opt_state, lr)
        return new_params, new_opt, loss

    return train_step


def make_prefill_step(model, cfg, shape):
    def prefill_step(params, batch):
        if cfg.family == "audio":
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 cache_len=shape.seq_len)
        return model.prefill(params, batch["tokens"], cache_len=shape.seq_len)

    return prefill_step


def make_decode_step(model, cfg, shape):
    def decode_step(params, cache, batch):
        kwargs = {}
        if cfg.family == "vlm" and "mrope_positions" in batch:
            kwargs["mrope_positions"] = batch["mrope_positions"]
        return model.decode_step(params, cache, batch["tokens"], batch["pos"],
                                 **kwargs)

    return decode_step


def init_train_state(model, rng):
    params = model.init(rng)
    return params, adamw_init(params)
