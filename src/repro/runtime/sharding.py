"""Divisibility-aware sharding resolution.

Model code declares *logical* PartitionSpecs (axis names like "layers"
that are not mesh axes, TP specs on head counts that may not divide the
mesh, etc.). ``resolve_pspec`` turns a logical spec into a legal physical
spec for a concrete (mesh, shape):

  * names that are not mesh axes -> None (e.g. the stacked-"layers" dim)
  * a dim whose size does not divide the assigned mesh-axis product is
    replicated instead (e.g. 8 KV heads on a 16-way "model" axis)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """Version-guarded ``jax.make_mesh``.

    ``jax.sharding.AxisType`` exists only on newer JAX (and the
    ``axis_types=`` kwarg with it); older releases build the same
    Auto-typed mesh with no kwarg. All repo code goes through this helper
    so both old and new JAX work unchanged.
    """
    shapes, names = tuple(axis_shapes), tuple(axis_names)
    if not hasattr(jax, "make_mesh"):  # predates jax.make_mesh entirely
        from jax.experimental import mesh_utils

        devs = mesh_utils.create_device_mesh(
            shapes, devices=list(devices) if devices is not None else None
        )
        return Mesh(devs, names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(names)
    return jax.make_mesh(shapes, names, **kw)


def _axis_size(mesh: Mesh, entry) -> int:
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape))[n]
    return size


def resolve_pspec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    if spec is None:
        return P()
    entries = list(spec)
    # pad/truncate to rank
    entries = entries[: len(shape)] + [None] * (len(shape) - len(entries))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in mesh.axis_names)
        if not names:
            out.append(None)
            continue
        size = _axis_size(mesh, tuple(names))
        if dim % size != 0:
            out.append(None)  # replicate: not divisible
        else:
            out.append(names if len(names) > 1 else names[0])
    return P(*out)


def resolve_tree(spec_tree, shape_tree, mesh: Mesh):
    """Map resolve_pspec over parallel (spec, shape) pytrees -> NamedShardings."""
    return jax.tree_util.tree_map(
        lambda spec, arr: NamedSharding(
            mesh, resolve_pspec(spec, tuple(arr.shape), mesh)
        ),
        spec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def state_shardings(model, params_shape, opt_shape, mesh: Mesh):
    """Shardings for (params, AdamWState) from the model's logical specs."""
    from repro.optim.adamw import AdamWState

    pspecs = model.param_pspecs()
    param_sh = resolve_tree(pspecs, params_shape, mesh)
    opt_sh = AdamWState(
        step=NamedSharding(mesh, P()),
        m=resolve_tree(pspecs, opt_shape.m, mesh),
        v=resolve_tree(pspecs, opt_shape.v, mesh),
    )
    return param_sh, opt_sh
