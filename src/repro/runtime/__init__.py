from repro.runtime.sharding import resolve_pspec, resolve_tree, state_shardings
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step

__all__ = [
    "resolve_pspec",
    "resolve_tree",
    "state_shardings",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]
