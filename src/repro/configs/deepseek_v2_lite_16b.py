"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]
27L d_model=2048 16H d_ff=1408(expert) vocab=102400, MLA kv_lora=512,
2 shared + 64 routed experts top-6 (first layer dense).

NOTE: the assignment line says both "MoE 64e top-6" and "160 routed"; the
HF config for DeepSeek-V2-Lite has 64 routed experts (160 belongs to the
full V2). We follow the 64-routed reading and record the discrepancy in
DESIGN.md."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # MLA: per-head kv after up-projection
    d_ff=10944,             # the single dense layer's FFN
    vocab=102400,
    head_dim=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_dense_layers=1,
    kv_lora_rank=512,
    rope_head_dim=64,
    v_head_dim=128,
    rope_theta=1e4,
)
