"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]
48L d_model=2048 32H (GQA kv=4) d_ff_expert=768 vocab=151936, MoE 128e top-8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=0,                 # every layer is MoE (no dense FFN layers)
    vocab=151936,
    head_dim=128,           # qwen3 uses explicit head_dim 128
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    rope_theta=1e6,
)
