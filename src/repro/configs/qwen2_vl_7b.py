"""qwen2-vl-7b [arXiv:2409.12191; hf] — M-RoPE transformer backbone.
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings + 3D (t,h,w) M-RoPE position ids."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    mrope=True,
    rope_theta=1e6,
)
