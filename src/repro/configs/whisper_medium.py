"""whisper-medium [arXiv:2212.04356; unverified] — enc-dec, conv frontend stub.
24L(dec) + 24L(enc) d_model=1024 16H d_ff=4096 vocab=51865.
The conv/mel frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings for the encoder."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    encoder_layers=24,
    rope_theta=1e4,   # we use RoPE in place of learned abs positions
)
