"""zamba2-2.7b [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention.
54L d_model=2560 32H d_ff=10240 vocab=32000, ssm_state=64.
Shared transformer block applied every 6 Mamba2 layers (weights shared)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    shared_attn_every=6,
    subquadratic=True,       # Mamba2 recurrence; shared attn uses KV cache
)
