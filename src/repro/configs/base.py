"""Architecture config schema for the assigned 10-arch pool.

Every architecture in the pool is expressed as one ``ArchConfig`` (exact
figures from the assignment table); ``reduced()`` derives the CPU smoke
config of the same family. Input shapes are global (pre-sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0             # deepseek: layer 0 is dense
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    shared_attn_every: int = 0              # zamba2: shared attn block period
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    # --- misc ---
    rope_theta: float = 1e6
    mrope: bool = False                     # qwen2-vl M-RoPE
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context applicability: sub-quadratic archs only
    subquadratic: bool = False
    # dry-run probes: unroll the layer scan so XLA's cost analysis (which
    # counts a while-loop body once) sees every layer
    unroll_layers: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way TP."""
        return pad_to(self.vocab, 256)

    def supports(self, shape: ShapeCfg) -> Tuple[bool, str]:
        """Which assigned shapes this arch runs (skips documented in
        DESIGN.md §Arch-applicability)."""
        if shape.name == "long_500k" and not self.subquadratic:
            return False, "full attention is O(S^2); 512k decode needs sub-quadratic arch"
        return True, ""

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Same family, laptop scale: for per-arch CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            d_ff_expert=32 if self.d_ff_expert else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            q_lora_rank=0,
            rope_head_dim=8 if self.kv_lora_rank else self.rope_head_dim,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=16 if self.ssm_state else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            first_dense_layers=min(self.first_dense_layers, 1),
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline utility)."""
        d, ff, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_padded
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio":
            # encoder + decoder + cross attention
            attn = 4 * d * self.n_heads * hd
            enc = self.encoder_layers * (attn + 2 * d * ff)
            dec = L * (2 * attn + 2 * d * ff)
            return emb + enc + dec
        if self.kv_lora_rank:  # MLA
            r, rr, vd = self.kv_lora_rank, self.rope_head_dim, (self.v_head_dim or hd)
            attn = (
                d * self.n_heads * (hd + rr)          # q proj (nope+rope)
                + d * (r + rr)                        # kv down
                + r * self.n_kv_heads * (hd + vd)     # kv up
                + self.n_heads * vd * d               # o proj
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":
            blk = 8 * d * d  # xlstm qkv/gates/up/down approx (factor-2 proj)
            return emb + L * blk
        if self.family == "hybrid":
            dm = 2 * d
            mamba = 2 * d * dm + dm * (2 * self.ssm_state) + dm * d + dm  # in,Bc,out,dt
            shared = attn + 2 * d * ff
            n_shared_uses = L // max(1, self.shared_attn_every)
            return emb + L * mamba + shared + n_shared_uses * d * d
        mlp = 3 * d * ff if ff else 0
        dense_part = attn + mlp
        if self.n_experts:
            moe_mlp = 3 * d * self.d_ff_expert * (self.n_experts + self.n_shared_experts)
            router = d * self.n_experts
            n_moe = L - self.first_dense_layers
            return (
                emb
                + self.first_dense_layers * (attn + 3 * d * (self.d_ff or self.d_ff_expert * 8))
                + n_moe * (attn + moe_mlp + router)
            )
        return emb + L * dense_part

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        n_moe = self.n_layers - self.first_dense_layers
        all_experts = 3 * d * self.d_ff_expert * self.n_experts
        active_experts = 3 * d * self.d_ff_expert * self.top_k
        return full - n_moe * (all_experts - active_experts)
