"""Architecture registry: ``--arch <id>`` resolves here."""
from repro.configs.base import ArchConfig, ShapeCfg, SHAPES

from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.deepseek_v2_lite_16b import CONFIG as _dsv2_lite
from repro.configs.deepseek_67b import CONFIG as _ds67
from repro.configs.phi3_medium_14b import CONFIG as _phi3_med
from repro.configs.mistral_large_123b import CONFIG as _mistral_large
from repro.configs.phi3_mini_3_8b import CONFIG as _phi3_mini
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.qwen2_vl_7b import CONFIG as _qwen2_vl
from repro.configs.zamba2_2_7b import CONFIG as _zamba2
from repro.configs.whisper_medium import CONFIG as _whisper

REGISTRY = {
    c.arch_id: c
    for c in [
        _qwen3_moe,
        _dsv2_lite,
        _ds67,
        _phi3_med,
        _mistral_large,
        _phi3_mini,
        _xlstm,
        _qwen2_vl,
        _zamba2,
        _whisper,
    ]
}

ARCH_IDS = sorted(REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")


__all__ = ["ArchConfig", "ShapeCfg", "SHAPES", "REGISTRY", "ARCH_IDS", "get_config"]
