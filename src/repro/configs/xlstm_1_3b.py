"""xlstm-1.3b [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.
48L d_model=2048 4H vocab=50304; recurrent (sub-quadratic, O(1) decode)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                 # xLSTM blocks carry their own up/down projection
    vocab=50304,
    subquadratic=True,
)
