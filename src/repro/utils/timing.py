"""Monotonic timing helpers (the engine's latency bookkeeping)."""
from __future__ import annotations

import time


def now_s() -> float:
    return time.perf_counter()


class Timer:
    """Context manager measuring wall time in seconds."""

    def __enter__(self):
        self.start = time.perf_counter()
        self.elapsed = 0.0
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start
        return False
