from repro.utils.tree import flatten_with_paths, leaf_nbytes, tree_bytes
from repro.utils.timing import Timer, now_s

__all__ = ["flatten_with_paths", "leaf_nbytes", "tree_bytes", "Timer", "now_s"]
