"""Pytree helpers shared by the snapshot core and the checkpoint manager."""
from __future__ import annotations

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:  # pragma: no cover - future jax key types
            parts.append(str(p))
    return "/".join(parts) if parts else "<root>"


def flatten_with_paths(tree):
    """Flatten ``tree`` -> (list[(path_str, leaf)], treedef).

    The path strings name the "VMAs" of the block table; they are stable
    across processes and stored in checkpoint manifests.
    """
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(_path_str(p), leaf) for p, leaf in leaves_with_paths], treedef


def leaf_nbytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize if leaf.shape else np.dtype(leaf.dtype).itemsize


def tree_bytes(tree) -> int:
    return sum(leaf_nbytes(l) for l in jax.tree_util.tree_leaves(tree))
