"""Pallas TPU kernels for the snapshot copy hot path.

The paper's optimized operation is the page-table/block copy. On TPU the
equivalent data movement is an HBM->HBM masked block copy staged through
VMEM. Two kernels:

  * ``snapcopy``  — copy block b from src to dst iff ``flags[b]`` says
    UNCOPIED, and flip the flag to COPIED. Blocks already copied by the
    parent's proactive sync are *skipped entirely* (no HBM read of src),
    which is the kernel-level analogue of Async-fork's "eliminating
    unnecessary synchronizations" (§4.2).
  * ``dirty``     — block-level delta detection between the previous
    snapshot epoch and the live state; drives incremental snapshots
    (beyond-paper optimization: persist only blocks that changed).

Tiling: grid is (n_blocks, n_tiles); each tile is a (1, TILE) VMEM-resident
strip with TILE a multiple of 128*8 so loads/stores are lane/sublane
aligned for the VPU. Copy is pure data movement — the roofline term is
HBM bandwidth; the skip predicate is what moves it below 2x state bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UNCOPIED = 0
COPIED = 2

DEFAULT_TILE = 1024  # elements per VMEM strip (x4B = 4KiB lanes-aligned)


def _snapcopy_kernel(flags_ref, src_ref, dst_in_ref, dst_ref, nflags_ref):
    flag = flags_ref[0]

    @pl.when(flag == UNCOPIED)
    def _copy():
        dst_ref[...] = src_ref[...]

    @pl.when(flag != UNCOPIED)
    def _keep():
        dst_ref[...] = dst_in_ref[...]

    nflags_ref[0] = jnp.where(flag == UNCOPIED, COPIED, flag)


def snapcopy(src, dst, flags, *, tile: int = DEFAULT_TILE,
             interpret: bool = True):
    """src, dst: (n_blocks, block_elems) same dtype; flags: (n_blocks,) i32.

    Returns (new_dst, new_flags). Blocks with flag != UNCOPIED keep their
    existing dst content (the parent already proactively copied them).
    """
    n_blocks, elems = src.shape
    tile = min(tile, elems)
    assert elems % tile == 0, f"block elems {elems} % tile {tile} != 0"
    n_tiles = elems // tile
    grid = (n_blocks, n_tiles)
    return pl.pallas_call(
        _snapcopy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, t: (b,)),
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(src.shape, src.dtype),
            jax.ShapeDtypeStruct(flags.shape, flags.dtype),
        ],
        interpret=interpret,
    )(flags, src, dst)


def _dirty_kernel(old_ref, new_ref, flag_ref):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        flag_ref[0] = jnp.int32(0)

    diff = jnp.any(old_ref[...] != new_ref[...])
    flag_ref[0] = jnp.where(diff, jnp.int32(1), flag_ref[0])


def dirty(old, new, *, tile: int = DEFAULT_TILE, interpret: bool = True):
    """Per-block delta detection: (n_blocks,) int32, 1 where any element
    of the block differs. Grid iterations over tiles accumulate into the
    same flag block (sequential TPU grid semantics)."""
    n_blocks, elems = old.shape
    tile = min(tile, elems)
    assert elems % tile == 0
    n_tiles = elems // tile
    return pl.pallas_call(
        _dirty_kernel,
        grid=(n_blocks, n_tiles),
        in_specs=[
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
            pl.BlockSpec((1, tile), lambda b, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, t: (b,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks,), jnp.int32),
        interpret=interpret,
    )(old, new)
