"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python for correctness); on TPU set ``interpret=False`` and the
same BlockSpecs drive real VMEM tiling.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import snapcopy as _k

ON_TPU = jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("tile",))
def masked_block_copy(src, dst, flags, tile: int = _k.DEFAULT_TILE):
    return _k.snapcopy(src, dst, flags, tile=tile, interpret=not ON_TPU)


@partial(jax.jit, static_argnames=("tile",))
def dirty_blocks(old, new, tile: int = _k.DEFAULT_TILE):
    return _k.dirty(old, new, tile=tile, interpret=not ON_TPU)


def as_blocks(x, block_elems: int):
    """View a flat array as (n_blocks, block_elems), padding the tail."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_elems)
