"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run with interpret=True (the kernel body
executes in Python for correctness); on TPU set ``interpret=False`` and the
same BlockSpecs drive real VMEM tiling.

The ``*_op`` entry points are what the snapshot core's ``DeviceStaging``
backend calls: they pick a legal tile for arbitrary block widths and keep
the (src, dst, flags) round trip entirely in device arrays — the flag
vector is the device-side mirror of the ``BlockTable`` state machine, so
the kernel's skip predicate implements §4.2's "eliminating unnecessary
synchronizations" on the copy path itself.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import snapcopy as _k

ON_TPU = jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("tile",))
def masked_block_copy(src, dst, flags, tile: int = _k.DEFAULT_TILE):
    return _k.snapcopy(src, dst, flags, tile=tile, interpret=not ON_TPU)


@partial(jax.jit, static_argnames=("tile",))
def dirty_blocks(old, new, tile: int = _k.DEFAULT_TILE):
    return _k.dirty(old, new, tile=tile, interpret=not ON_TPU)


# Interpret mode executes one Python iteration per grid step, so the tile
# cap is the latency knob: keep strips VMEM-sized on real TPU, but let a
# strip span a whole block on CPU where the "VMEM" is imaginary and grid
# steps are the only cost.
_TILE_CAP = _k.DEFAULT_TILE if ON_TPU else (1 << 18)


def pick_tile(elems: int, cap: int = None) -> int:
    """Largest power-of-two divisor of ``elems`` not above ``cap``.

    Block widths come from arbitrary (rows_per_block * row_elems) products,
    which are usually power-of-two-rich but not guaranteed multiples of the
    default tile; the grid still needs elems % tile == 0.
    """
    if cap is None:
        cap = _TILE_CAP
    tile = 1
    while tile * 2 <= cap and elems % (tile * 2) == 0:
        tile *= 2
    return tile


def snapcopy_op(src, dst, flags, *, tile: int | None = None):
    """Masked block copy with automatic legal tiling.

    src, dst: (n_blocks, elems) same dtype; flags: (n_blocks,) int32 with
    the BlockTable convention (0 = UNCOPIED is copied + flipped to COPIED;
    anything else keeps the existing dst content). Returns (dst', flags').
    """
    if tile is None:
        tile = pick_tile(src.shape[1])
    return masked_block_copy(src, dst, flags, tile=tile)


def dirty_op(old, new, *, tile: int | None = None):
    """Block-level delta detection with automatic legal tiling.

    Returns (n_blocks,) int32: 1 where any element of the block differs.
    """
    if tile is None:
        tile = pick_tile(old.shape[1])
    return dirty_blocks(old, new, tile=tile)


def as_blocks(x, block_elems: int):
    """View a flat array as (n_blocks, block_elems), padding the tail."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block_elems
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_elems)


@partial(jax.jit, static_argnames=("n_blocks", "elems"))
def to_blocked(leaf, n_blocks: int, elems: int):
    """Reshape a leaf into its (n_blocks, elems) block-table layout.

    Valid because blocks partition a leaf into equal contiguous row ranges
    (only the last block may be short): the layout is exactly ``as_blocks``
    with the tail pad landing entirely in the final block. ``n_blocks`` is
    static so a geometry mismatch fails at trace time, not silently.
    """
    blocked = as_blocks(jnp.asarray(leaf), elems)
    assert blocked.shape[0] == n_blocks, (blocked.shape, n_blocks)
    return blocked


def flags_to_device(flags) -> jax.Array:
    """Host BlockState values -> device int32 flag vector for the kernels."""
    return jnp.asarray(np.asarray(flags, dtype=np.int32))


def flags_from_device(flags) -> np.ndarray:
    """Kernel flag vector -> host int32 (for folding back into BlockTable)."""
    return np.asarray(flags, dtype=np.int32)
