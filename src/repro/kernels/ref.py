"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.snapcopy import COPIED, UNCOPIED


def snapcopy_ref(src, dst, flags):
    """Masked block copy oracle."""
    mask = (flags == UNCOPIED)[:, None]
    new_dst = jnp.where(mask, src, dst)
    new_flags = jnp.where(flags == UNCOPIED, COPIED, flags)
    return new_dst, new_flags


def dirty_ref(old, new):
    """Block-delta oracle."""
    return jnp.any(old != new, axis=1).astype(jnp.int32)
