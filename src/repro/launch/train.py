"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this runs the sharded train step on the production mesh;
on this CPU container use ``--local`` (reduced config, host mesh) — the
code path (mesh, shard_map MoE, checkpoint manager) is identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import TrainSnapshotManager
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ShapeCfg
from repro.data.pipeline import SyntheticPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--local", action="store_true",
                    help="reduced config on the host mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-mode", default="asyncfork",
                    choices=["blocking", "asyncfork"])
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint dir (default: outside the repo tree, see repro.checkpoint.default_checkpoint_dir)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.local:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        shape = ShapeCfg("local", seq_len=64, global_batch=4, kind="train")
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape = SHAPES[args.shape]

    model = build_model(cfg)
    pipe = SyntheticPipeline(cfg, shape, seed=0)
    data = iter(pipe)
    mgr = TrainSnapshotManager(args.ckpt_dir, mode=args.ckpt_mode)

    with mesh:
        params, opt = init_train_state(model, jax.random.PRNGKey(0))
        fn = make_train_step(model)
        donating = jax.jit(fn, donate_argnums=(0, 1))
        nondonating = jax.jit(fn)
        for step in range(args.steps):
            batch = next(data)
            t0 = time.perf_counter()
            if args.ckpt_every and step and step % args.ckpt_every == 0:
                mgr.save(step, params, opt)
            f = nondonating if mgr.snapshot_active() else donating
            params, opt, loss = f(params, opt, batch)
            loss.block_until_ready()
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
    pipe.close()
    mgr.wait_all()
    if mgr.stall_log:
        print("checkpoint stalls:", mgr.summary())


if __name__ == "__main__":
    main()
