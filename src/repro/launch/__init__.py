"""Launch-scale tooling: meshes, dry-run cost model, serving/training drivers."""
