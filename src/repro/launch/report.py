"""Aggregate dry-run JSON cells into the §Dry-run / §Roofline tables.

Usage: PYTHONPATH=src python -m repro.launch.report results/dryrun_baseline
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(os.listdir(out_dir)):
        if f.endswith(".json"):
            with open(os.path.join(out_dir, f)) as fh:
                rows.append(json.load(fh))
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def table(rows: List[Dict], mesh: str = "single") -> str:
    out = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
        "6ND/HLO | roofline | mem/dev GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip: {r['reason'][:40]} | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status'].upper()} | — | — | — | — |")
            continue
        rl = r["roofline"]
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(rl['compute_s'])} | "
            f"{fmt_ms(rl['memory_s'])} | {fmt_ms(rl['collective_s'])} | "
            f"{rl['dominant'].replace('_s','')} | "
            f"{rl.get('useful_flops_frac', 0):.2f} | "
            f"{rl.get('roofline_frac', 0):.3f} | "
            f"{m['peak_bytes_per_dev']/1e9:.1f} | "
            f"{'Y' if m['fits_16GB'] else 'N'} |"
        )
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skipped"]
    bad = [r for r in rows if r["status"] not in ("ok", "skipped")]
    lines = [f"cells: {len(rows)} ok={len(ok)} skipped={len(skip)} "
             f"failed={len(bad)}"]
    for r in bad:
        lines.append(f"  FAILED {r['arch']} x {r['shape']} x {r['mesh']}")
    fits = sum(1 for r in ok if r["memory"]["fits_16GB"])
    lines.append(f"fits 16GB/dev: {fits}/{len(ok)}")
    if ok:
        worst = min(
            (r for r in ok if r["shape"] == "train_4k"),
            key=lambda r: r["roofline"].get("roofline_frac", 0),
            default=None,
        )
        if worst:
            lines.append(
                f"worst train roofline: {worst['arch']} "
                f"({worst['roofline'].get('roofline_frac', 0):.3f})"
            )
        coll = max(
            ok, key=lambda r: r["roofline"]["collective_s"]
            / max(1e-12, r["roofline"]["bound_s"]),
        )
        lines.append(f"most collective-bound: {coll['arch']} x {coll['shape']}")
    return "\n".join(lines)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline"
    rows = load(out_dir)
    print(summary(rows))
    print()
    for mesh in ("single", "multi"):
        print(f"### mesh: {mesh}\n")
        print(table(rows, mesh))
        print()


if __name__ == "__main__":
    main()
