import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
#   512 placeholder host devices back both production meshes (256 single-pod
#   + 512 multi-pod). Never set this outside this module.

# Multi-pod dry run: prove every (arch x shape x mesh) lowers, compiles,
# fits per-device memory, and yield the cost/collective numbers §Roofline
# reads. Failures here are bugs in the framework's sharding.
#
# Usage:
#   python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k --mesh single
#   python -m repro.launch.dryrun --all --out results/dryrun   # sweep (resumable)
# (no ``from __future__``: the XLA_FLAGS lines above must stay first.)

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.data.pipeline import batch_pspecs, make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, roofline
from repro.models import build_model
from repro.optim.adamw import adamw_init
from repro.runtime.sharding import resolve_pspec, resolve_tree
from repro.runtime.steps import make_decode_step, make_prefill_step, make_train_step


def _shardings_for(tree_specs, tree_shapes, mesh):
    return jax.tree_util.tree_map(
        lambda spec, sds: NamedSharding(mesh, resolve_pspec(spec, tuple(sds.shape), mesh)),
        tree_specs,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _compile_cell(cfg, shape, mesh):
    """Lower + compile one (config, shape) on ``mesh``; return compiled."""
    multi = "pod" in mesh.axis_names
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = model.param_pspecs()
    param_sh = _shardings_for(pspecs, params_shape, mesh)
    batch_specs = make_batch_specs(cfg, shape)
    batch_sh = {
        k: NamedSharding(mesh, resolve_pspec(s, tuple(batch_specs[k].shape), mesh))
        for k, s in batch_pspecs(cfg, shape, multi).items()
    }
    with mesh:
        if shape.kind == "train":
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            opt_sh = type(opt_shape)(
                step=NamedSharding(mesh, P()),
                m=_shardings_for(pspecs, opt_shape.m, mesh),
                v=_shardings_for(pspecs, opt_shape.v, mesh),
            )
            step = make_train_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_shape, opt_shape, batch_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, cfg, shape)
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_shape, batch_specs)
        else:  # decode
            B = shape.global_batch
            cache_shape = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
            cache_specs = (
                model.cache_pspecs(B) if cfg.family == "hybrid"
                else model.cache_pspecs()
            )
            cache_sh = _shardings_for(cache_specs, cache_shape, mesh)
            step = make_decode_step(model, cfg, shape)
            bdim = ("pod", "data") if multi else ("data",)
            logits_sh = NamedSharding(
                mesh, resolve_pspec(P(bdim, None, "model"),
                                    (B, 1, cfg.vocab_padded), mesh))
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, batch_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_shape, cache_shape, batch_specs)
        return lowered.compile()


def _cost_of(compiled):
    cost = compiled.cost_analysis() or {}
    # older JAX returns a one-entry list of per-device dicts
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    colls = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(sum(colls.values())), colls)


def _depth_points(cfg) -> tuple:
    """Two reduced depths for per-layer cost extrapolation. XLA's HLO cost
    analysis counts a while-loop (scan) body ONCE, so full-depth flops are
    underreported; compiling the same cell at depths L1 < L2 and linearly
    extrapolating recovers exact per-layer cost incl. remat/collectives."""
    if cfg.family == "ssm":
        return 8, 16       # one / two full [7 mLSTM + 1 sLSTM] groups
    if cfg.family == "hybrid":
        p = cfg.shared_attn_every or 1
        return p, 2 * p    # one / two mamba groups + shared block
    lo = max(1, cfg.first_dense_layers)
    return lo, lo + 1


def _with_depth(cfg, L: int):
    kw = {"n_layers": L, "unroll_layers": True}
    if cfg.family == "audio":
        kw["encoder_layers"] = L
    import dataclasses as _dc

    return _dc.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    t0 = time.time()

    # full-depth compile: memory fit + the deliverable artifact
    compiled = _compile_cell(cfg, shape, mesh)
    mem = compiled.memory_analysis()
    raw_flops, raw_bytes, raw_cbytes, colls = _cost_of(compiled)

    # depth extrapolation for loop-aware cost (see _depth_points)
    L1, L2 = _depth_points(cfg)
    f1, b1, c1, _ = _cost_of(_compile_cell(_with_depth(cfg, L1), shape, mesh))
    f2, b2, c2, _ = _cost_of(_compile_cell(_with_depth(cfg, L2), shape, mesh))
    L = cfg.n_layers
    scale = (L - L1) / max(1, (L2 - L1))
    flops = f1 + (f2 - f1) * scale
    nbytes = b1 + (b2 - b1) * scale
    cbytes = c1 + (c2 - c1) * scale

    # time-recurrence FLOPs (SSM/hybrid): invisible to HLO cost analysis
    model = build_model(cfg)
    rec_flops = 0.0
    if hasattr(model, "recurrence_flops_per_device"):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1) * sizes.get("pod", 1)
        tp = sizes.get("model", 1)
        B = shape.global_batch
        S = shape.seq_len if shape.kind != "decode" else 1
        mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd recompute
        rec_flops = mult * model.recurrence_flops_per_device(B, S, dp, tp)
        flops += rec_flops

    # MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode D = batch.
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * d_tokens
    elif shape.kind == "prefill":
        d_tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * d_tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch

    rl = roofline(flops, nbytes, cbytes, chips=chips,
                  model_flops_global=model_flops)
    per_dev_bytes = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        - mem.alias_size_in_bytes + mem.temp_size_in_bytes
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "flops_per_dev": flops,
        "bytes_per_dev": nbytes,
        "collective_bytes_per_dev": cbytes,
        "raw_loop_uncorrected": {
            "flops": raw_flops, "bytes": raw_bytes, "coll_bytes": raw_cbytes,
        },
        "depth_points": [L1, L2],
        "collectives": colls,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_dev": per_dev_bytes,
            "fits_16GB": bool(per_dev_bytes < 16e9),
        },
        "roofline": rl,
        "param_count": cfg.param_count(),
        "active_param_count": n_active,
    }
    return result


def _print_cell(r: Dict) -> None:
    if r["status"] != "ok":
        print(f"[dryrun] {r['arch']} x {r['shape']} x {r['mesh']}: "
              f"SKIP ({r.get('reason','')})")
        return
    m = r["memory"]
    rl = r["roofline"]
    print(
        f"[dryrun] {r['arch']} x {r['shape']} x {r['mesh']}: OK "
        f"({r['chips']} chips, compile {r['compile_s']}s)\n"
        f"  mem/dev: args={m['argument_bytes']/1e9:.2f}GB "
        f"temp={m['temp_bytes']/1e9:.2f}GB peak~{m['peak_bytes_per_dev']/1e9:.2f}GB "
        f"fits16GB={m['fits_16GB']}\n"
        f"  roofline: compute={rl['compute_s']*1e3:.2f}ms "
        f"memory={rl['memory_s']*1e3:.2f}ms collective={rl['collective_s']*1e3:.2f}ms "
        f"dominant={rl['dominant']} frac={rl.get('roofline_frac', 0):.3f}"
    )


def sweep(out_dir: str, mesh_kinds=("single", "multi"), archs=None,
          shapes=None, timeout_s: int = 1800) -> None:
    """Resumable full sweep; each cell runs in a fresh subprocess."""
    os.makedirs(out_dir, exist_ok=True)
    archs = archs or ARCH_IDS
    shapes = shapes or list(SHAPES)
    for mesh_kind in mesh_kinds:
        for arch in archs:
            for shape in shapes:
                path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
                if os.path.exists(path):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                       "--json", path]
                print(f"[sweep] {arch} x {shape} x {mesh_kind} ...", flush=True)
                env = dict(os.environ)
                env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
                try:
                    p = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=timeout_s, env=env)
                    if p.returncode != 0:
                        with open(path, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "mesh": mesh_kind, "status": "error",
                                       "stderr": p.stderr[-4000:]}, f, indent=1)
                        print(f"[sweep]   ERROR (rc={p.returncode})", flush=True)
                    else:
                        print(p.stdout.strip().splitlines()[-1] if p.stdout else "",
                              flush=True)
                except subprocess.TimeoutExpired:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh_kind, "status": "timeout"}, f)
                    print("[sweep]   TIMEOUT", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--json", default=None, help="write result JSON here")
    ap.add_argument("--all", action="store_true", help="sweep all cells")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if args.all:
        sweep(args.out)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        result = run_cell(args.arch, args.shape, args.mesh)
    except Exception:
        result = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                  "status": "error", "stderr": traceback.format_exc()[-4000:]}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=1)
    _print_cell(result)
    if result["status"] == "error":
        print(result["stderr"], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
