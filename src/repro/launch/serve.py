"""Serving launcher: prefill + decode loop with optional replica snapshot.

``python -m repro.launch.serve --arch <id> --local [--snapshot-at N]``
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeCfg
from repro.core import AsyncForkSnapshotter, PyTreeProvider
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--snapshot-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced() if args.local else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    B, S0, S_max = args.batch, 16, 16 + args.tokens + 8

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0, cfg.vocab)
        if cfg.family == "audio":
            frames = jax.random.normal(jax.random.PRNGKey(2), (B, S0, cfg.d_model))
            logits, cache = model.prefill(params, frames, prompt, cache_len=S_max)
        else:
            logits, cache = model.prefill(params, prompt, cache_len=S_max)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        pos = jnp.full((B,), S0, jnp.int32)

        provider = PyTreeProvider({"params": params, "cache": cache})
        snapper = AsyncForkSnapshotter(provider, block_bytes=1 << 20,
                                       copier_threads=2)
        snap = None
        t_start = time.perf_counter()
        for step in range(args.tokens):
            if step == args.snapshot_at:
                snap = snapper.fork()
                print(f"[serve] replica fork: {snap.metrics.fork_s*1e3:.2f} ms")
            kwargs = {}
            if cfg.family == "vlm":
                kwargs["mrope_positions"] = jnp.broadcast_to(
                    pos[None, :, None], (3, B, 1))
            logits, cache = model.decode_step(params, cache, tok, pos, **kwargs)
            provider.refresh({"params": params, "cache": cache})
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            pos = pos + 1
        dt = time.perf_counter() - t_start
        print(f"[serve] {args.arch}: {args.tokens} tokens x {B} seqs in "
              f"{dt*1e3:.0f} ms ({args.tokens*B/dt:.1f} tok/s)")
        if snap is not None:
            snap.wait(60)
            print(f"[serve] replica captured: ok={snap.ok}, "
                  f"interruptions={snap.metrics.n_interruptions}")


if __name__ == "__main__":
    main()
