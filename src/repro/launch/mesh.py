"""Production mesh definitions (TPU v5e pod slices).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

from repro.runtime.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod" axis is
    pure data parallelism across pods (DCN), "data"/"model" are ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 (data, model) mesh on whatever devices exist — used by smoke
    tests and examples so shard_map code paths run unchanged on CPU."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link
