"""Roofline terms from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips x 197 TF bf16)
  memory     = HLO_bytes / (chips x 819 GB/s)
  collective = collective_bytes / (chips x 50 GB/s ICI)

cost_analysis() provides flops/bytes; collective bytes are NOT there, so
we parse the optimized HLO text and sum operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*"
    r"(?:\(([^)]*)\)|((?:\w+)\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result sizes of collective ops in HLO text, per op kind.

    The result shape of a collective is the per-device output; we count it
    once per op as the bytes crossing the interconnect per device (a
    standard, if slightly conservative, approximation for ring algorithms
    where each device sends ~its shard (N-1)/N times).
    """
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2) or ""
        kind = m.group(3).replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


def roofline(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
    *,
    chips: int,
    model_flops_global: Optional[float] = None,
) -> Dict[str, float]:
    """Inputs are PER-DEVICE (cost_analysis() reports the per-device SPMD
    module; the HLO collective parser sums per-device result bytes)."""
    t_compute = flops_per_dev / PEAK_FLOPS_BF16
    t_memory = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    out = dict(terms)
    out["dominant"] = dominant
    out["bound_s"] = terms[dominant]
    if model_flops_global:
        out["model_flops"] = model_flops_global
        out["useful_flops_frac"] = model_flops_global / max(
            1.0, flops_per_dev * chips
        )
        # roofline fraction: useful work at peak over the bound time
        out["roofline_frac"] = (
            model_flops_global / (chips * PEAK_FLOPS_BF16)
        ) / max(1e-12, terms[dominant])
    return out
