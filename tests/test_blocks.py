"""Unit tests for the block table (the "page table" analogue)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlockState, BlockTable


def _tree():
    return {
        "emb": jnp.zeros((1024, 64), jnp.float32),   # 256 KiB
        "bias": jnp.zeros((7,), jnp.float32),        # tiny leaf
        "scalar": jnp.float32(3.0),                  # scalar leaf
    }


def test_partitioning_covers_every_row():
    table = BlockTable(_tree(), block_bytes=16 << 10)  # 64 rows/block
    emb = next(h for h in table.leaf_handles if h.path == "emb")
    assert [b.start for b in emb.blocks] == list(range(0, 1024, 64))
    assert emb.blocks[-1].stop == 1024
    assert sum(b.stop - b.start for b in emb.blocks) == 1024
    # every leaf gets >= 1 block, including scalars
    assert all(len(h.blocks) >= 1 for h in table.leaf_handles)
    assert table.total_bytes == 1024 * 64 * 4 + 7 * 4 + 4


def test_block_bytes_close_to_target():
    table = BlockTable(_tree(), block_bytes=16 << 10)
    emb = next(h for h in table.leaf_handles if h.path == "emb")
    for b in emb.blocks:
        assert b.nbytes == 16 << 10


def test_flag_machine_trylock_semantics():
    table = BlockTable(_tree(), block_bytes=16 << 10)
    key = table.blocks[0].key
    assert table.state(key) == BlockState.UNCOPIED
    assert table.try_acquire(key)            # won the trylock
    assert not table.try_acquire(key)        # second acquire loses
    table.mark(key, BlockState.COPIED)
    assert table.state(key) == BlockState.COPIED
    assert not table.try_acquire(key)        # copied blocks never re-lock


def test_two_way_pointer_closes_when_leaf_done():
    table = BlockTable(_tree(), block_bytes=16 << 10)
    emb = next(h for h in table.leaf_handles if h.path == "emb")
    assert not table.leaf_done(emb.leaf_id)
    for ref in emb.blocks:
        assert table.try_acquire(ref.key)
        table.mark(ref.key, BlockState.COPIED)
    assert table.leaf_done(emb.leaf_id)  # O(1) check, no loop over PMDs


def test_rollback_drops_protection():
    table = BlockTable(_tree(), block_bytes=16 << 10)
    emb = next(h for h in table.leaf_handles if h.path == "emb")
    table.try_acquire(emb.blocks[0].key)
    table.mark(emb.blocks[0].key, BlockState.COPIED)
    n = table.rollback_leaf(emb.leaf_id)
    assert n == len(emb.blocks) - 1
    states = [table.state(b.key) for b in emb.blocks]
    assert BlockState.UNCOPIED not in states and BlockState.COPYING not in states


def test_mark_does_not_double_count_done():
    table = BlockTable(_tree(), block_bytes=16 << 10)
    emb = next(h for h in table.leaf_handles if h.path == "emb")
    ref = emb.blocks[0]
    table.try_acquire(ref.key)
    table.mark(ref.key, BlockState.COPIED)
    before = emb.twoway.remaining
    table.mark(ref.key, BlockState.PERSISTED)  # COPIED->PERSISTED: no decrement
    assert emb.twoway.remaining == before
