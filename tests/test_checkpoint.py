"""Checkpoint manager: save/restore round trip, async-vs-blocking stall,
donation safety, progressive release."""
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import TrainSnapshotManager, restore_checkpoint
from repro.configs import get_config
from repro.models import build_model
from repro.runtime.steps import init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        get_config("phi3-mini-3.8b").reduced(),
        n_layers=2, d_model=128, d_ff=256, vocab=512,
    )
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    fn = make_train_step(model)
    batch = {"tokens": np.random.randint(0, cfg.vocab, (4, 65)).astype(np.int32)}
    return cfg, model, params, opt, fn, batch


def _clone(t):
    return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), t)


@pytest.mark.parametrize("mode", ["blocking", "asyncfork"])
def test_save_restore_round_trip(setup, mode, tmp_path):
    cfg, model, params, opt, fn, batch = setup
    mgr = TrainSnapshotManager(str(tmp_path), mode=mode, copier_threads=2)
    p, o = _clone(params), _clone(opt)
    t0 = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), p)
    snap = mgr.save(7, p, o)
    # donated steps immediately after the save
    donating = jax.jit(fn, donate_argnums=(0, 1))
    nondonating = jax.jit(fn)
    for _ in range(3):
        f = nondonating if mgr.snapshot_active() else donating
        p, o, loss = f(p, o, batch)
    mgr.wait_all(120)
    rp, ro = restore_checkpoint(str(tmp_path / "step_00000007"))
    # restored == fork-time state exactly, bit for bit
    flat_t0, _ = jax.tree_util.tree_flatten_with_path(t0)
    for path, arr in flat_t0:
        key = "params/" + "/".join(str(getattr(k, "key", k)) for k in path)
        sub = rp
        for part in key.split("/")[1:]:
            sub = sub[part]
        np.testing.assert_array_equal(np.asarray(sub, arr.dtype), arr)
    assert int(np.asarray(ro.step)) == int(np.asarray(opt.step))


def test_async_save_is_cheaper_than_blocking(tmp_path):
    """blocking save() stages the whole state inline (O(state)); asyncfork
    save() returns after metadata work. The module fixture's model is too
    tiny to discriminate (~2-3 ms for BOTH, a coin flip under load), so
    this test uses a state big enough that inline staging dominates."""
    from repro.optim.adamw import AdamWState

    rows = 8 * (1 << 20) // (256 * 4)  # 8 MiB per leaf, 24 MiB total
    big = jnp.ones((rows, 256), jnp.float32)
    jax.block_until_ready(big)
    opt = AdamWState(step=jnp.zeros((), jnp.int32),
                     m={"emb": big + 1.0}, v={"emb": big + 2.0})
    stalls = {}
    for mode in ("blocking", "asyncfork"):
        mgr = TrainSnapshotManager(str(tmp_path / mode), mode=mode,
                                   copier_threads=2)
        mgr.save(1, {"emb": big}, opt)
        stalls[mode] = mgr.stall_log[-1][1]
        mgr.wait_all(120)
    assert stalls["asyncfork"] < stalls["blocking"]


def test_sharded_save_restore_round_trip(setup, tmp_path):
    """shards=3: leaves partition across per-shard FileSinks under a
    composite manifest; restore is shard-blind and bit-exact."""
    cfg, model, params, opt, fn, batch = setup
    mgr = TrainSnapshotManager(str(tmp_path), mode="asyncfork",
                               copier_threads=2, shards=3)
    p, o = _clone(params), _clone(opt)
    t0 = jax.tree_util.tree_map(lambda x: np.asarray(x).copy(), p)
    snap = mgr.save(11, p, o)
    assert len(snap.parts) == 3
    mgr.wait_all(120)
    assert os.path.isdir(str(tmp_path / "step_00000011" / "shard_0"))
    rp, ro = restore_checkpoint(str(tmp_path / "step_00000011"))
    flat_t0, _ = jax.tree_util.tree_flatten_with_path(t0)
    for path, arr in flat_t0:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        sub = rp
        for part in key.split("/"):
            sub = sub[part]
        np.testing.assert_array_equal(np.asarray(sub, arr.dtype), arr)
    assert int(np.asarray(ro.step)) == int(np.asarray(opt.step))


def test_sharded_incremental_chain_restores(setup, tmp_path):
    """Sharded delta chain: save -> mutate params -> delta save; each
    shard inherits clean blocks from its own parent dir and the composite
    restore resolves the chains."""
    cfg, model, params, opt, fn, batch = setup
    mgr = TrainSnapshotManager(str(tmp_path), mode="asyncfork",
                               copier_threads=2, shards=2,
                               incremental=True, full_every=4)
    p, o = _clone(params), _clone(opt)
    s1 = mgr.save(1, p, o)
    s1.wait_persisted(120)
    # mutate params between saves; opt state stays identical
    p2 = jax.tree_util.tree_map(lambda x: x + 1.0, p)
    s2 = mgr.save(2, p2, o)
    s2.wait_persisted(120)
    inherited = sum(part.metrics.inherited_blocks for part in s2.parts)
    assert inherited > 0  # unchanged opt blocks inherited from step 1
    rp, _ = restore_checkpoint(str(tmp_path / "step_00000002"))
    expect = jax.tree_util.tree_map(lambda x: np.asarray(x), p2)
    flat, _ = jax.tree_util.tree_flatten_with_path(expect)
    for path, arr in flat:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        sub = rp
        for part in key.split("/"):
            sub = sub[part]
        np.testing.assert_array_equal(np.asarray(sub, arr.dtype), arr)


def test_default_directory_outside_repo(monkeypatch, tmp_path):
    from repro.checkpoint import default_checkpoint_dir

    monkeypatch.delenv("REPRO_CKPT_DIR", raising=False)
    d = default_checkpoint_dir()
    assert os.path.isabs(d)
    assert not os.path.abspath(d).startswith(
        os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    )
    monkeypatch.setenv("REPRO_CKPT_DIR", str(tmp_path / "elsewhere"))
    assert default_checkpoint_dir() == str(tmp_path / "elsewhere")
    mgr = TrainSnapshotManager()
    assert mgr.directory == str(tmp_path / "elsewhere")


def test_progressive_release_closes_leaves(setup, tmp_path):
    cfg, model, params, opt, fn, batch = setup
    mgr = TrainSnapshotManager(str(tmp_path), mode="asyncfork", copier_threads=2)
    p, o = _clone(params), _clone(opt)
    snap = mgr.save(2, p, o)
    snap.wait(60)
    assert not mgr.snapshot_active()  # copy window closed
    for h in snap.table.leaf_handles:
        assert snap.table.leaf_done(h.leaf_id)
    mgr.wait_all(120)
    mgr.gc()
    assert not mgr._snaps

def test_manager_reshard_across_delta_chain(setup, tmp_path):
    """PR 4: changing the shard partition mid-stream re-anchors the delta
    chain — saves before and after reshard(3) both restore bit-exact, and
    the post-reshard save is a full anchor (no cross-partition deltas)."""
    cfg, model, params, opt, fn, batch = setup
    mgr = TrainSnapshotManager(str(tmp_path), mode="asyncfork",
                               copier_threads=2, shards=2,
                               incremental=True, full_every=8)
    p, o = _clone(params), _clone(opt)
    s1 = mgr.save(1, p, o)
    s1.wait_persisted(120)
    p2 = jax.tree_util.tree_map(lambda x: x + 1.0, p)
    s2 = mgr.save(2, p2, o)
    s2.wait_persisted(120)
    assert sum(pt.metrics.inherited_blocks for pt in s2.parts) > 0

    mgr.reshard(3)
    p3 = jax.tree_util.tree_map(lambda x: x + 2.0, p)
    s3 = mgr.save(3, p3, o)
    s3.wait_persisted(120)
    assert len(s3.parts) == 3
    # full anchor under the new partition: nothing inherited across it
    assert sum(pt.metrics.inherited_blocks for pt in s3.parts) == 0
    p4 = jax.tree_util.tree_map(lambda x: x + 3.0, p)
    s4 = mgr.save(4, p4, o)
    s4.wait_persisted(120)
    assert sum(pt.metrics.inherited_blocks for pt in s4.parts) > 0

    from repro.core import read_snapshot_layout
    rec = read_snapshot_layout(str(tmp_path / "step_00000003"))
    assert rec["kind"] == "leaves" and len(rec["shards"]) == 3

    for step, expect_p in ((2, p2), (3, p3), (4, p4)):
        rp, _ = restore_checkpoint(str(tmp_path / f"step_{step:08d}"))
        flat, _ = jax.tree_util.tree_flatten_with_path(
            jax.tree_util.tree_map(lambda x: np.asarray(x), expect_p))
        for path, arr in flat:
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            sub = rp
            for part in key.split("/"):
                sub = sub[part]
            np.testing.assert_array_equal(np.asarray(sub, arr.dtype), arr)
