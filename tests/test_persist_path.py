"""The zero-copy persist hot path: coalesced run-writes, batched D2H
drain, vectorized flag mirrors, and the parallel restore pool.

Covers the PR's acceptance criteria: ``write_run`` output is
byte-identical to per-block ``write_block`` writes under out-of-order
concurrent workers; an abort mid-run fires ``sink.abort()`` exactly once
and leaks no ``manifest.json.tmp``; the restore pool resolves shards and
delta chains to the same bytes as the sequential path; and corrupt
manifests/blobs raise clear errors instead of silently skipping.
"""
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncForkSnapshotter,
    BlockState,
    FailingProvider,
    FileSink,
    MemorySink,
    NullSink,
    PersistPipeline,
    PyTreeProvider,
    RestorePool,
    ShardedSnapshotCoordinator,
    Sink,
    SnapshotError,
    read_file_snapshot,
)
from repro.core.blocks import BlockRun, BlockTable
from repro.core.staging import mirror_flags


def _table(rows=100, cols=16, block_rows=8):
    """A leaf with a short tail block (100 rows / 8-row blocks -> 13)."""
    state = {"kv": jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols)}
    return state, BlockTable(state, block_bytes=block_rows * cols * 4)


def _random_run_partition(refs, rng, max_blocks=5):
    """Split a leaf's block list into contiguous runs of random length."""
    runs, i = [], 0
    while i < len(refs):
        n = int(rng.integers(1, max_blocks + 1))
        chunk = refs[i : i + n]
        runs.append(BlockRun(chunk[0].leaf_id, chunk[0].block_id, tuple(chunk)))
        i += n
    return runs


def _leaf_bytes(directory, leaf_id=0):
    with open(os.path.join(directory, f"leaf_{leaf_id}.bin"), "rb") as f:
        return f.read()


# --------------------------------------------------------------------- #
# write_run == write_block, out of order, concurrently                  #
# --------------------------------------------------------------------- #
@pytest.mark.timeout(120)
def test_write_run_byte_identical_to_per_block_concurrent(tmp_path):
    state, table = _table()
    host = np.asarray(state["kv"])
    rng = np.random.default_rng(7)

    a = FileSink(str(tmp_path / "per_block"))
    a.open(table.leaf_handles)
    refs = list(table.blocks)
    rng.shuffle(refs)
    threads = [
        threading.Thread(
            target=lambda r=r: a.write_block(r, host[r.start : r.stop])
        )
        for r in refs
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    a.close()

    b = FileSink(str(tmp_path / "runs"))
    b.open(table.leaf_handles)
    runs = _random_run_partition(table.blocks, rng)
    rng.shuffle(runs)

    def write_run(run):
        arrays = [host[r.start : r.stop] for r in run.refs]
        b.write_run(run.leaf_id, run.start_block, arrays)

    threads = [threading.Thread(target=write_run, args=(run,)) for run in runs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.close()

    assert _leaf_bytes(str(tmp_path / "per_block")) == \
        _leaf_bytes(str(tmp_path / "runs"))
    np.testing.assert_array_equal(
        read_file_snapshot(str(tmp_path / "runs"))["kv"], host
    )


def test_write_run_handles_bfloat16_and_scalars(tmp_path):
    """Extension dtypes reject the buffer protocol; the uint8 reinterpret
    must keep them (and 0-d scalar blocks) on the zero-copy path."""
    state = {
        "w": jnp.arange(64 * 8, dtype=jnp.bfloat16).reshape(64, 8),
        "step": jnp.float32(7.0),
    }
    table = BlockTable(state, block_bytes=16 * 8 * 2)
    sink = FileSink(str(tmp_path / "bf16"))
    sink.open(table.leaf_handles)
    for h in table.leaf_handles:
        leaf = np.asarray(state[h.path.split("/")[-1]])
        arrays = [
            leaf[r.start : r.stop] if h.shape else leaf.reshape(())
            for r in h.blocks
        ]
        sink.write_run(h.leaf_id, 0, arrays)
    sink.close()
    out = read_file_snapshot(str(tmp_path / "bf16"))
    np.testing.assert_array_equal(out["w"], np.asarray(state["w"]))
    assert float(out["step"]) == 7.0


def test_null_and_memory_sink_run_paths_match_per_block():
    state, table = _table(rows=40)
    host = np.asarray(state["kv"])
    refs = table.blocks
    arrays = [host[r.start : r.stop] for r in refs]

    null = NullSink()
    null.write_run(0, 0, arrays)
    assert null.bytes_written == sum(r.nbytes for r in refs)

    mem_run, mem_blk = MemorySink(), MemorySink()
    mem_run.write_run(0, 0, arrays)
    for r, a in zip(refs, arrays):
        mem_blk.write_block(r, a)
    assert set(mem_run.blocks) == set(mem_blk.blocks)
    for k in mem_blk.blocks:
        np.testing.assert_array_equal(mem_run.blocks[k], mem_blk.blocks[k])


@pytest.mark.timeout(120)
def test_write_block_only_sink_gets_real_refs_from_pipeline(tmp_path):
    """A legacy sink implementing only write_block must receive per-block
    writes with REAL refs (row geometry intact), not batched runs."""

    class Recording(Sink):
        def __init__(self):
            self.calls = []

        def open(self, leaf_handles):
            pass

        def write_block(self, ref, data):
            self.calls.append((ref.key, ref.start, ref.stop, data.nbytes))

    with pytest.raises(NotImplementedError):
        Recording().write_run(0, 0, [np.zeros(4, np.float32)])

    prov = PyTreeProvider(
        {"kv": jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)}
    )
    snapper = AsyncForkSnapshotter(prov, block_bytes=8 * 16 * 4,
                                   copier_threads=1)
    snapper.persist_pipeline = PersistPipeline(workers=2, run_blocks=4)
    sink = Recording()
    snap = snapper.fork(sink)
    assert snap.wait_persisted(60)
    table = snap.table
    expect = sorted(
        (r.key, r.start, r.stop, r.nbytes) for r in table.blocks
    )
    assert sorted(sink.calls) == expect


@pytest.mark.timeout(120)
@pytest.mark.parametrize("run_blocks", [1, 4, 64])
def test_pipeline_run_blocks_restore_byte_identical(tmp_path, run_blocks):
    """The whole pipeline at different coalescing granularities persists
    the same bytes, with donated writes racing the workers."""
    prov = PyTreeProvider(
        {"kv": jnp.arange(128 * 16, dtype=jnp.float32).reshape(128, 16)}
    )
    t0 = np.asarray(prov.leaf(0)).copy()
    snapper = AsyncForkSnapshotter(prov, block_bytes=512, copier_threads=2)
    snapper.persist_pipeline = PersistPipeline(workers=3, run_blocks=run_blocks)
    snap = snapper.fork(FileSink(str(tmp_path / f"rb{run_blocks}")))
    for i in range(8):
        snapper.before_write(0, [i * 4])
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[i * 4].set(-1.0), delete_old=True)
    assert snap.wait_persisted(60)
    restored = read_file_snapshot(str(tmp_path / f"rb{run_blocks}"))
    np.testing.assert_array_equal(restored["kv"], t0)


# --------------------------------------------------------------------- #
# abort mid-run                                                         #
# --------------------------------------------------------------------- #
class CountingFileSink(FileSink):
    def __init__(self, directory):
        super().__init__(directory)
        self.abort_calls = 0
        self.close_calls = 0

    def abort(self):
        # count AFTER the base abort: observing abort_calls == 1 then
        # implies the directory removal has completed
        super().abort()
        self.abort_calls += 1

    def close(self):
        self.close_calls += 1
        super().close()


@pytest.mark.timeout(120)
def test_abort_mid_run_exactly_once_no_tmp_leak(tmp_path):
    """A copy failure inside a multi-block run aborts the epoch: exactly
    one ``sink.abort()``, zero ``close()``, no ``manifest.json.tmp`` (or
    any other file) left behind."""
    state = {"kv": jnp.ones((256, 16), jnp.float32)}
    # key the failure on the ROW RANGE of block 10 (16 rows/block at
    # block_bytes=1024 on a 64-byte row): span-batched staging reads a
    # whole claimed run through one synthetic BlockRef, so identity-based
    # block_id predicates would never fire
    prov = FailingProvider(state, fail_on=lambda ref: ref.start <= 160 < ref.stop)
    snapper = AsyncForkSnapshotter(prov, block_bytes=1024, copier_threads=1)
    snapper.persist_pipeline = PersistPipeline(workers=4, run_blocks=8)
    sink = CountingFileSink(str(tmp_path / "abort"))
    snap = snapper.fork(sink)
    snap.persist_done.wait(30)
    with pytest.raises(SnapshotError):
        snap.wait_persisted(30)
    assert snap.aborted
    # abort() sets persist_done directly; the pipeline's job cleanup (the
    # actual sink.abort) drains moments later
    deadline = time.monotonic() + 10.0
    while sink.abort_calls == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert sink.abort_calls == 1
    assert sink.close_calls == 0
    leftovers = [
        os.path.join(root, f)
        for root, _, files in os.walk(tmp_path)
        for f in files
    ]
    assert leftovers == []


# --------------------------------------------------------------------- #
# BlockTable: vectorized states + run coalescing                        #
# --------------------------------------------------------------------- #
def test_leaf_states_matches_per_block_state():
    _, table = _table()
    h = table.leaf_handles[0]
    table.try_acquire(h.blocks[1].key)                      # COPYING
    table.mark(h.blocks[1].key, BlockState.COPIED)
    table.try_acquire(h.blocks[4].key)                      # COPYING
    table.mark(h.blocks[7].key, BlockState.PERSISTED)
    states = table.leaf_states(0)
    assert states.dtype == np.int32
    for ref in h.blocks:
        assert states[ref.block_id] == int(table.state(ref.key))
    flags = mirror_flags(table, 0, force_uncopied=7)
    assert flags[7] == int(BlockState.UNCOPIED)
    assert flags[1] == int(BlockState.COPIED)


def test_coalesce_runs_merges_same_state_and_breaks_on_exclude():
    _, table = _table(rows=96, block_rows=8)                # 12 blocks
    h = table.leaf_handles[0]
    for b in (3, 4, 5):
        table.try_acquire(h.blocks[b].key)
        table.mark(h.blocks[b].key, BlockState.COPIED)
    runs = table.coalesce_runs(0)
    spans = [(r.start_block, r.stop_block, r.state) for r in runs]
    assert spans == [
        (0, 3, BlockState.UNCOPIED),
        (3, 6, BlockState.COPIED),
        (6, 12, BlockState.UNCOPIED),
    ]
    # refs cover every block exactly once, in order
    covered = [ref.block_id for r in runs for ref in r.refs]
    assert covered == list(range(12))

    capped = table.coalesce_runs(0, max_blocks=2)
    assert all(len(r.refs) <= 2 for r in capped)
    assert [ref.block_id for r in capped for ref in r.refs] == list(range(12))

    holes = table.coalesce_runs(0, exclude={(0, 4), (0, 9)})
    assert all((0, 4) not in [ref.key for ref in r.refs] for r in holes)
    assert [ref.block_id for r in holes for ref in r.refs] == \
        [0, 1, 2, 3, 5, 6, 7, 8, 10, 11]


def test_mark_run_counts_twoway_once():
    _, table = _table(rows=64, block_rows=8)                # 8 blocks
    h = table.leaf_handles[0]
    run = BlockRun(0, 0, tuple(h.blocks[:4]))
    table.mark_run(run, BlockState.PERSISTED)
    assert h.twoway.remaining == 4
    # re-marking already-final blocks must not double-count
    table.mark_run(run, BlockState.PERSISTED)
    assert h.twoway.remaining == 4
    rest = BlockRun(0, 4, tuple(h.blocks[4:]))
    table.mark_run(rest, BlockState.PERSISTED)
    assert h.twoway.closed and table.leaf_done(0)


# --------------------------------------------------------------------- #
# device staging: batched D2H drain                                     #
# --------------------------------------------------------------------- #
@pytest.mark.timeout(120)
def test_device_staged_run_matches_per_block_reads():
    prov = PyTreeProvider(
        {"kv": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)}
    )
    snapper = AsyncForkSnapshotter(
        prov, block_bytes=8 * 32 * 4, copier_threads=1, backend="device"
    )
    snap = snapper.fork()
    assert snap.wait(60)
    refs = snap.table.leaf_handles[0].blocks[2:6]
    run_arrays = snap.staged_run(refs)
    for ref, arr in zip(refs, run_arrays):
        np.testing.assert_array_equal(
            np.asarray(arr), np.asarray(snap.staged_block(ref))
        )
    host = snap.backend.drain(0)
    assert host.shape[0] == len(snap.table.leaf_handles[0].blocks)
    assert isinstance(host, np.ndarray)


@pytest.mark.timeout(120)
def test_device_backend_run_persist_restores_t0(tmp_path):
    """End to end: device staging -> batched drain -> pwritev runs ->
    restore equals the fork-time image, under donated writes."""
    prov = PyTreeProvider(
        {"kv": jnp.arange(96 * 16, dtype=jnp.float32).reshape(96, 16)}
    )
    t0 = np.asarray(prov.leaf(0)).copy()
    snapper = AsyncForkSnapshotter(
        prov, block_bytes=8 * 16 * 4, copier_threads=2, backend="device"
    )
    snapper.persist_pipeline = PersistPipeline(workers=2, run_blocks=4)
    snap = snapper.fork(FileSink(str(tmp_path / "dev")))
    for i in range(6):
        snapper.before_write(0, [i * 8])
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[i * 8].set(-2.0), delete_old=True)
    assert snap.wait_persisted(60)
    restored = read_file_snapshot(str(tmp_path / "dev"))
    np.testing.assert_array_equal(restored["kv"], t0)


# --------------------------------------------------------------------- #
# restore pool                                                          #
# --------------------------------------------------------------------- #
@pytest.mark.timeout(120)
def test_restore_pool_matches_sequential_for_sharded_delta_chain(tmp_path):
    provs = [
        PyTreeProvider({
            "kv": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
            + 100.0 * k
        })
        for k in range(4)
    ]
    coord = ShardedSnapshotCoordinator(
        provs, mode="asyncfork", block_bytes=512, copier_threads=1,
        retain_images=True,
    )
    coord.bgsave_to_dir(str(tmp_path / "base")).wait_persisted(60)
    for k in range(4):
        coord.before_write(k, 0, [5])
        old = provs[k].leaf(0)
        provs[k].update_leaf(0, old.at[5].set(-3.0), delete_old=True)
    coord.bgsave_to_dir(
        str(tmp_path / "delta"), parent="base", incremental=True
    ).wait_persisted(60)
    coord.wait_all(60)

    seq = read_file_snapshot(str(tmp_path / "delta"), workers=1)
    par = read_file_snapshot(str(tmp_path / "delta"), workers=4)
    pooled = read_file_snapshot(
        str(tmp_path / "delta"), pool=RestorePool(workers=3)
    )
    assert set(seq) == set(par) == set(pooled)
    for path in seq:
        np.testing.assert_array_equal(seq[path], par[path])
        np.testing.assert_array_equal(seq[path], pooled[path])
    for k in range(4):
        expect = np.asarray(provs[k].leaf(0))
        np.testing.assert_array_equal(par[f"shard{k}/kv"], expect)


def test_restore_pool_surfaces_worker_errors(tmp_path):
    pool = RestorePool(workers=4)
    with pytest.raises(FileNotFoundError):
        pool.map(lambda p: open(p).read(), ["/nonexistent/a", "/nonexistent/b"])


def test_restore_pool_map_preserves_order():
    pool = RestorePool(workers=4)
    assert pool.map(lambda x: x * x, range(37)) == [i * i for i in range(37)]


# --------------------------------------------------------------------- #
# corrupt-snapshot validation                                           #
# --------------------------------------------------------------------- #
def _write_snapshot(tmp_path, name, parent=None):
    prov = PyTreeProvider({"kv": jnp.ones((16, 4), jnp.float32),
                           "step": jnp.float32(3.0)})
    table = BlockTable(prov.tree(), block_bytes=4 * 4 * 4)
    sink = FileSink(str(tmp_path / name), parent=parent)
    sink.open(table.leaf_handles)
    for h in table.leaf_handles:
        leaf = np.asarray(prov.leaf(h.leaf_id))
        for r in h.blocks:
            sink.write_block(
                r, leaf[r.start : r.stop] if h.shape else leaf
            )
    sink.close()
    return str(tmp_path / name)


def test_truncated_scalar_leaf_raises_clear_error(tmp_path):
    import json

    d = _write_snapshot(tmp_path, "snap")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    scalar = next(l for l in manifest["leaves"] if not l["shape"])
    open(os.path.join(d, scalar["file"]), "w").close()  # truncate to 0
    with pytest.raises(ValueError, match="scalar leaf.*empty"):
        read_file_snapshot(d)


def test_truncated_shaped_leaf_raises_clear_error(tmp_path):
    import json

    d = _write_snapshot(tmp_path, "snap")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    shaped = next(l for l in manifest["leaves"] if l["shape"])
    p = os.path.join(d, shaped["file"])
    with open(p, "r+b") as f:
        f.truncate(os.path.getsize(p) // 2)
    with pytest.raises(ValueError, match="holds.*needs"):
        read_file_snapshot(d)


def test_delta_manifest_missing_blocks_carried_raises(tmp_path):
    import json

    _write_snapshot(tmp_path, "base")
    d = _write_snapshot(tmp_path, "delta", parent="base")
    mp = os.path.join(d, "manifest.json")
    with open(mp) as f:
        manifest = json.load(f)
    for leaf in manifest["leaves"]:
        leaf.pop("carried", None)
    with open(mp, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="blocks.*carried|carried"):
        read_file_snapshot(d)


# --------------------------------------------------------------------- #
# metrics: persist_s vs sink_write_s                                    #
# --------------------------------------------------------------------- #
@pytest.mark.timeout(120)
def test_sink_write_s_excludes_copy_window():
    prov = PyTreeProvider({"kv": jnp.ones((256, 64), jnp.float32)})
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=2)
    snap = snapper.fork(NullSink(bandwidth=400e6))
    assert snap.wait_persisted(60)
    m = snap.metrics
    assert m.sink_write_s > 0.0
    # the IO interval is a sub-span of the full fork->durable window
    assert m.sink_write_s <= m.persist_s + 1e-9
    assert "sink_write_ms" in m.summary()


# --------------------------------------------------------------------- #
# two-lane overlap + compressed runs (DESIGN.md §13)                    #
# --------------------------------------------------------------------- #
@pytest.mark.timeout(120)
@pytest.mark.parametrize("compress", [None, "zlib"])
def test_two_lane_overlap_byte_identical_to_serial(tmp_path, compress):
    """Property test for the overlapped datapath: with the SAME fork-time
    image and the SAME donated-write schedule racing the workers, the
    two-lane pipeline (stager + per-job writer lane) persists exactly
    what the serial lane does. Uncompressed dirs are compared at the raw
    leaf-file level (positioned writes make run partitioning invisible);
    compressed dirs at the restored-array level (frame boundaries track
    the nondeterministic run coalescing, the inflated bytes must not)."""
    restored = {}
    for overlap in (False, True):
        prov = PyTreeProvider(
            {"kv": jnp.arange(128 * 16, dtype=jnp.float32).reshape(128, 16)}
        )
        t0 = np.asarray(prov.leaf(0)).copy()
        snapper = AsyncForkSnapshotter(prov, block_bytes=512, copier_threads=2)
        snapper.persist_pipeline = PersistPipeline(
            workers=2, run_blocks=4, overlap=overlap
        )
        d = str(tmp_path / f"ov_{overlap}_{compress}")
        snap = snapper.fork(FileSink(d, compress=compress))
        for i in range(8):
            snapper.before_write(0, [i * 4])
            old = prov.leaf(0)
            prov.update_leaf(0, old.at[i * 4].set(-1.0), delete_old=True)
        assert snap.wait_persisted(60)
        got = read_file_snapshot(d, verify=True)
        np.testing.assert_array_equal(got["kv"], t0)
        restored[overlap] = (d, got, snap.metrics)
    np.testing.assert_array_equal(restored[False][1]["kv"],
                                  restored[True][1]["kv"])
    if compress is None:
        assert _leaf_bytes(restored[False][0]) == _leaf_bytes(restored[True][0])
    # both arms account lane busy time (serial mode still splits each
    # run into a stage span + a write span inside one worker); the
    # overlap clock only ever measures both-lanes-busy seconds, so the
    # frac is a valid [0, 1] concurrency ratio in either mode
    for overlap in (False, True):
        m = restored[overlap][2]
        assert m.stage_s > 0.0 and m.write_busy_s > 0.0
        assert 0.0 <= m.overlap_frac <= 1.0
        assert m.overlap_s <= min(m.stage_s, m.write_busy_s) + 1e-9
        assert "overlap_frac" in m.summary()


@pytest.mark.timeout(120)
def test_abort_mid_run_serial_lane_exactly_once(tmp_path):
    """The exactly-once abort contract holds on the overlap=False serial
    lane too: one ``sink.abort()``, zero ``close()``, nothing on disk."""
    state = {"kv": jnp.ones((256, 16), jnp.float32)}
    prov = FailingProvider(state, fail_on=lambda ref: ref.start <= 160 < ref.stop)
    snapper = AsyncForkSnapshotter(prov, block_bytes=1024, copier_threads=1)
    snapper.persist_pipeline = PersistPipeline(
        workers=4, run_blocks=8, overlap=False
    )
    sink = CountingFileSink(str(tmp_path / "abort_serial"))
    snap = snapper.fork(sink)
    snap.persist_done.wait(30)
    with pytest.raises(SnapshotError):
        snap.wait_persisted(30)
    assert snap.aborted
    deadline = time.monotonic() + 10.0
    while sink.abort_calls == 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert sink.abort_calls == 1
    assert sink.close_calls == 0
    leftovers = [
        os.path.join(root, f)
        for root, _, files in os.walk(tmp_path)
        for f in files
    ]
    assert leftovers == []


@pytest.mark.timeout(120)
@pytest.mark.parametrize("anchor,delta", [(None, "zlib"), ("zlib", None)])
def test_mixed_compression_delta_chain_restores(tmp_path, anchor, delta):
    """A compressed delta over an uncompressed full parent (and the
    reverse) restores byte-exact through the chain walk, and the catalog
    deep-verify recovers both epochs without quarantining either — each
    leaf's manifest records its OWN encoding."""
    from repro.core import SnapshotCatalog

    pool = tmp_path / "pool"
    pool.mkdir()
    provs = [
        PyTreeProvider({
            "kv": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
            + 100.0 * k
        })
        for k in range(2)
    ]
    coord = ShardedSnapshotCoordinator(
        provs, mode="asyncfork", block_bytes=512, copier_threads=1,
        retain_images=True,
    )
    coord.bgsave_to_dir(
        str(pool / "ep0"), compress=anchor
    ).wait_persisted(60)
    for k in range(2):
        coord.before_write(k, 0, [5])
        old = provs[k].leaf(0)
        provs[k].update_leaf(0, old.at[5].set(-3.0), delete_old=True)
    coord.bgsave_to_dir(
        str(pool / "ep1"), parent="ep0", incremental=True, compress=delta
    ).wait_persisted(60)
    coord.wait_all(60)

    flat = read_file_snapshot(str(pool / "ep1"), verify=True)
    for k in range(2):
        expect = np.asarray(provs[k].leaf(0))
        np.testing.assert_array_equal(flat[f"shard{k}/kv"], expect)

    cat = SnapshotCatalog.from_dir(str(pool), deep_verify=True)
    recovered = sorted(
        os.path.basename(d) for d in cat.last_recovery.recovered_dirs
    )
    assert recovered == ["ep0", "ep1"]
    assert not os.path.isdir(str(pool / "_quarantine"))


@pytest.mark.timeout(120)
def test_checkpoint_compress_restore_verify_round_trip(tmp_path):
    """``TrainSnapshotManager(compress="zlib")`` end to end: the save
    lands zlib frames (manifest records the codec) and
    ``restore_checkpoint(verify=True)`` inflates + crc-checks them back
    to the exact fork-time trees."""
    import json

    from repro.checkpoint import TrainSnapshotManager, restore_checkpoint
    from repro.optim.adamw import AdamWState

    params = {"w": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)}
    opt = AdamWState(
        step=jnp.zeros((), jnp.int32) + 3,
        m={"w": jnp.ones((64, 8), jnp.float32)},
        v={"w": jnp.full((64, 8), 2.0, jnp.float32)},
    )
    mgr = TrainSnapshotManager(
        str(tmp_path), mode="asyncfork", copier_threads=2, block_bytes=1024,
        compress="zlib",
    )
    mgr.save(3, params, opt)
    mgr.wait_all(120)
    d = str(tmp_path / "step_00000003")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    assert any(
        leaf.get("compress") == "zlib" for leaf in manifest["leaves"]
    )
    rp, ro = restore_checkpoint(d, verify=True)
    np.testing.assert_array_equal(rp["w"], np.asarray(params["w"]))
    np.testing.assert_array_equal(ro.m["w"], np.asarray(opt.m["w"]))
    np.testing.assert_array_equal(ro.v["w"], np.asarray(opt.v["w"]))
    assert int(np.asarray(ro.step)) == 3
