"""Striped write gates (PR 5): GateSet semantics, deadlock freedom of the
ordered all-gate barrier under layout swaps, and the headline invariant —

    ANY interleaving of concurrent multi-threaded per-shard writes with a
    mid-stream BGSAVE barrier (and an optional split/merge) equals a
    quiesced point-in-time cut: per shard, the snapshot reflects a prefix
    of each writer's batch sequence, whole batches at a time, cut at that
    shard's T0 stamp (DESIGN.md §9).

The concurrency tests run seeded even without hypothesis; with the
optional 'test' extra installed, a hypothesis wrapper additionally draws
the writer/shard/batch geometry and the reshard op.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import GateRetired, GateSet
from repro.kvstore import KVEngine, ShardedKVStore

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property wrapper skips; seeded tests still run
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------- #
# GateSet unit semantics                                                 #
# --------------------------------------------------------------------- #
def test_striped_gates_are_independent():
    gs = GateSet(3)
    g0, w0 = gs.acquire(0)
    try:
        # another stripe is acquirable from a second thread while 0 is held
        ok = threading.Event()

        def other():
            g1, _ = gs.acquire(1)
            g1.release()
            ok.set()

        th = threading.Thread(target=other)
        th.start()
        th.join(5.0)
        assert ok.is_set()
    finally:
        g0.release()
    assert gs.wait_summary()["gate_acquires"] == 2.0


def test_unstriped_gateset_aliases_one_lock():
    gs = GateSet(3, striped=False)
    g0, _ = gs.acquire(0)
    try:
        blocked = threading.Event()

        def other():
            g2, _ = gs.acquire(2)  # same underlying lock as stripe 0
            g2.release()
            blocked.set()

        th = threading.Thread(target=other)
        th.start()
        th.join(0.2)
        assert not blocked.is_set()  # global-gate semantics: it waits
    finally:
        g0.release()
    assert blocked.wait(5.0)


def test_all_gate_barrier_is_reentrant_and_excludes_writers():
    gs = GateSet(2)
    entered = threading.Event()

    def writer():
        g, _ = gs.acquire(1)
        g.release()
        entered.set()

    with gs.all():
        with gs.all():  # nested: bgsave_to_dir -> bgsave re-acquires
            th = threading.Thread(target=writer)
            th.start()
            th.join(0.2)
            assert not entered.is_set()
        th.join(0.2)
        assert not entered.is_set()  # still one barrier level held
    assert entered.wait(5.0)
    th.join(5.0)


def test_resize_creates_new_stripes_already_held():
    """A stripe born from a mid-barrier resize must not admit writers
    until the resizing thread's outermost barrier exits."""
    gs = GateSet(2)
    got_new = threading.Event()

    def writer_new_stripe():
        g, _ = gs.acquire(2)  # only exists after the resize
        g.release()
        got_new.set()

    gs.acquire_all()
    gs.resize(3, carry={0: 0, 1: 1})
    th = threading.Thread(target=writer_new_stripe)
    th.start()
    th.join(0.2)
    assert not got_new.is_set()  # fresh gate created already-held
    gs.release_all()
    assert got_new.wait(5.0)
    th.join(5.0)


def test_resize_wakes_writers_blocked_on_dropped_stripes():
    """A writer queued on a stripe that a merge retires must wake at
    barrier exit and see GateRetired (so it can re-route), not hang."""
    gs = GateSet(2)
    outcome = {}

    def writer_old_stripe():
        try:
            g, _ = gs.acquire(1)
            g.release()
            outcome["ok"] = True
        except GateRetired:
            outcome["retired"] = True

    gs.acquire_all()
    th = threading.Thread(target=writer_old_stripe)
    th.start()
    time.sleep(0.05)  # let it block on the (old) stripe 1
    gs.resize(1, carry={0: 0})  # merge: stripe 1 dropped
    gs.release_all()
    th.join(5.0)
    assert not th.is_alive()
    assert outcome == {"retired": True}


def test_resize_requires_barrier_and_validates():
    gs = GateSet(2)
    with pytest.raises(RuntimeError):
        gs.resize(3)
    with pytest.raises(RuntimeError):
        gs.release_all()
    with pytest.raises(GateRetired):
        gs.acquire(7)


# --------------------------------------------------------------------- #
# the interleaving invariant (tentpole acceptance)                       #
# --------------------------------------------------------------------- #
def _run_interleaving(n_shards, writers, n_batches, reshard=None, seed=0,
                      striped=True):
    """Concurrent per-span writers vs a mid-stream barrier (+ optional
    reshard). Returns everything the checks below need."""
    block_rows = 16
    capacity = n_shards * 4 * block_rows
    store = ShardedKVStore(capacity, row_width=8, block_rows=block_rows,
                           seed=seed, shards=n_shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=1,
                   persist_bandwidth=None, copier_duty=1.0,
                   striped_gates=striped)
    store.warmup(batch=4)
    init = store.read_all().copy()
    spans = [(w * capacity // writers, (w + 1) * capacity // writers)
             for w in range(writers)]
    records = [[] for _ in range(writers)]  # (seq, t_start, t_end)
    errors = []
    start = threading.Barrier(writers + 1)

    def writer(w):
        lo, hi = spans[w]
        rows = np.arange(lo, hi, dtype=np.int64)
        start.wait()
        try:
            for seq in range(1, n_batches + 1):
                vals = np.full((rows.size, 8), float(w * 1000 + seq),
                               np.float32)
                t0 = time.perf_counter()
                store.set(rows, vals, before_write=eng._write_hook,
                          gate=eng._gate, on_gate_wait=eng._gate_wait_hook)
                records[w].append((seq, t0, time.perf_counter()))
        except BaseException as exc:  # pragma: no cover - the assert below
            errors.append(exc)

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    for th in threads:
        th.start()
    start.wait()
    if reshard == "split":
        eng.split(0)
    elif reshard == "merge":
        eng.merge(0, 1)
    t_bg0 = time.perf_counter()
    snap = eng.coordinator.bgsave()
    t_bg1 = time.perf_counter()
    for th in threads:
        th.join(30.0)
        assert not th.is_alive(), "writer deadlocked"
    assert not errors, errors
    assert snap.wait_persisted(60)
    img = np.concatenate([
        np.concatenate([np.asarray(b) for b in t["blocks"]])
        for t in snap.to_trees()
    ])
    return store, eng, snap, init, spans, records, img, (t_bg0, t_bg1)


def _check_point_in_time_cut(snap, init, spans, records, img, window,
                             block_rows=16):
    """Per (writer-span ∩ barrier-layout shard): the image is uniform at
    some batch seq j (whole gate-held batches are atomic w.r.t. the
    barrier on each shard), j covers every batch that finished before the
    barrier began and none that started after it returned."""
    t_bg0, t_bg1 = window
    layout = snap.layout
    shard_rows = [(layout.bounds[k] * block_rows,
                   layout.bounds[k + 1] * block_rows)
                  for k in range(layout.n_shards)]
    for w, (lo, hi) in enumerate(spans):
        seqs = [s for s, _, _ in records[w]]
        must_have = max((s for s, _, e in records[w] if e < t_bg0), default=0)
        too_late = min((s for s, b, _ in records[w] if b > t_bg1),
                       default=max(seqs, default=0) + 1)
        for slo, shi in shard_rows:
            a, b = max(lo, slo), min(hi, shi)
            if a >= b:
                continue
            cut = img[a:b]
            if np.array_equal(cut, init[a:b]):
                j = 0
            else:
                uniq = np.unique(cut)
                assert uniq.size == 1, (
                    f"writer {w} rows [{a},{b}): torn batch in snapshot "
                    f"(values {uniq[:4]}...)"
                )
                j = int(uniq[0]) - w * 1000
                assert j in seqs, f"writer {w}: impossible seq {j}"
            assert j >= must_have, (
                f"writer {w} rows [{a},{b}): snapshot at seq {j} misses "
                f"batch {must_have} that completed before the barrier"
            )
            assert j < too_late, (
                f"writer {w} rows [{a},{b}): snapshot at seq {j} includes "
                f"a batch that started after the barrier returned"
            )


def _check_no_lost_writes(store, spans, n_batches, init):
    live = store.read_all()
    for w, (lo, hi) in enumerate(spans):
        expect = float(w * 1000 + n_batches)
        assert (live[lo:hi] == expect).all(), (
            f"writer {w}: final state lost its last batch (reshard "
            "re-route must not drop or misdirect the tail)"
        )


@pytest.mark.parametrize("striped", [True, False])
def test_concurrent_writers_barrier_is_quiesced_cut(striped):
    out = _run_interleaving(n_shards=3, writers=4, n_batches=6,
                            striped=striped)
    store, eng, snap, init, spans, records, img, window = out
    _check_point_in_time_cut(snap, init, spans, records, img, window)
    _check_no_lost_writes(store, spans, 6, init)
    # the wait metric is wired end to end
    assert "gate_wait_us" in snap.metrics.summary()


@pytest.mark.parametrize("reshard", ["split", "merge"])
def test_concurrent_writers_reshard_and_barrier(reshard):
    """A split/merge fired from a non-writer thread lands mid-stream:
    stale-routed tails must re-route (no lost updates, no torn batches)
    and the barrier cut must hold under the successor layout."""
    out = _run_interleaving(n_shards=2, writers=3, n_batches=6,
                            reshard=reshard)
    store, eng, snap, init, spans, records, img, window = out
    assert store.layout.epoch == 1
    _check_point_in_time_cut(snap, init, spans, records, img, window)
    _check_no_lost_writes(store, spans, 6, init)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(
        n_shards=st.integers(2, 4),
        writers=st.integers(1, 4),
        n_batches=st.integers(1, 5),
        reshard=st.sampled_from([None, "split", "merge"]),
        seed=st.integers(0, 3),
    )
    def test_property_interleaving_equals_quiesced_cut(
        n_shards, writers, n_batches, reshard, seed
    ):
        out = _run_interleaving(n_shards=n_shards, writers=writers,
                                n_batches=n_batches, reshard=reshard,
                                seed=seed)
        store, eng, snap, init, spans, records, img, window = out
        _check_point_in_time_cut(snap, init, spans, records, img, window)
        _check_no_lost_writes(store, spans, n_batches, init)


# --------------------------------------------------------------------- #
# deadlock freedom: writers x barriers x layout swaps                    #
# --------------------------------------------------------------------- #
def test_no_deadlock_writers_barriers_and_layout_swaps():
    """Ordered all-gate acquisition + single-stripe writers + mid-flight
    resizes: every thread must finish. (A cycle would hang the join and
    trip the suite's timeout.)"""
    n_shards = 3
    store = ShardedKVStore(n_shards * 8 * 16, row_width=8, block_rows=16,
                           seed=0, shards=n_shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=1,
                   persist_bandwidth=None, copier_duty=1.0)
    store.warmup(batch=4)
    stop = threading.Event()
    errors = []

    def writer(w):
        rng = np.random.default_rng(w)
        vals = np.full((4, 8), float(w), np.float32)
        try:
            while not stop.is_set():
                base = int(rng.integers(0, store.capacity - 4))
                rows = np.arange(base, base + 4, dtype=np.int64)
                store.set(rows, vals, before_write=eng._write_hook,
                          gate=eng._gate,
                          on_gate_wait=eng._gate_wait_hook)
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    def barrier_loop():
        try:
            while not stop.is_set():
                eng.coordinator.bgsave().wait_persisted(30)
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    def reshard_loop():
        try:
            while not stop.is_set():
                eng.split(0)
                eng.merge(0, 1)
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(3)]
    threads += [threading.Thread(target=barrier_loop),
                threading.Thread(target=reshard_loop)]
    for th in threads:
        th.start()
    time.sleep(1.0)
    stop.set()
    for th in threads:
        th.join(30.0)
        assert not th.is_alive(), "deadlock: thread failed to finish"
    assert not errors, errors
    eng.coordinator.wait_all(60)
