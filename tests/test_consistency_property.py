"""Property-based tests (hypothesis) for the system's core invariant:

    For ANY interleaving of donated engine writes with the snapshot's
    background copy, the materialized snapshot equals the fork-time (T0)
    state exactly — the paper's consistency argument (§4.1, Table 2).

Also checks the monotone flag machine and metrics invariants.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'test' extra"
)
from hypothesis import given, settings, strategies as st

from repro.core import PyTreeProvider, make_snapshotter

MODES = ["blocking", "cow", "asyncfork"]


@st.composite
def update_script(draw):
    """A random engine run: (rows, value) donated SET batches."""
    n_rows = draw(st.sampled_from([64, 96, 128]))
    n_updates = draw(st.integers(0, 12))
    updates = []
    for _ in range(n_updates):
        k = draw(st.integers(1, 8))
        rows = draw(
            st.lists(st.integers(0, n_rows - 1), min_size=k, max_size=k, unique=True)
        )
        val = draw(st.floats(-100, 100, allow_nan=False, width=32))
        updates.append((rows, val))
    return n_rows, updates


@settings(max_examples=25, deadline=None)
@given(
    script=update_script(),
    mode=st.sampled_from(MODES),
    block_bytes=st.sampled_from([512, 2048, 8192]),
    threads=st.sampled_from([1, 3]),
)
def test_snapshot_equals_t0_under_any_interleaving(script, mode, block_bytes, threads):
    n_rows, updates = script
    state = {
        "kv": jnp.arange(n_rows * 32, dtype=jnp.float32).reshape(n_rows, 32),
        "meta": jnp.zeros((4,), jnp.float32),
    }
    prov = PyTreeProvider(state)
    t0 = np.asarray(prov.leaf(0)).copy()  # 'kv' flattens first
    snapper = make_snapshotter(
        mode, prov, block_bytes=block_bytes, copier_threads=threads
    )
    snap = snapper.fork()
    for rows, val in updates:
        snapper.before_write(0, rows)
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[np.asarray(rows)].set(val), delete_old=True)
    tree = snap.to_tree()
    np.testing.assert_array_equal(np.asarray(tree["kv"]), t0)
    # live state reflects the last update per row
    expect = t0.copy()
    for rows, val in updates:
        expect[np.asarray(rows)] = val
    np.testing.assert_allclose(np.asarray(prov.leaf(0)), expect, rtol=0, atol=0)


@settings(max_examples=15, deadline=None)
@given(
    mode=st.sampled_from(MODES),
    block_bytes=st.sampled_from([512, 4096]),
)
def test_every_block_copied_exactly_once(mode, block_bytes):
    """parent-copied + child-copied == total blocks; no double copy."""
    state = {"kv": jnp.ones((128, 64), jnp.float32)}
    prov = PyTreeProvider(state)
    snapper = make_snapshotter(mode, prov, block_bytes=block_bytes, copier_threads=2)
    snap = snapper.fork()
    for i in range(6):
        snapper.before_write(0, [i * 16])
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[i * 16].set(-1.0), delete_old=True)
    tree = snap.to_tree()
    assert np.asarray(tree["kv"]).shape == (128, 64)
    m = snap.metrics
    if mode == "blocking":
        assert m.copied_blocks_parent == 0
        assert m.copied_blocks_child == snap.table.n_blocks
    else:
        assert m.copied_blocks_parent + m.copied_blocks_child == snap.table.n_blocks


@st.composite
def sharded_script(draw):
    """A random cross-shard run: (shard, rows, value) updates and a fork
    position splitting them into pre-/post-barrier halves."""
    n_shards = draw(st.integers(2, 4))
    n_updates = draw(st.integers(0, 10))
    updates = []
    for _ in range(n_updates):
        shard = draw(st.integers(0, n_shards - 1))
        k = draw(st.integers(1, 4))
        rows = draw(st.lists(st.integers(0, 63), min_size=k, max_size=k,
                             unique=True))
        val = draw(st.floats(-100, 100, allow_nan=False, width=32))
        updates.append((shard, rows, val))
    fork_at = draw(st.integers(0, n_updates))
    return n_shards, updates, fork_at


@settings(max_examples=25, deadline=None)
@given(script=sharded_script(), block_bytes=st.sampled_from([512, 2048]))
def test_cross_shard_barrier_is_point_in_time(script, block_bytes):
    """The union of shard images equals the state at the fork barrier for
    ANY interleaving of writes across shards (DESIGN.md §6)."""
    from repro.core import ShardedSnapshotCoordinator

    n_shards, updates, fork_at = script
    provs = [
        PyTreeProvider({
            "kv": jnp.arange(64 * 8, dtype=jnp.float32).reshape(64, 8)
            + 1000.0 * k
        })
        for k in range(n_shards)
    ]
    coord = ShardedSnapshotCoordinator(
        provs, mode="asyncfork", block_bytes=block_bytes, copier_threads=2
    )

    def apply(shard, rows, val):
        with coord.write_gate:
            coord.before_write(shard, 0, rows)
            old = provs[shard].leaf(0)
            provs[shard].update_leaf(
                0, old.at[np.asarray(rows)].set(val), delete_old=True
            )

    for shard, rows, val in updates[:fork_at]:
        apply(shard, rows, val)
    expected = [np.asarray(p.leaf(0)).copy() for p in provs]
    snap = coord.bgsave()
    for shard, rows, val in updates[fork_at:]:
        apply(shard, rows, val)
    trees = snap.to_trees()
    for k in range(n_shards):
        np.testing.assert_array_equal(np.asarray(trees[k]["kv"]), expected[k])


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_metrics_out_of_service_bounded_by_wall_time(data):
    import time

    mode = data.draw(st.sampled_from(MODES))
    state = {"kv": jnp.ones((256, 64), jnp.float32)}
    prov = PyTreeProvider(state)
    snapper = make_snapshotter(mode, prov, block_bytes=1024, copier_threads=2)
    t_wall0 = time.perf_counter()
    snap = snapper.fork()
    for i in range(4):
        snapper.before_write(0, [i])
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[i].set(0.5), delete_old=True)
    snap.to_tree()
    wall = time.perf_counter() - t_wall0 + 1e-3
    assert 0.0 <= snap.metrics.out_of_service_s <= wall
