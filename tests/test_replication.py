"""Replicated epoch shipping + background scrubbing (ISSUE 10).

Covers the tentpole paths outside the subprocess crash harness (which
lives in ``test_crash_recovery.py``): delta-chain wire format and skip
aliasing, transfer retry/backoff and exhausted-budget unwinding, the
scrubber's bit-flip → quarantine → re-fetch repair with reads staying
exact throughout, the GC-orphan retry-then-quarantine loop, catalog
occupancy in ``EngineReport.summary()``, the checkpoint manager's
``replicate_to`` option, and ``RecoveryManager`` on empty / partial /
quarantine-only pools (the previously untested edges).
"""
import json
import os
import shutil

import numpy as np
import pytest

from repro.core import (
    EpochReplicator,
    EpochScrubber,
    FaultInjector,
    ReplicationError,
    RetryPolicy,
    ScrubPolicy,
    SnapshotCatalog,
    install_faults,
    read_file_snapshot,
)
from repro.core.policy import BgsavePolicy
from repro.core.recovery import QUARANTINE_DIRNAME, RecoveryManager
from repro.core.recovery import RecoveryReport  # noqa: F401  (API surface)
from repro.kvstore import KVEngine, ShardedKVStore

CAPACITY = 512
BLOCK_ROWS = 64
WIDTH = 4
SHARDS = 2


@pytest.fixture(autouse=True)
def _clean_installed_faults():
    install_faults(None)
    yield
    install_faults(None)


def _engine(policy=None):
    store = ShardedKVStore(capacity=CAPACITY, block_rows=BLOCK_ROWS,
                           row_width=WIDTH, seed=11, shards=SHARDS)
    eng = KVEngine(store, mode="blocking", persist_bandwidth=None,
                   policy=policy or BgsavePolicy(delta_threshold=2.0,
                                                 full_every=99))
    store.warmup(batch=2)
    return store, eng


def _set(store, eng, rows, val):
    vals = np.full((rows.size, WIDTH), val, np.float32)
    store.set(rows, vals, before_write=eng._write_hook, gate=eng._gate)


def _commit_epochs(store, eng, pool, n, sparse=True):
    """n durable epochs into pool/ep<k>; sparse=True touches one block
    per epoch (deltas carry a small fraction of the table), else every
    block."""
    for e in range(n):
        if sparse and e > 0:
            lo = (e % (CAPACITY // BLOCK_ROWS)) * BLOCK_ROWS
            rows = np.arange(lo, lo + BLOCK_ROWS, dtype=np.int64)
        else:
            rows = np.arange(0, CAPACITY, dtype=np.int64)
        _set(store, eng, rows, float(e + 1))
        snap = eng.coordinator.bgsave_to_dir(os.path.join(pool, f"ep{e}"))
        assert snap.wait_persisted(120.0)


def _assert_replica_exact(eng, replica):
    """from_dir on the replica pool alone reproduces every epoch's reads
    byte-exact (the failover check)."""
    rcat = SnapshotCatalog.from_dir(replica)
    store2, eng2 = _engine()
    eng2.coordinator.catalog = rcat
    probe = np.arange(CAPACITY, dtype=np.int64)
    src = sorted(eng.catalog.epochs())
    dst = sorted(rcat.epochs())
    assert len(dst) == len(src)
    for a, b in zip(src, dst):
        np.testing.assert_array_equal(eng2.get_at(probe, b),
                                      eng.get_at(probe, a))
    return rcat


# -- shipping: wire format, ordering, idempotence -------------------------

def test_ship_delta_chain_is_the_wire_format(tmp_path):
    """Deltas ship only their carried runs: bytes on the wire stay well
    under the naive full-copy equivalent, and the replica still reads
    byte-exact through its relative-ref chains."""
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 4, sparse=True)
    rep = EpochReplicator(replica, catalog=eng.catalog)
    assert rep.lag() == 4
    assert rep.sync() == 4
    assert rep.lag() == 0
    m = rep.metrics.summary()
    assert m["epochs_shipped"] == 4
    # 1 full + 3 one-block deltas: the wire moved a fraction of the
    # logical bytes (each delta's sparse file re-materializes via
    # truncate, not via shipped zeros)
    assert m["bytes_shipped"] < 0.6 * m["bytes_logical"]
    _assert_replica_exact(eng, replica)
    # idempotent: nothing pending ships zero and moves zero bytes
    assert rep.sync() == 0
    assert rep.metrics.summary()["bytes_shipped"] == m["bytes_shipped"]


def test_ship_skip_epoch_reuses_replica_dirs(tmp_path):
    """A zero-write epoch (skip mode) ships only its composite manifest;
    the alias entries resolve against the already-shipped target."""
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine(policy=BgsavePolicy(
        delta_threshold=2.0, full_every=99, allow_skip=True))
    _set(store, eng, np.arange(CAPACITY, dtype=np.int64), 1.0)
    s0 = eng.coordinator.bgsave_to_dir(os.path.join(pool, "ep0"))
    assert s0.wait_persisted(120.0)
    # no writes since ep0: both shards take zero-copy skip epochs
    s1 = eng.coordinator.bgsave_to_dir(os.path.join(pool, "ep1"))
    assert s1.wait_persisted(120.0)
    assert s1.modes == ["skip"] * SHARDS
    rep = EpochReplicator(replica, catalog=eng.catalog)
    assert rep.sync() == 2
    assert rep.metrics.dirs_reused == SHARDS
    # the skip epoch's dir on the replica holds ONLY the manifest
    assert os.listdir(os.path.join(replica, "ep1")) == ["manifest.json"]
    _assert_replica_exact(eng, replica)


def test_ship_uncommitted_dir_refuses(tmp_path):
    rep = EpochReplicator(str(tmp_path / "replica"))
    torn = tmp_path / "pool" / "ep0"
    torn.mkdir(parents=True)
    with pytest.raises(ReplicationError, match="no composite manifest"):
        rep.ship_dir(str(torn))


# -- transfer faults: retry, backoff, unwinding ---------------------------

@pytest.mark.parametrize("site", ["replicate.read", "replicate.write"])
def test_transient_transfer_fault_is_retried(tmp_path, site):
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 2)
    inj = FaultInjector()
    install_faults(inj)
    inj.arm(site, mode="raise", times=2)
    rep = EpochReplicator(replica, catalog=eng.catalog)
    assert rep.sync() == 2
    assert rep.metrics.transfer_retries >= 2
    assert rep.metrics.transfer_failures == 0
    install_faults(None)
    _assert_replica_exact(eng, replica)


@pytest.mark.parametrize("site", ["replicate.read", "replicate.write",
                                  "replicate.commit"])
def test_exhausted_retry_unwinds_partial_epoch(tmp_path, site):
    """Past the retry budget (or at the unretried commit site) the ship
    fails cleanly: the partial replica epoch dir is unwound, the failure
    counted, and a later re-ship succeeds from scratch."""
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 2)
    inj = FaultInjector()
    install_faults(inj)
    inj.arm(site, mode="raise", times=50)
    rep = EpochReplicator(
        replica, catalog=eng.catalog,
        retry=RetryPolicy(max_retries=2, backoff_s=1e-4))
    assert rep.sync() == 0  # first epoch fails, dependents blocked
    assert rep.ship_errors == 1
    assert rep.metrics.transfer_failures >= 1
    assert not os.path.exists(os.path.join(replica, "ep0"))
    install_faults(None)
    assert rep.sync() == 2
    _assert_replica_exact(eng, replica)


def test_background_ship_loop(tmp_path):
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    from repro.core import ReplicationPolicy
    rep = EpochReplicator(replica, catalog=eng.catalog,
                          policy=ReplicationPolicy(interval_s=0.01))
    rep.start()
    try:
        _commit_epochs(store, eng, pool, 3)
        deadline = 200
        while rep.lag() and deadline:
            deadline -= 1
            import time
            time.sleep(0.05)
        assert rep.lag() == 0
    finally:
        rep.stop()
    _assert_replica_exact(eng, replica)


# -- scrubbing: bit rot -> quarantine -> re-fetch -------------------------

def _flip_byte(path, offset=8):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def test_scrub_detects_quarantines_and_refetches(tmp_path):
    """The acceptance loop: inject a bit flip into a cold committed run,
    scrub detects it, the corrupt dir moves to quarantine (never
    deleted), a verified replica copy lands at the original path, and
    reads stay exact throughout."""
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 3)
    rep = EpochReplicator(replica, catalog=eng.catalog)
    scrub = EpochScrubber(eng.catalog, ScrubPolicy(dirs_per_scan=100))
    eng.attach_maintenance(replicator=rep, scrubber=scrub)
    assert rep.sync() == 3
    probe = np.arange(CAPACITY, dtype=np.int64)
    expected = {eid: np.array(eng.get_at(probe, eid), copy=True)
                for eid in eng.catalog.epochs()}

    # rot a cold run: flip a byte in ep0/shard_0's largest data file
    sdir = os.path.join(pool, "ep0", "shard_0")
    victim = max(
        (os.path.join(sdir, f) for f in os.listdir(sdir)
         if f != "manifest.json"),
        key=os.path.getsize)
    _flip_byte(victim)

    # reads stay exact BEFORE the repair: live epochs serve from the
    # resident staging images, not the rotten disk
    for eid, exp in expected.items():
        np.testing.assert_array_equal(eng.get_at(probe, eid), exp)

    found = scrub.scan_once()
    assert [os.path.basename(os.path.dirname(d)) for d, _ in found] == ["ep0"]
    assert "checksum mismatch" in found[0][1]
    assert scrub.metrics.corrupt_found == 1
    assert scrub.metrics.repaired == 1
    assert scrub.metrics.quarantined == 1
    assert scrub.corrupt == []  # repaired, not stranded

    # the corrupt bytes are preserved in pool/quarantine, never deleted
    qdir = os.path.join(pool, QUARANTINE_DIRNAME)
    qnames = os.listdir(qdir)
    assert any(n.startswith("ep0.shard_0") for n in qnames)
    qvictim = os.path.join(qdir, qnames[0], os.path.basename(victim))
    assert os.path.exists(qvictim)

    # the repaired dir verifies end to end and the NEXT scrub is clean
    assert read_file_snapshot(os.path.join(pool, "ep0"))
    assert scrub.scan_once() == []

    # reads stay exact AFTER eviction forces disk reads through the
    # repaired files
    for eid in list(expected):
        eng.catalog.evict_live(eid)
    for eid, exp in expected.items():
        np.testing.assert_array_equal(eng.get_at(probe, eid), exp)
    assert eng.catalog.quarantined_dirs  # observable on the catalog


def test_scrub_without_replica_leaves_evidence_in_place(tmp_path):
    """No replica: the corrupt dir is reported but left untouched —
    destroying the only copy is never an improvement."""
    pool = str(tmp_path / "pool")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 2)
    sdir = os.path.join(pool, "ep0", "shard_1")
    victim = max(
        (os.path.join(sdir, f) for f in os.listdir(sdir)
         if f != "manifest.json"),
        key=os.path.getsize)
    _flip_byte(victim)
    scrub = EpochScrubber(eng.catalog, ScrubPolicy(dirs_per_scan=100))
    found = scrub.scan_once()
    assert len(found) == 1
    assert scrub.metrics.repaired == 0
    assert scrub.corrupt and scrub.corrupt[0][0] == os.path.realpath(sdir)
    assert os.path.isdir(sdir)  # still in place
    assert not os.path.exists(os.path.join(pool, QUARANTINE_DIRNAME))


def test_gc_orphan_retry_then_quarantine(tmp_path):
    """catalog.gc orphans drain through the scrubber: one retried rmtree
    (same fault site), then quarantine for what still will not die."""
    pool = str(tmp_path / "pool")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 2)
    inj = FaultInjector()
    install_faults(inj)

    # case 1: transient failure — the drop's rmtree faults once, the
    # scrubber's retry succeeds and the orphan is removed for real
    inj.arm("catalog.gc", mode="raise", times=1)
    dropped = eng.catalog.epochs()[-1]
    orphan_dirs = eng.catalog._records  # noqa: F841 (keep linters quiet)
    eng.catalog.drop_epoch(dropped)
    assert eng.catalog.gc_errors == 1
    orphans = [p for p, _ in eng.catalog.gc_error_log]
    assert orphans and all(os.path.isdir(p) for p in orphans)
    scrub = EpochScrubber(eng.catalog, ScrubPolicy(dirs_per_scan=100))
    scrub.scan_once()
    assert scrub.metrics.orphans_removed == len(orphans)
    assert all(not os.path.exists(p) for p in orphans)
    assert eng.catalog.gc_error_log == []  # drained

    # case 2: persistent failure — the retry faults too (enough armed
    # shots to outlast both the drop's fires and the scrub retries);
    # the orphan is MOVED to quarantine, not leaked and not deleted
    inj.arm("catalog.gc", mode="raise", times=10)
    eng.catalog.drop_epoch(eng.catalog.epochs()[-1])
    assert eng.catalog.gc_error_log
    stuck = [p for p, _ in eng.catalog.gc_error_log]
    scrub.scan_once()
    assert scrub.metrics.orphans_quarantined == len(stuck)
    assert all(not os.path.exists(p) for p in stuck)
    qdir = os.path.join(pool, QUARANTINE_DIRNAME)
    assert os.path.isdir(qdir) and os.listdir(qdir)
    assert eng.catalog.quarantined_dirs


# -- observability --------------------------------------------------------

def test_engine_report_surfaces_catalog_occupancy(tmp_path):
    pool, replica = str(tmp_path / "pool"), str(tmp_path / "replica")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 3)
    rep = EpochReplicator(replica, catalog=eng.catalog)
    scrub = EpochScrubber(eng.catalog, ScrubPolicy(dirs_per_scan=100))
    eng.attach_maintenance(replicator=rep, scrubber=scrub)
    rep.sync()
    scrub.scan_once()

    occ = eng.catalog.occupancy()
    ndirs = len(eng.catalog.committed_dirs())
    assert occ["dirs"] == ndirs >= 4
    assert occ["bytes"] > 0
    assert occ["chain_depth_max"] == 2  # ep2 -> ep1 -> ep0
    assert 0 < occ["chain_depth_mean"] <= occ["chain_depth_max"]
    assert occ["quarantined"] == 0

    from repro.kvstore.workload import Workload
    report = eng.run(
        Workload(rate_qps=500.0, set_ratio=0.0, batch=8, seed=3),
        duration_s=0.05, bgsave_at=())
    s = report.summary()
    assert s["catalog_dirs"] == occ["dirs"]
    assert s["catalog_bytes"] >= occ["bytes"]
    assert s["catalog_chain_max"] == occ["chain_depth_max"]
    assert s["catalog_quarantined"] == 0.0
    assert s["replication_lag"] == 0.0
    assert s["epochs_shipped"] == 3.0
    assert s["bytes_shipped"] > 0.0
    assert s["dirs_scrubbed"] == ndirs
    assert s["corrupt_found"] == 0.0
    assert s["repaired_dirs"] == 0.0


# -- checkpoint manager: replicate-on-commit ------------------------------

def test_checkpoint_manager_replicate_to(tmp_path):
    from repro.checkpoint.manager import (
        TrainSnapshotManager,
        restore_checkpoint,
    )
    from repro.optim.adamw import AdamWState

    rng = np.random.default_rng(5)
    params = {"w": rng.normal(size=(64, 8)).astype(np.float32),
              "b": np.zeros((8,), np.float32)}
    opt = AdamWState(
        step=np.zeros((), np.int32),
        m={k: np.zeros_like(v) for k, v in params.items()},
        v={k: np.zeros_like(v) for k, v in params.items()},
    )
    primary = str(tmp_path / "ckpts")
    standby = str(tmp_path / "standby")
    mgr = TrainSnapshotManager(
        directory=primary, mode="blocking", shards=2, incremental=True,
        replicate_to=standby)
    for step in range(3):
        params = {k: v + 1.0 for k, v in params.items()}
        mgr.save(step, params, opt)
        mgr.wait_all()
    # every save committed on the standby, in order, delta chains intact
    for step in range(3):
        rdir = os.path.join(standby, f"step_{step:08d}")
        assert os.path.exists(os.path.join(rdir, "manifest.json")), step
    rp, _ = restore_checkpoint(os.path.join(standby, "step_00000002"))
    np.testing.assert_array_equal(rp["w"], params["w"])
    np.testing.assert_array_equal(rp["b"], params["b"])
    assert mgr.replicator.metrics.epochs_shipped == 3
    assert mgr.replicator.metrics.transfer_failures == 0


# -- RecoveryManager edge pools (satellite) -------------------------------

def test_recovery_missing_and_empty_pool(tmp_path):
    missing = str(tmp_path / "nope")
    cat = SnapshotCatalog.from_dir(missing)
    assert cat.epochs() == []
    assert cat.last_recovery.recovered == []
    assert not os.path.exists(missing)  # not materialized

    empty = tmp_path / "empty"
    empty.mkdir()
    cat2 = SnapshotCatalog.from_dir(str(empty))
    assert cat2.epochs() == []
    assert cat2.last_recovery.summary()["recovered_epochs"] == 0.0
    assert os.listdir(empty) == []  # no quarantine dir conjured


def test_recovery_partially_created_pool(tmp_path):
    """Pre-commit wreckage only: an empty epoch dir, a dir whose shard
    got data + a tmp manifest but no rename, junk files. Everything
    torn quarantines; stray files are ignored, not destroyed."""
    pool = tmp_path / "pool"
    pool.mkdir()
    (pool / "ep0").mkdir()
    sdir = pool / "ep1" / "shard_0"
    sdir.mkdir(parents=True)
    (sdir / "leaf_0.bin").write_bytes(b"\x00" * 64)
    (sdir / "manifest.json.tmp").write_text(json.dumps({"leaves": []}))
    (pool / "notes.txt").write_text("not an epoch")

    report = RecoveryManager(str(pool)).recover_into(SnapshotCatalog())
    assert report.recovered == []
    reasons = dict(
        (os.path.basename(p).split(".")[0], r)
        for p, r in report.quarantined)
    assert set(reasons) == {"ep0", "ep1"}
    assert all("manifest" in r for r in reasons.values())
    qdir = pool / QUARANTINE_DIRNAME
    assert sorted(os.listdir(qdir)) == ["ep0", "ep1"]
    # the half-written payload is preserved inside quarantine
    assert (qdir / "ep1" / "shard_0" / "leaf_0.bin").exists()
    assert (pool / "notes.txt").exists()


def test_recovery_quarantine_only_pool(tmp_path):
    """A pool holding nothing but prior wreckage: recovery must not
    re-quarantine, repair, or otherwise touch the quarantine dir."""
    pool = tmp_path / "pool"
    qdir = pool / QUARANTINE_DIRNAME
    (qdir / "ep0" / "shard_0").mkdir(parents=True)
    (qdir / "ep0" / "shard_0" / "leaf_0.bin").write_bytes(b"junk")
    (qdir / "ep3.compact").mkdir()  # swap leftover inside quarantine

    before = sorted(
        os.path.join(r, n) for r, d, f in os.walk(qdir) for n in d + f)
    cat = SnapshotCatalog.from_dir(str(pool))
    report = cat.last_recovery
    assert report.recovered == []
    assert report.quarantined == []
    assert report.repaired_swaps == []
    after = sorted(
        os.path.join(r, n) for r, d, f in os.walk(qdir) for n in d + f)
    assert after == before  # byte-for-byte untouched


def test_recovery_ignores_stale_fetch_staging(tmp_path):
    """A crash between a re-fetch's copytree and its rename swap leaves
    ``<dir>.fetch`` staging; recovery of the pool must still validate
    the epoch itself (the staging dir is unreferenced by any manifest)."""
    pool = str(tmp_path / "pool")
    store, eng = _engine()
    _commit_epochs(store, eng, pool, 2)
    sdir = os.path.join(pool, "ep0", "shard_0")
    shutil.copytree(sdir, sdir + ".fetch")
    cat = SnapshotCatalog.from_dir(pool)
    assert len(cat.last_recovery.recovered) == 2
