"""Deterministic BGSAVE workload for the crash harness.

Run as a child process (``sys.executable tests/crash_child.py <pool>
<site>``) it builds a seeded sharded engine, writes a deterministic
pattern per epoch, commits durable BGSAVE epochs into ``<pool>/ep<k>``,
and arms a process-wide :class:`~repro.core.faults.FaultInjector` to
``os._exit`` at ``<site>`` — SIGKILL-equivalent: no atexit, no flush, no
unwind. After every successful commit it prints ``COMMITTED <k>`` so the
parent knows the exact committed prefix at the instant of death.

Imported by the parent (``tests/test_crash_recovery.py``) the same
module replays the identical writes against an identical seeded store to
produce the byte-exact expected row values for every epoch.

Site placement:

* write-plane sites (``sink.write``, ``sink.fsync``, ``sink.rename``,
  ``persist.run``, ``persist.stage``, ``bgsave.commit``): armed before
  the LAST epoch's writes+BGSAVE, so epochs ``0..N-2`` are committed and
  the crash lands mid-epoch ``N-1``;
* ``compactor.swap``: all epochs commit, then a delta-chain fold dies
  mid-swap (leaving a ``.compact`` leftover for recovery to repair);
* ``catalog.gc``: all epochs commit, then a ``drop_epoch`` dies before
  its ``rmtree`` — the drop is NOT durable, so recovery legitimately
  resurrects the epoch (the parent expects ALL epochs back);
* replicate sites (``replicate.read``, ``replicate.write``,
  ``replicate.commit``): all epochs commit, epochs ``0..N-2`` ship
  cleanly to the standby pool (``SHIPPED <k>`` printed per epoch), then
  the crash lands mid-ship of epoch ``N-1`` — the replica must recover
  exactly the shipped prefix, the torn partial epoch quarantined.
"""
import os
import sys

import numpy as np

CAPACITY = 512
BLOCK_ROWS = 64
ROW_WIDTH = 4
SHARDS = 2
SEED = 7
EPOCHS = 3

# sites where the crash interrupts epoch EPOCHS-1 mid-flight
WRITE_PLANE_SITES = (
    "sink.write", "sink.fsync", "sink.rename", "persist.run",
    "persist.stage", "bgsave.commit",
)
POST_COMMIT_SITES = ("compactor.swap", "catalog.gc")
REPLICATE_SITES = ("replicate.read", "replicate.write", "replicate.commit")


def replica_dir(pool: str) -> str:
    """The standby pool the replicate-site runs ship into (a sibling of
    the primary pool, derived so parent and child agree on it)."""
    return os.path.join(os.path.dirname(os.path.abspath(pool)), "replica")


def build():
    from repro.core.policy import BgsavePolicy
    from repro.kvstore import KVEngine, ShardedKVStore

    store = ShardedKVStore(capacity=CAPACITY, block_rows=BLOCK_ROWS,
                           row_width=ROW_WIDTH, seed=SEED, shards=SHARDS)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=0.5,
                   policy=BgsavePolicy(delta_threshold=2.0, full_every=99))
    store.warmup(batch=2)
    return store, eng


def epoch_rows(e: int) -> np.ndarray:
    """Deterministic per-epoch write set spanning both shards."""
    return np.arange(e % 5, CAPACITY, 3 + e, dtype=np.int64)


def epoch_vals(e: int, n: int) -> np.ndarray:
    base = np.arange(n, dtype=np.float32).reshape(-1, 1)
    return np.tile(base, (1, ROW_WIDTH)) + float(e + 1) * 1000.0


def write_epoch(store, eng, e: int) -> None:
    rows = epoch_rows(e)
    kw = {}
    if eng is not None:
        kw = dict(before_write=eng._write_hook, gate=eng._gate)
    store.set(rows, epoch_vals(e, rows.size), **kw)


def expected_tables(epochs: int = EPOCHS):
    """Replay the workload sans snapshots: full expected row table after
    each epoch's writes (index e == content of committed epoch e)."""
    store, _ = build()
    probe = np.arange(CAPACITY, dtype=np.int64)
    out = []
    for e in range(epochs):
        write_epoch(store, None, e)
        out.append(np.array(store.get(probe), copy=True))
    return out


def run(pool: str, site: str, epochs: int = EPOCHS) -> None:
    from repro.core import faults

    store, eng = build()
    coord = eng.coordinator
    inj = faults.FaultInjector()
    faults.install(inj)

    for e in range(epochs):
        if site in WRITE_PLANE_SITES and e == epochs - 1:
            inj.arm(site, mode="crash")
        write_epoch(store, eng, e)
        snap = coord.bgsave_to_dir(os.path.join(pool, f"ep{e}"))
        if not snap.wait_persisted(120.0):
            raise SystemExit(f"epoch {e} did not persist")
        print(f"COMMITTED {e}", flush=True)

    if site == "catalog.gc":
        inj.arm(site, mode="crash")
        # the tip epoch's delta dirs are only held by the epoch itself
        eng.catalog.drop_epoch(eng.catalog.epochs()[-1])
        raise SystemExit("drop_epoch survived an armed crash site")
    if site == "compactor.swap":
        cat = eng.catalog
        target = None
        with cat._lock:
            for path in sorted(cat._dirs):
                if cat._dirs[path].parent is not None:
                    target = path
                    break
        if target is None:
            raise SystemExit("no delta-chained dir to compact")
        inj.arm(site, mode="crash")
        cat.compact_dir(target)
        raise SystemExit("compact_dir survived an armed crash site")
    if site in REPLICATE_SITES:
        from repro.core.replicate import EpochReplicator

        rep = EpochReplicator(replica_dir(pool), catalog=eng.catalog)
        work = rep.pending()
        for _, d in work[:-1]:
            rep.ship_dir(d)
            print(f"SHIPPED {os.path.basename(d)[2:]}", flush=True)
        inj.arm(site, mode="crash")
        rep.ship_dir(work[-1][1])
        raise SystemExit(f"ship at {site} survived an armed crash site")
    if site in WRITE_PLANE_SITES:
        raise SystemExit(f"site {site} never fired")
    raise SystemExit(f"unknown site {site!r}")


if __name__ == "__main__":
    run(sys.argv[1], sys.argv[2])
