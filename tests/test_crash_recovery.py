"""Kill-at-every-site crash harness (ISSUE 8 acceptance).

For every fault-injection site, a child process (``tests/crash_child.py``)
runs live BGSAVE traffic and ``os._exit``s mid-flight at that site —
SIGKILL-equivalent. A FRESH process (this one) then rebuilds the catalog
with :meth:`SnapshotCatalog.from_dir` and must see exactly the
fully-committed epoch prefix: every recovered epoch reads byte-exact,
every torn dir is quarantined (moved, never deleted), and a flipped byte
in a committed run is rejected by checksum verification.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import SnapshotCatalog, read_file_snapshot
from repro.core.faults import CRASH_EXIT_CODE, SITES
from repro.core.recovery import QUARANTINE_DIRNAME

sys.path.insert(0, os.path.dirname(__file__))
import crash_child  # noqa: E402

_CHILD = os.path.join(os.path.dirname(__file__), "crash_child.py")


def _run_child(pool, site):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (
            os.path.join(os.path.dirname(os.path.dirname(_CHILD)), "src"),
            env.get("PYTHONPATH", ""),
        ) if p
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, _CHILD, str(pool), site],
        capture_output=True, text=True, timeout=240, env=env,
    )


def _committed(stdout):
    return [int(l.split()[1]) for l in stdout.splitlines()
            if l.startswith("COMMITTED ")]


def _shipped(stdout):
    return [int(l.split()[1]) for l in stdout.splitlines()
            if l.startswith("SHIPPED ")]


def _check_recovered_reads(pool, cat, committed, expected):
    """Every recovered epoch restores byte-exact, via BOTH the raw
    directory reader and an engine wired to the recovered catalog."""
    report = cat.last_recovery
    probe = np.arange(crash_child.CAPACITY, dtype=np.int64)
    store, eng = crash_child.build()
    eng.coordinator.catalog = cat  # cross-restart: engine reads through
    # the recovered catalog (fresh ids, commit order == epoch order)
    by_dir = dict(zip(report.recovered_dirs, report.recovered))
    for e in committed:
        epoch_dir = os.path.join(str(pool), f"ep{e}")
        eid = by_dir[os.path.abspath(epoch_dir)]
        got = eng.get_at(probe, eid)
        np.testing.assert_array_equal(got, expected[e])
        flat = read_file_snapshot(epoch_dir)  # crc-verified read
        assert flat
    # branch() forks a writable child off the newest recovered epoch
    tip = max(by_dir.values())
    child = eng.branch(tip)
    np.testing.assert_array_equal(
        child.store.get(probe), expected[max(committed)]
    )
    child.branch_ref.release()


@pytest.mark.timeout(300)
@pytest.mark.parametrize("site", sorted(SITES))
def test_kill_at_site_recovers_committed_prefix(site, tmp_path):
    pool = tmp_path / "pool"
    pool.mkdir()
    proc = _run_child(pool, site)
    assert proc.returncode == CRASH_EXIT_CODE, (
        f"child at site {site!r} exited {proc.returncode}:\n"
        f"{proc.stdout}\n{proc.stderr}"
    )
    committed = _committed(proc.stdout)
    if site in crash_child.WRITE_PLANE_SITES:
        assert committed == list(range(crash_child.EPOCHS - 1))
    else:
        # post-commit sites crash AFTER every epoch committed; the
        # interrupted operation (drop/compact) is not durable, so
        # recovery resurfaces all of them
        assert committed == list(range(crash_child.EPOCHS))

    cat = SnapshotCatalog.from_dir(str(pool))
    report = cat.last_recovery
    recovered_names = sorted(
        os.path.basename(d) for d in report.recovered_dirs
    )
    assert recovered_names == [f"ep{e}" for e in committed]

    if site in crash_child.WRITE_PLANE_SITES:
        # the torn epoch dir left by the crash is quarantined, NOT deleted
        torn = f"ep{crash_child.EPOCHS - 1}"
        assert not (pool / torn).exists()
        qdir = pool / QUARANTINE_DIRNAME
        assert any(n.startswith(torn) for n in os.listdir(qdir)), (
            f"torn {torn} missing from quarantine: {os.listdir(qdir)}"
        )
    if site == "compactor.swap":
        # the interrupted swap's leftovers were repaired away
        assert report.repaired_swaps
        assert not any(
            n.endswith((".compact", ".old"))
            for _, dirs, _ in os.walk(pool) for n in dirs
        )

    expected = crash_child.expected_tables()
    _check_recovered_reads(pool, cat, committed, expected)

    if site in crash_child.REPLICATE_SITES:
        # failover: the replica pool recovers EXACTLY the shipped prefix
        # (epochs 0..N-2 committed replica-side before the crash), reads
        # byte-exact through a catalog rebuilt from the replica alone,
        # and the torn mid-ship epoch is quarantined, never deleted
        shipped = _shipped(proc.stdout)
        assert shipped == list(range(crash_child.EPOCHS - 1))
        replica = crash_child.replica_dir(str(pool))
        rcat = SnapshotCatalog.from_dir(replica)
        rreport = rcat.last_recovery
        assert sorted(
            os.path.basename(d) for d in rreport.recovered_dirs
        ) == [f"ep{e}" for e in shipped]
        _check_recovered_reads(replica, rcat, shipped, expected)
        torn = os.path.join(replica, f"ep{crash_child.EPOCHS - 1}")
        if site == "replicate.commit" or rreport.quarantined:
            # a partial epoch dir existed at the kill (always true at the
            # commit site; at read/write only once the first byte moved)
            assert not os.path.exists(torn)
            qdir = os.path.join(replica, QUARANTINE_DIRNAME)
            assert any(
                n.startswith(f"ep{crash_child.EPOCHS - 1}")
                for n in os.listdir(qdir)
            )


@pytest.mark.timeout(300)
def test_flipped_byte_in_committed_run_rejected(tmp_path):
    """Deep verification catches silent corruption: flip one byte in a
    committed run's data file; the reader raises ValueError naming the
    shard dir, and recovery quarantines exactly that epoch."""
    pool = tmp_path / "pool"
    pool.mkdir()
    store, eng = crash_child.build()
    for e in range(2):
        crash_child.write_epoch(store, eng, e)
        snap = eng.coordinator.bgsave_to_dir(str(pool / f"ep{e}"))
        assert snap.wait_persisted(120.0)

    sdir = str(pool / "ep0" / "shard_0")
    files = [f for f in os.listdir(sdir) if f != "manifest.json"]
    victim = max((os.path.join(sdir, f) for f in files),
                 key=os.path.getsize)
    with open(victim, "r+b") as f:
        f.seek(8)
        b = f.read(1)
        f.seek(8)
        f.write(bytes([b[0] ^ 0xFF]))

    with pytest.raises(ValueError, match="checksum mismatch") as ei:
        read_file_snapshot(str(pool / "ep0"))
    assert "shard_0" in str(ei.value)  # the error names the shard dir

    cat = SnapshotCatalog.from_dir(str(pool))
    report = cat.last_recovery
    reasons = {os.path.basename(p).split(".")[0]: r
               for p, r in report.quarantined}
    assert "ep0" in reasons
    assert "checksum mismatch" in reasons["ep0"]
    assert "shard_0" in reasons["ep0"]  # the reason names the shard dir
    # ep1's shards delta-chain onto ep0's dirs (the workload forces
    # deltas): quarantining ep0 orphans ep1, which must follow — the
    # recovered set is a clean PREFIX, never a superset
    assert "ep1" in reasons and "parent" in reasons["ep1"]
    assert report.recovered == []
    # quarantine MOVES, never deletes: the corrupt bytes are preserved
    qdir = pool / QUARANTINE_DIRNAME
    assert sorted(n.split(".")[0] for n in os.listdir(qdir)) == \
        ["ep0", "ep1"]


@pytest.mark.timeout(300)
def test_swap_roll_forward_and_roll_back(tmp_path):
    """Hand-built mid-swap states: a complete ``X.compact`` with the
    target missing rolls FORWARD; an ``X.old`` with the target missing
    rolls BACK; leftovers next to an intact target are dropped."""
    pool = tmp_path / "pool"
    pool.mkdir()
    store, eng = crash_child.build()
    for e in range(2):
        crash_child.write_epoch(store, eng, e)
        snap = eng.coordinator.bgsave_to_dir(str(pool / f"ep{e}"))
        assert snap.wait_persisted(120.0)
    expected = crash_child.expected_tables(2)

    # roll FORWARD: simulate death between "path -> path.old" and
    # "path.compact -> path" on a delta-chained shard dir
    cat0 = eng.catalog
    with cat0._lock:
        target = next(p for p in sorted(cat0._dirs)
                      if cat0._dirs[p].parent is not None)
    import shutil
    shutil.copytree(target, target + ".keep")  # stand-in full image
    # build a genuine fold the same way compact_dir would, then unwind
    # the swap to the mid-crash state
    cat0.compact_dir(target)
    os.rename(target, target + ".compact")
    os.rename(target + ".keep", target + ".old")

    cat = SnapshotCatalog.from_dir(str(pool))
    actions = dict((os.path.basename(p), a)
                   for p, a in cat.last_recovery.repaired_swaps)
    assert actions.get(os.path.basename(target)) == "rolled_forward"
    assert len(cat.last_recovery.recovered) == 2
    probe = np.arange(crash_child.CAPACITY, dtype=np.int64)
    store2, eng2 = crash_child.build()
    eng2.coordinator.catalog = cat
    tip = max(cat.last_recovery.recovered)
    np.testing.assert_array_equal(eng2.get_at(probe, tip), expected[1])

    # roll BACK: only an .old remains
    os.rename(target, target + ".old")
    cat2 = SnapshotCatalog.from_dir(str(pool))
    actions2 = dict((os.path.basename(p), a)
                    for p, a in cat2.last_recovery.repaired_swaps)
    assert actions2.get(os.path.basename(target)) == "rolled_back"
    assert len(cat2.last_recovery.recovered) == 2
