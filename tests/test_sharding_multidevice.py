"""Multi-device sharding tests run in SUBPROCESSES with 8 virtual devices
(XLA_FLAGS must be set before jax init, and the main test process must
keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest

_ENV = {**os.environ, "PYTHONPATH": "src"}


def _run(code: str, timeout=600) -> str:
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=_ENV)
    assert p.returncode == 0, p.stderr[-3000:]
    return p.stdout


def test_moe_ep_matches_single_device_oracle():
    """EP all_to_all dispatch on a (2,4) mesh == dense per-token oracle."""
    out = _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config
from repro.models.moe import init_moe, moe_forward
cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                          moe_capacity_factor=8.0)
params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
ref, _ = moe_forward(params, x, cfg)  # no-mesh single-device path
from repro.runtime.sharding import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with mesh:
    out, aux = jax.jit(lambda p, x: moe_forward(p, x, cfg))(params, x)
err = float(jnp.abs(out - ref).max())
assert err < 1e-4, err
print("EP_OK", err)
""")
    assert "EP_OK" in out


def test_train_step_shards_and_runs():
    """A reduced train step lowers, compiles AND RUNS on a (2,4) mesh with
    the production sharding rules; loss matches single-device run."""
    out = _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.runtime.steps import make_train_step, init_train_state
from repro.runtime.sharding import resolve_pspec
cfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(),
                          vocab=512, d_model=64)
model = build_model(cfg)
params, opt = init_train_state(model, jax.random.PRNGKey(0))
batch = {"tokens": np.random.randint(0, cfg.vocab, (4, 33)).astype(np.int32)}
fn = make_train_step(model)
ref_loss = float(fn(params, opt, batch)[2])
from repro.runtime.sharding import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
pspecs = model.param_pspecs()
shard = lambda spec, arr: jax.device_put(
    arr, NamedSharding(mesh, resolve_pspec(spec, tuple(arr.shape), mesh)))
sp = jax.tree_util.tree_map(shard, pspecs, params,
                            is_leaf=lambda x: isinstance(x, P) or x is None)
so = type(opt)(step=jax.device_put(opt.step, NamedSharding(mesh, P())),
               m=jax.tree_util.tree_map(shard, pspecs, opt.m,
                                        is_leaf=lambda x: isinstance(x, P) or x is None),
               v=jax.tree_util.tree_map(shard, pspecs, opt.v,
                                        is_leaf=lambda x: isinstance(x, P) or x is None))
with mesh:
    p2, o2, loss = jax.jit(fn)(sp, so, batch)
assert abs(float(loss) - ref_loss) < 5e-2, (float(loss), ref_loss)
print("SHARD_OK", float(loss), ref_loss)
""")
    assert "SHARD_OK" in out


def test_elastic_restore_across_meshes():
    """Checkpoint saved from a (4,2) mesh restores onto (2,4) and (8,1)
    meshes (elastic restart) with identical values."""
    out = _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.runtime.steps import init_train_state
from repro.runtime.sharding import make_mesh, resolve_pspec
from repro.checkpoint import TrainSnapshotManager, restore_checkpoint
cfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(),
                          vocab=512, d_model=64)
model = build_model(cfg)
params, opt = init_train_state(model, jax.random.PRNGKey(0))
host = jax.tree_util.tree_map(lambda a: np.asarray(a).copy(), params)
with tempfile.TemporaryDirectory() as d:
    mgr = TrainSnapshotManager(d, mode="asyncfork", copier_threads=2)
    mgr.save(0, params, opt)
    mgr.wait_all(120)
    rp, ro = restore_checkpoint(os.path.join(d, "step_00000000"))
for shape_ in [(2, 4), (8, 1)]:
    mesh = make_mesh(shape_, ("data", "model"))
    pspecs = model.param_pspecs()
    def place(spec, arr):
        return jax.device_put(jnp.asarray(arr), NamedSharding(
            mesh, resolve_pspec(spec, tuple(np.shape(arr)), mesh)))
    placed = jax.tree_util.tree_map(place, pspecs, rp,
                                    is_leaf=lambda x: isinstance(x, P) or x is None)
    flat_a = jax.tree_util.tree_leaves(placed)
    flat_b = jax.tree_util.tree_leaves(host)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a, b.dtype), b)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_dryrun_cell_small_mesh():
    """The dry-run machinery itself on an 8-device host (fast sanity that
    the 512-device sweep exercises the same code)."""
    out = _run(r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, dataclasses
import repro.launch.dryrun as dr
from repro.configs import get_config, SHAPES
from repro.runtime.sharding import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(), vocab=512)
compiled = dr._compile_cell(cfg, SHAPES["train_4k"], mesh)
f, b, c, colls = dr._cost_of(compiled)
assert f > 0 and b > 0
print("DRYRUN_OK", f)
""", timeout=900)
    assert "DRYRUN_OK" in out
