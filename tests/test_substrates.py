"""Optimizer, schedule, data pipeline, sharding resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeCfg
from repro.data.pipeline import SyntheticPipeline, batch_pspecs, make_batch_specs
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.optim.adamw import global_norm


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, lr=0.05, weight_decay=0.0)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 200


def test_adamw_clips_global_norm():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, s2 = adamw_update(params, huge, state, lr=0.1, clip_norm=1.0,
                          weight_decay=0.0)
    # update magnitude bounded by lr * (1/sqrt(vhat)) ~ O(lr)
    assert float(jnp.abs(p2["w"] - params["w"]).max()) < 1.0


def test_moments_are_fp32():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.m["w"].dtype == jnp.float32
    assert state.v["w"].dtype == jnp.float32


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.int32(0))) < 1e-5
    peak = float(cosine_schedule(jnp.int32(100)))
    end = float(cosine_schedule(jnp.int32(10_000)))
    assert peak > end > 0


def test_batch_specs_cover_all_cells():
    for arch in ("phi3-mini-3.8b", "whisper-medium", "qwen2-vl-7b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = make_batch_specs(cfg, shape)
            assert "tokens" in specs
            ps = batch_pspecs(cfg, shape, multi_pod=True)
            assert set(ps) == set(specs)


def test_pipeline_prefetch_and_reproducibility():
    cfg = get_config("phi3-mini-3.8b").reduced()
    shape = ShapeCfg("t", seq_len=32, global_batch=2, kind="train")
    a = next(iter(SyntheticPipeline(cfg, shape, seed=3)))
    b = next(iter(SyntheticPipeline(cfg, shape, seed=3)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 33)
    assert a["tokens"].max() < cfg.vocab


def test_resolve_pspec_divisibility():
    from repro.runtime.sharding import make_mesh, resolve_pspec

    mesh = make_mesh((1,), ("model",))
    # mesh axis of size 1 divides everything
    assert resolve_pspec(P("model", None), (8, 4), mesh) == P("model", None)
    # unknown logical names drop to None
    assert resolve_pspec(P("layers", "model"), (8, 4), mesh) == P(None, "model")
    # non-divisible dims replicate (simulated via axis size 1 is trivial;
    # use shape 0 edge to ensure no crash)
    assert resolve_pspec(None, (8,), mesh) == P()


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert np.isclose(float(global_norm(t)), np.sqrt(3 + 16))
