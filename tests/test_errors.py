"""§4.4 error handling: copy failures must roll back protection, abort the
child, and leave the parent (engine) fully functional."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncForkSnapshotter,
    FailingProvider,
    MemorySink,
    SnapshotError,
)


def _state():
    return {"table": jnp.ones((256, 128), jnp.float32)}


def test_child_copy_failure_aborts_snapshot_and_rolls_back():
    prov = FailingProvider(_state(), fail_on=lambda ref: ref.block_id == 3)
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1)
    snap = snapper.fork()
    with pytest.raises(SnapshotError):
        snap.wait(10)
    assert snap.aborted
    counts = snap.table.counts()
    # rollback: nothing left write-protected or locked (§4.4 case 2)
    assert counts["UNCOPIED"] == 0 and counts["COPYING"] == 0
    assert all(h.twoway.error is not None for h in snap.table.leaf_handles)


def test_parent_proactive_copy_failure_aborts_but_engine_survives():
    # fail only when the PARENT does the proactive copy of block 5
    prov = FailingProvider(_state(), fail_on=lambda ref: ref.block_id == 5)
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1,
                                   yield_every=0)
    # freeze the copier by monkeypatching its shard empty: use 0 threads trick
    snap = snapper.fork()
    # race: parent may or may not hit the failing block first; either way the
    # engine write path must not raise.
    rows = range(5 * 8, 5 * 8 + 4)
    snapper.before_write(0, rows)  # must NOT raise even if snapshot aborts
    old = prov.leaf(0)
    prov.update_leaf(0, old.at[np.asarray(list(rows))].set(-1.0), delete_old=True)
    assert float(prov.leaf(0)[40, 0]) == -1.0  # engine state intact


def test_persister_abort_cleans_sink():
    import time

    prov = FailingProvider(_state(), fail_on=lambda ref: ref.block_id == 7)
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1)
    sink = MemorySink()
    snap = snapper.fork(sink)
    with pytest.raises(SnapshotError):
        snap.wait_persisted(10)
    assert sink.aborted or not sink.closed
    # abort() unblocks waiters immediately (§4.4); the persister thread
    # notices asynchronously and then removes partial output — poll for it
    deadline = time.monotonic() + 5.0
    while sink.blocks and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not sink.blocks  # partial output removed


def test_engine_can_fork_again_after_abort():
    prov = FailingProvider(_state(), fail_on=lambda ref: ref.block_id == 2,
                           max_failures=1)
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1)
    s1 = snapper.fork()
    with pytest.raises(SnapshotError):
        s1.wait(10)
    s2 = snapper.fork()  # budget exhausted -> this one succeeds
    s2.wait(10)
    assert s2.ok
    tree = s2.to_tree()
    np.testing.assert_array_equal(np.asarray(tree["table"]),
                                  np.asarray(prov.leaf(0)))
