"""Sharded snapshot coordinator + parallel persist pipeline.

Covers the PR's acceptance criteria: out-of-order FileSink writes restore
byte-identical state; abort mid-persist with workers in flight removes the
sink directory and surfaces the error via wait_all; cross-shard fork
barrier consistency under a concurrently writing workload; and a shards=4
engine BGSAVE restoring to the exact read_all() taken at the barrier.
"""
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncForkSnapshotter,
    CoordinatedSnapshot,
    FailingProvider,
    FileSink,
    PersistPipeline,
    PyTreeProvider,
    ShardedSnapshotCoordinator,
    SnapshotError,
    read_file_snapshot,
)
from repro.core.blocks import BlockTable
from repro.kvstore import KVEngine, ShardedKVStore, Workload


def _providers(n, rows=128, cols=16, offset=0.0):
    return [
        PyTreeProvider({
            "kv": jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols)
            + offset + 1000.0 * k
        })
        for k in range(n)
    ]


# --------------------------------------------------------------------- #
# out-of-order parallel persist                                         #
# --------------------------------------------------------------------- #
def test_filesink_out_of_order_writes_restore_byte_identical(tmp_path):
    """pwrite layout: blocks written in any order (here: reversed, from
    multiple threads) reassemble to the exact T0 bytes."""
    state = {"kv": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)}
    table = BlockTable(state, block_bytes=8 * 32 * 4)  # 8 blocks
    sink = FileSink(str(tmp_path / "ooo"))
    sink.open(table.leaf_handles)
    host = np.asarray(state["kv"])
    refs = list(table.blocks)[::-1]  # reversed order

    def write(ref):
        sink.write_block(ref, host[ref.start:ref.stop])

    threads = [threading.Thread(target=write, args=(r,)) for r in refs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    sink.close()
    restored = read_file_snapshot(str(tmp_path / "ooo"))
    np.testing.assert_array_equal(restored["kv"], host)


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_parallel_persisters_restore_byte_identical(tmp_path, workers):
    prov = _providers(1)[0]
    t0 = np.asarray(prov.leaf(0)).copy()
    snapper = AsyncForkSnapshotter(
        prov, block_bytes=1024, copier_threads=2, persist_workers=workers
    )
    snap = snapper.fork(FileSink(str(tmp_path / f"w{workers}")))
    # donated writes racing the persist pipeline
    for i in range(8):
        snapper.before_write(0, [i * 4])
        old = prov.leaf(0)
        prov.update_leaf(0, old.at[i * 4].set(-1.0), delete_old=True)
    assert snap.wait_persisted(60)
    restored = read_file_snapshot(str(tmp_path / f"w{workers}"))
    np.testing.assert_array_equal(restored["kv"], t0)


def test_abort_mid_persist_with_workers_in_flight_removes_dir(tmp_path):
    """A copy failure while several persist workers are in flight aborts
    the epoch, removes the sink directory, and wait_all raises."""
    state = {"kv": jnp.ones((256, 64), jnp.float32)}
    # row-range predicate (block 9 = rows 72..80 at 8 rows/block): span
    # staging reads whole runs via one synthetic ref, so block_id
    # predicates would never fire
    prov = FailingProvider(state, fail_on=lambda ref: ref.start <= 72 < ref.stop)
    coord = ShardedSnapshotCoordinator(
        [prov], mode="asyncfork", block_bytes=2048,
        copier_threads=1, persist_workers=4,
    )
    d = str(tmp_path / "abort")
    snap = coord.bgsave(sinks=[FileSink(d)])
    with pytest.raises(SnapshotError):
        coord.wait_all(30)
    assert snap.aborted
    # FileSink.abort quiesces in-flight pwrites then removes the directory
    deadline = time.monotonic() + 5.0
    while os.path.exists(d) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not os.path.exists(d)


# --------------------------------------------------------------------- #
# cross-shard fork barrier                                              #
# --------------------------------------------------------------------- #
def test_barrier_union_is_point_in_time_single_writer():
    """Writes before the barrier land in the snapshot, writes after do
    not — across every shard, for one interleaving per shard count."""
    for n_shards in (2, 4):
        provs = _providers(n_shards)
        coord = ShardedSnapshotCoordinator(
            provs, mode="asyncfork", block_bytes=1024, copier_threads=2
        )

        def write(shard, row, val):
            coord.before_write(shard, 0, [row])
            old = provs[shard].leaf(0)
            provs[shard].update_leaf(0, old.at[row].set(val), delete_old=True)

        write(0, 3, -1.0)          # pre-barrier: must be IN the snapshot
        expected = [np.asarray(p.leaf(0)).copy() for p in provs]
        snap = coord.bgsave()
        for k in range(n_shards):  # post-barrier: must be OUT
            write(k, 5, -2.0)
        trees = snap.to_trees()
        for k in range(n_shards):
            np.testing.assert_array_equal(np.asarray(trees[k]["kv"]), expected[k])


def test_barrier_consistency_under_concurrent_writing_workload():
    """A writer thread hammers random shards through the write gate while
    the main thread takes repeated cross-shard BGSAVEs; every snapshot
    must equal the exact state captured under the gate at its barrier."""
    n_shards = 3
    provs = _providers(n_shards, rows=64, cols=8)
    coord = ShardedSnapshotCoordinator(
        provs, mode="asyncfork", block_bytes=512, copier_threads=2
    )
    stop = threading.Event()
    rng = np.random.default_rng(0)
    writes = []

    def writer():
        i = 0
        while not stop.is_set():
            k = int(rng.integers(n_shards))
            row = int(rng.integers(64))
            i += 1
            with coord.write_gate:  # gate held across sync -> commit
                coord.before_write(k, 0, [row])
                old = provs[k].leaf(0)
                provs[k].update_leaf(0, old.at[row].set(float(i)),
                                     delete_old=True)
            writes.append(i)

    th = threading.Thread(target=writer)
    th.start()
    try:
        for _ in range(5):
            time.sleep(0.01)
            with coord.write_gate:  # reentrant: bgsave retakes it
                expected = [np.asarray(p.leaf(0)).copy() for p in provs]
                snap = coord.bgsave()
            trees = snap.to_trees()
            for k in range(n_shards):
                np.testing.assert_array_equal(
                    np.asarray(trees[k]["kv"]), expected[k]
                )
    finally:
        stop.set()
        th.join()
    assert len(writes) > 0


# --------------------------------------------------------------------- #
# sharded engine end-to-end (acceptance criterion)                      #
# --------------------------------------------------------------------- #
def test_sharded_engine_bgsave_restores_barrier_state(tmp_path):
    """shards=4 engine: the persisted composite snapshot equals the
    read_all() taken at the fork barrier, under live donated traffic."""
    store = ShardedKVStore(capacity=2048, block_rows=128, row_width=16,
                           seed=0, shards=4)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=1.0)
    store.warmup(batch=8)
    t0 = store.read_all().copy()
    d = str(tmp_path / "cluster")
    snap = eng.coordinator.bgsave_to_dir(d)
    wl = Workload(rate_qps=1e9, set_ratio=1.0, batch=8, seed=2)
    vals = np.random.rand(8, 16).astype(np.float32)
    for ev in wl.events(store.capacity, 1e-4)[:50]:
        store.set(ev.rows, vals, before_write=eng._write_hook, gate=eng._gate)
    assert snap.wait_persisted(60)
    restored = read_file_snapshot(d)
    got = np.concatenate([
        np.concatenate([restored[f"shard{k}/blocks/{b}"]
                        for b in range(store.shards[k].n_blocks)])
        for k in range(store.n_shards)
    ])
    np.testing.assert_array_equal(got, t0)
    assert store.read_all().shape == t0.shape  # engine alive and well


def test_sharded_engine_report_aggregates_per_shard_metrics():
    store = ShardedKVStore(capacity=2048, block_rows=256, row_width=16,
                           seed=0, shards=2)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=1.0)
    wl = Workload(rate_qps=300, set_ratio=0.5, batch=8, seed=0)
    rep = eng.run(wl, duration_s=0.5, bgsave_at=(0.3,))
    s = rep.summary()
    assert s["shards"] == 2.0
    assert rep.snapshot_metrics and len(rep.snapshot_metrics[0]["per_shard"]) == 2


def test_sharded_store_routing_round_trip():
    store = ShardedKVStore(capacity=4096, block_rows=256, row_width=8,
                           seed=0, shards=4)
    rows = np.array([0, 5, 1024, 2000, 4095], dtype=np.int64)
    vals = np.random.rand(5, 8).astype(np.float32)
    store.set(rows, vals)
    got = store.get(rows)
    # get() returns shard-then-block grouped order == sorted rows here
    np.testing.assert_allclose(got, vals[np.argsort(rows)], rtol=0, atol=0)
    assert store.read_all().shape == (store.capacity, 8)


def test_coordinated_snapshot_metrics_rollup():
    provs = _providers(2)
    coord = ShardedSnapshotCoordinator(provs, mode="blocking", block_bytes=1024)
    snap = coord.bgsave()
    assert isinstance(snap, CoordinatedSnapshot)
    snap.wait_persisted(30)
    m = snap.metrics
    total_blocks = sum(s.table.n_blocks for s in snap.parts)
    assert m.copied_blocks_child == total_blocks
    s = m.summary()
    assert s["shards"] == 2.0 and len(s["per_shard"]) == 2


def test_mid_barrier_failure_aborts_prepared_shards():
    """A commit failure on one shard must not strand the other shards'
    prepared epochs: their events fire (no wait_all stall) and nothing
    stays in the active registries pinning T0 refs."""
    state = {"kv": jnp.ones((64, 16), jnp.float32)}
    provs = [PyTreeProvider(dict(state)),
             FailingProvider(dict(state), fail_on=lambda ref: True,
                             max_failures=10_000),
             PyTreeProvider(dict(state))]
    coord = ShardedSnapshotCoordinator(provs, mode="blocking",
                                       block_bytes=512)
    with pytest.raises(SnapshotError):
        coord.bgsave()
    for sn in coord.snapshotters:
        for snap in sn._active:
            assert snap.copy_done.is_set() and snap.persist_done.is_set()
        assert sn.active() == []


def test_pipeline_idle_workers_exit_and_respawn():
    """Workers spawned for a job exit after the idle timeout and the next
    submit respawns them (no thread leak across many checkpoint saves)."""
    pipe = PersistPipeline(workers=2, idle_timeout=0.05)
    prov = _providers(1)[0]
    for _ in range(2):
        snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1)
        snapper.persist_pipeline = pipe
        from repro.core import MemorySink
        snap = snapper.fork(MemorySink())
        assert snap.wait_persisted(30)
        deadline = time.monotonic() + 5.0
        while any(t.is_alive() for t in pipe._threads) and \
                time.monotonic() < deadline:
            time.sleep(0.02)
        assert not any(t.is_alive() for t in pipe._threads)
