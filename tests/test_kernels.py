"""Pallas kernel tests: shape/dtype sweep vs the jnp oracle (interpret
mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import as_blocks, dirty_blocks, masked_block_copy
from repro.kernels.ref import dirty_ref, snapcopy_ref
from repro.kernels.snapcopy import COPIED, UNCOPIED


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n_blocks,elems,tile", [
    (4, 256, 256), (8, 1024, 256), (3, 512, 512), (16, 2048, 1024),
])
def test_snapcopy_matches_oracle(dtype, n_blocks, elems, tile):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n_blocks + elems))
    if jnp.issubdtype(dtype, jnp.integer):
        src = jax.random.randint(k1, (n_blocks, elems), 0, 100, dtype)
        dst = jax.random.randint(k2, (n_blocks, elems), 0, 100, dtype)
    else:
        src = jax.random.normal(k1, (n_blocks, elems)).astype(dtype)
        dst = jax.random.normal(k2, (n_blocks, elems)).astype(dtype)
    flags = jnp.asarray(
        np.random.default_rng(0).choice([UNCOPIED, COPIED], n_blocks), jnp.int32
    )
    out, nf = masked_block_copy(src, dst, flags, tile=tile)
    ref_out, ref_nf = snapcopy_ref(src, dst, flags)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    np.testing.assert_array_equal(np.asarray(nf), np.asarray(ref_nf))
    assert bool((nf != UNCOPIED).all())  # everything protected got copied


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_blocks,elems,tile", [
    (4, 256, 256), (6, 1024, 512), (2, 4096, 1024),
])
def test_dirty_matches_oracle(dtype, n_blocks, elems, tile):
    old = jax.random.normal(jax.random.PRNGKey(0), (n_blocks, elems)).astype(dtype)
    new = old.at[1, 5].add(1.0)
    if n_blocks > 2:
        new = new.at[n_blocks - 1, elems - 1].add(2.0)
    out = dirty_blocks(old, new, tile=tile)
    ref = dirty_ref(old, new)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(out[1]) == 1 and int(out[0]) == 0


def test_dirty_detects_single_element_change_any_tile():
    """Accumulation across grid tiles: a change in ANY tile flips the flag."""
    old = jnp.zeros((2, 2048), jnp.float32)
    for pos in (0, 1023, 1024, 2047):
        new = old.at[1, pos].set(1.0)
        out = dirty_blocks(old, new, tile=1024)
        assert int(out[1]) == 1 and int(out[0]) == 0, pos


def test_as_blocks_pads_tail():
    x = jnp.arange(10.0)
    b = as_blocks(x, 4)
    assert b.shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(b[2]), [8.0, 9.0, 0.0, 0.0])


def test_snapcopy_all_uncopied_is_full_copy():
    src = jnp.arange(8 * 256, dtype=jnp.float32).reshape(8, 256)
    dst = jnp.zeros_like(src)
    flags = jnp.zeros((8,), jnp.int32)
    out, nf = masked_block_copy(src, dst, flags)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(src))
    assert bool((nf == COPIED).all())
