"""Staging backends + incremental (dirty-block) snapshot epochs.

Backend parity: HostStaging and DeviceStaging must produce identical T0
images under concurrent donated writes in all three snapshotter modes.
Incremental epochs: only dirty blocks reach the sink, restores through a
FileSink delta chain equal the full-snapshot restore.
"""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockState,
    MemorySink,
    FileSink,
    PyTreeProvider,
    make_snapshotter,
    read_file_snapshot,
)
from repro.core.staging import mirror_flags
from repro.kernels.ops import pick_tile, to_blocked

MODES = ["blocking", "cow", "asyncfork"]
BACKENDS = ["host", "device"]


def _state(rows=128, cols=32):
    return {
        "kv": jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols),
        "meta": jnp.full((4,), 7.0, jnp.float32),
        "step": jnp.float32(11.0),
    }


def _donated_update(prov, snapper, leaf_id, rows, value):
    snapper.before_write(leaf_id, rows)
    old = prov.leaf(leaf_id)
    prov.update_leaf(leaf_id, old.at[np.asarray(rows)].set(value), delete_old=True)


# --------------------------------------------------------------------- #
# backend parity                                                        #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
def test_backends_consistent_under_writes(mode, backend):
    prov = PyTreeProvider(_state())
    t0_kv = np.asarray(prov.leaf(0)).copy()
    snapper = make_snapshotter(
        mode, prov, block_bytes=2048, copier_threads=2, backend=backend
    )
    snap = snapper.fork()
    for step in range(8):
        _donated_update(prov, snapper, 0, list(range(step * 4, step * 4 + 4)), -1.0)
    tree = snap.to_tree()
    np.testing.assert_array_equal(np.asarray(tree["kv"]), t0_kv)
    np.testing.assert_array_equal(np.asarray(tree["meta"]), np.full((4,), 7.0))
    assert float(np.asarray(tree["step"])) == 11.0
    assert float(prov.leaf(0)[0, 0]) == -1.0  # live state moved on


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_persists_through_sink(backend):
    prov = PyTreeProvider(_state())
    sink = MemorySink()
    snapper = make_snapshotter(
        "asyncfork", prov, block_bytes=2048, copier_threads=2, backend=backend
    )
    snap = snapper.fork(sink)
    snap.wait_persisted(60)
    assert sink.closed
    assert len(sink.blocks) == snap.table.n_blocks
    # sink contents reassemble to the T0 leaf regardless of backend
    h = snap.table.leaf_handles[0]
    rebuilt = np.concatenate(
        [np.asarray(sink.blocks[(0, b.block_id)]) for b in h.blocks]
    )
    np.testing.assert_array_equal(rebuilt, np.asarray(snap.to_tree()["kv"]))


# --------------------------------------------------------------------- #
# incremental epochs                                                    #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", MODES)
def test_incremental_persists_exactly_dirty_blocks(mode, backend):
    prov = PyTreeProvider(_state())
    snapper = make_snapshotter(
        mode, prov, block_bytes=2048, copier_threads=2,
        backend=backend, retain_images=True,
    )
    s1 = snapper.fork(MemorySink())
    s1.wait_persisted(60)
    # kv blocks are 2048B/(32*4B) = 16 rows; touch rows in exactly 2 blocks
    for r in (0, 17):
        _donated_update(prov, snapper, 0, [r], -5.0)
    live_kv = np.asarray(prov.leaf(0)).copy()
    sink2 = MemorySink()
    s2 = snapper.fork(sink2, incremental=True)
    s2.wait_persisted(60)
    # exactly the 2 dirty kv blocks persisted; meta/step unchanged -> inherited
    assert set(sink2.blocks) == {(0, 0), (0, 1)}
    assert s2.metrics.inherited_blocks == s2.table.n_blocks - 2
    assert all(
        s2.table.state(k) == BlockState.PERSISTED for k in s2.inherited
    )
    np.testing.assert_array_equal(np.asarray(s2.to_tree()["kv"]), live_kv)


def test_incremental_without_base_is_full():
    prov = PyTreeProvider(_state())
    snapper = make_snapshotter(
        "asyncfork", prov, block_bytes=2048, retain_images=True
    )
    sink = MemorySink()
    snap = snapper.fork(sink, incremental=True)  # no previous epoch yet
    snap.wait_persisted(60)
    assert snap.metrics.inherited_blocks == 0
    assert len(sink.blocks) == snap.table.n_blocks


@pytest.mark.parametrize("backend", BACKENDS)
def test_incremental_restore_equals_full_restore(backend, tmp_path):
    prov = PyTreeProvider(_state())
    snapper = make_snapshotter(
        "asyncfork", prov, block_bytes=2048, copier_threads=2,
        backend=backend, retain_images=True,
    )
    s1 = snapper.fork(FileSink(str(tmp_path / "full_0")))
    s1.wait_persisted(60)
    for r in (3, 40, 90):
        _donated_update(prov, snapper, 0, [r], 123.0)
    _donated_update(prov, snapper, 1, [2], -9.0)

    # delta snapshot chained on full_0 + an independent full snapshot
    s2 = snapper.fork(
        FileSink(str(tmp_path / "delta_1"), parent="full_0"), incremental=True
    )
    s2.wait_persisted(60)
    full = make_snapshotter("blocking", prov, block_bytes=2048, backend=backend)
    s3 = full.fork(FileSink(str(tmp_path / "full_1")))
    s3.wait_persisted(60)

    delta_restore = read_file_snapshot(str(tmp_path / "delta_1"))
    full_restore = read_file_snapshot(str(tmp_path / "full_1"))
    assert set(delta_restore) == set(full_restore)
    for path in full_restore:
        np.testing.assert_array_equal(delta_restore[path], full_restore[path])


def test_filesink_delta_manifest_round_trip(tmp_path):
    """The delta manifest records carried vs inherited blocks and the
    parent link resolves relative to the sibling directory."""
    import json

    prov = PyTreeProvider(_state())
    snapper = make_snapshotter(
        "blocking", prov, block_bytes=2048, retain_images=True
    )
    s1 = snapper.fork(FileSink(str(tmp_path / "a")))
    s1.wait_persisted(60)
    _donated_update(prov, snapper, 0, [0], 1.5)
    live = np.asarray(prov.leaf(0)).copy()
    s2 = snapper.fork(FileSink(str(tmp_path / "b"), parent="a"), incremental=True)
    s2.wait_persisted(60)

    with open(tmp_path / "b" / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["parent"] == "a"
    kv = next(l for l in manifest["leaves"] if l["path"] == "kv")
    assert kv["carried"] == [0]  # only the written block travels
    assert len(kv["blocks"]) == s2.table.leaf_handles[0].geometry().n_blocks
    out = read_file_snapshot(str(tmp_path / "b"))
    np.testing.assert_array_equal(out["kv"], live)


def test_fork_start_is_stamped_before_table_build():
    prov = PyTreeProvider(_state())
    snapper = make_snapshotter("blocking", prov, block_bytes=2048)
    snap = snapper.fork()
    # fork_start anchors the engine's snapshot-window span at the real
    # fork entry, which precedes the handle's t0 (post-table-build)
    assert snap.fork_start <= snap.t0


# --------------------------------------------------------------------- #
# kernel wrapper helpers                                                #
# --------------------------------------------------------------------- #
def test_pick_tile_divides():
    for elems in (1024, 512, 96, 33, 1):
        t = pick_tile(elems)
        assert elems % t == 0 and t <= 1024


def test_to_blocked_round_trip():
    leaf = jnp.arange(10 * 3, dtype=jnp.float32).reshape(10, 3)
    blocked = to_blocked(leaf, 3, 12)  # 4 rows per block, last block padded
    assert blocked.shape == (3, 12)
    flat = np.asarray(blocked).reshape(-1)[: 10 * 3]
    np.testing.assert_array_equal(flat.reshape(10, 3), np.asarray(leaf))


def test_mirror_flags_tracks_table_state():
    from repro.core import BlockTable

    table = BlockTable(_state(), block_bytes=2048)
    h = table.leaf_handles[0]
    table.try_acquire(h.blocks[0].key)          # -> COPYING
    table.mark(h.blocks[1].key, BlockState.COPIED)
    flags = mirror_flags(table, 0, force_uncopied=0)
    assert flags[0] == int(BlockState.UNCOPIED)  # forced open for the stage
    assert flags[1] == int(BlockState.COPIED)
    assert flags[2] == int(BlockState.UNCOPIED)
