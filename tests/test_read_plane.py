"""Concurrent read plane (PR 6): SharedGate / shared-stripe semantics,
the single-publish routing view, seqlock reads, the RequestServer, and
the headline invariant —

    ANY interleaving of concurrent readers with per-shard writers, BGSAVE
    barriers, and split/merge loops yields, for every row of every read,
    a value some prefix of that row's committed writes could produce —
    never a torn row, never bytes through a retired store's stale routing
    (DESIGN.md §10).

The concurrency tests run seeded even without hypothesis; with the
optional 'test' extra installed, a hypothesis wrapper additionally draws
the reader/writer/shard geometry and the reshard op.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import GateRetired, GateSet, SharedGate, SnapshotMetrics
from repro.kvstore import (
    FlushRequest,
    GetRequest,
    KVEngine,
    RequestServer,
    SetRequest,
    ShardedKVStore,
    Workload,
)
from repro.kvstore.store import RoutingView

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property wrapper skips; seeded tests still run
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------- #
# SharedGate unit semantics                                              #
# --------------------------------------------------------------------- #
def test_shared_readers_overlap():
    g = SharedGate()
    assert g.acquire_shared(blocking=False)
    ok = threading.Event()

    def other():
        assert g.acquire_shared(blocking=False)  # overlaps the first hold
        g.release_shared()
        ok.set()

    th = threading.Thread(target=other)
    th.start()
    th.join(5.0)
    assert ok.is_set()
    g.release_shared()


def test_exclusive_excludes_shared_and_vice_versa():
    g = SharedGate()
    with g:  # exclusive
        done = []
        th = threading.Thread(
            target=lambda: done.append(g.acquire_shared(blocking=False)))
        th.start()
        th.join(5.0)
        assert done == [False]
    g.acquire_shared()
    done2 = []
    th = threading.Thread(target=lambda: done2.append(g.acquire(blocking=False)))
    th.start()
    th.join(5.0)
    assert done2 == [False]
    g.release_shared()


def test_writer_preference_blocks_new_shared():
    """A QUEUED exclusive acquirer must not starve behind a stream of
    overlapping readers: once a writer waits, fresh shared acquires from
    other threads block until it gets through."""
    g = SharedGate()
    g.acquire_shared()
    writer_in = threading.Event()

    def writer():
        with g:
            writer_in.set()

    th = threading.Thread(target=writer)
    th.start()
    time.sleep(0.05)  # let the writer queue up on the condition
    late = []
    th2 = threading.Thread(
        target=lambda: late.append(g.acquire_shared(blocking=False)))
    th2.start()
    th2.join(5.0)
    assert late == [False]  # writer-preference: the late reader yields
    g.release_shared()
    th.join(5.0)
    assert writer_in.is_set()


def test_exclusive_holder_may_read_shared():
    """The barrier thread reads through its own stripes (reentrant
    shared-in-exclusive — e.g. a bgsave gathering under the all-gate)."""
    g = SharedGate()
    with g:
        assert g.acquire_shared(blocking=False)
        g.release_shared()


def test_shared_release_without_hold_raises():
    g = SharedGate()
    with pytest.raises(RuntimeError):
        g.release_shared()
    with pytest.raises(RuntimeError):
        g.release()


def test_gateset_shared_blocked_on_fresh_stripe_until_barrier_exit():
    """A stripe born held from a mid-barrier resize admits readers only
    when the resizing barrier exits — same rule as writers."""
    gs = GateSet(2)
    got = threading.Event()

    def reader_new_stripe():
        sg, _ = gs.acquire_shared(2)  # only exists after the resize
        sg.release_shared()
        got.set()

    gs.acquire_all()
    gs.resize(3, carry={0: 0, 1: 1})
    th = threading.Thread(target=reader_new_stripe)
    th.start()
    th.join(0.2)
    assert not got.is_set()  # fresh gate is exclusive-held by the barrier
    gs.release_all()
    assert got.wait(5.0)
    th.join(5.0)


def test_gateset_shared_out_of_range_raises_retired():
    gs = GateSet(2)
    with pytest.raises(GateRetired):
        gs.acquire_shared(5)


def test_all_gate_barrier_not_starved_by_hot_writer():
    """FIFO service order: a writer hammering acquire/release in a tight
    loop must not indefinitely re-take a briefly free stripe ahead of a
    blocked all-gate barrier (a bare Condition lets the running thread
    win every wakeup race — the barrier once starved for minutes here)."""
    gs = GateSet(3)
    stop = threading.Event()

    def hot_writer():
        while not stop.is_set():
            with gs.all():
                time.sleep(0.0005)

    th = threading.Thread(target=hot_writer)
    th.start()
    try:
        time.sleep(0.05)  # let the writer reach steady-state hammering
        for _ in range(3):
            t0 = time.perf_counter()
            with gs.all():
                waited = time.perf_counter() - t0
            # generous bound: pre-fix this exceeded 60s routinely
            assert waited < 5.0, f"barrier starved {waited:.1f}s"
    finally:
        stop.set()
        th.join(10.0)


def test_gateset_shared_wait_metered():
    gs = GateSet(2)
    sg, w = gs.acquire_shared(0)
    assert w == 0.0  # uncontended: no wait charged
    sg.release_shared()
    summ = gs.wait_summary()
    assert "shared_wait_us" in summ and "shared_waits" in summ


# --------------------------------------------------------------------- #
# routing view: one atomic publish                                       #
# --------------------------------------------------------------------- #
def test_routing_view_is_single_published_object():
    store = ShardedKVStore(4 * 16 * 2, row_width=8, block_rows=16, shards=2)
    v = store._view
    assert isinstance(v, RoutingView)
    # every routing accessor derives from the ONE view (the pre-PR-6
    # split publication of _row_bounds then layout is gone)
    assert store.layout is v.layout
    assert store._row_bounds is v.row_bounds
    assert store.capacity == int(v.row_bounds[-1])
    assert v.stores == tuple(store.shards)
    store.split(0)
    v2 = store._view
    assert v2 is not v and v2.layout.epoch == 1
    assert store._seq == 2  # even again: seqlock round-tripped


def test_get_concurrent_returns_input_order():
    store = ShardedKVStore(4 * 16 * 3, row_width=8, block_rows=16, shards=3)
    rng = np.random.default_rng(0)
    rows = rng.permutation(store.capacity)[:40].astype(np.int64)
    vals = rng.random((40, 8), dtype=np.float32)
    store.set(rows, vals)
    out = store.get_concurrent(rows)
    assert np.array_equal(out, vals)  # scrambled cross-shard, cross-block


# --------------------------------------------------------------------- #
# readers vs writers / barriers / reshards (tentpole acceptance)         #
# --------------------------------------------------------------------- #
def _run_read_interleaving(n_shards, writers, readers, seed=0,
                           duration_s=0.8, reshard=True):
    """Concurrent get_concurrent readers vs span-confined writers, a
    BGSAVE loop, and (optionally) a split/merge loop. Returns per-read
    records for the prefix-consistency check."""
    block_rows = 16
    capacity = n_shards * 4 * block_rows
    store = ShardedKVStore(capacity, row_width=8, block_rows=block_rows,
                           seed=seed, shards=n_shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=1,
                   persist_bandwidth=None, copier_duty=1.0)
    store.warmup(batch=4)
    init = store.read_all().copy()
    spans = [(w * capacity // writers, (w + 1) * capacity // writers)
             for w in range(writers)]
    batch_log = [[] for _ in range(writers)]  # (seq, t_start, t_end)
    reads = []       # (writer, rows, out, t_start, t_end)
    reads_lock = threading.Lock()
    errors = []
    stop = threading.Event()
    start = threading.Barrier(writers + readers + 1)

    def writer(w):
        lo, hi = spans[w]
        rows = np.arange(lo, hi, dtype=np.int64)
        start.wait()
        try:
            seq = 0
            while not stop.is_set():
                seq += 1
                vals = np.full((rows.size, 8), float(w * 1000 + seq),
                               np.float32)
                t0 = time.perf_counter()
                store.set(rows, vals, before_write=eng._write_hook,
                          gate=eng._gate, on_gate_wait=eng._gate_wait_hook)
                batch_log[w].append((seq, t0, time.perf_counter()))
        except BaseException as exc:  # pragma: no cover - asserted below
            errors.append(exc)

    def reader(r):
        rng = np.random.default_rng(seed * 100 + r)
        start.wait()
        try:
            local = []
            while not stop.is_set():
                w = int(rng.integers(0, writers))
                lo, hi = spans[w]
                a = int(rng.integers(lo, hi - 4))
                rows = np.arange(a, a + 4, dtype=np.int64)
                t0 = time.perf_counter()
                out = store.get_concurrent(
                    rows, gate=eng._gate,
                    on_read_event=eng._read_event_hook)
                local.append((w, rows, out, t0, time.perf_counter()))
            with reads_lock:
                reads.extend(local)
        except BaseException as exc:  # pragma: no cover - asserted below
            errors.append(exc)

    def reshard_loop():
        try:
            while not stop.is_set():
                eng.split(0)
                eng.merge(0, 1)
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    def barrier_loop():
        try:
            while not stop.is_set():
                eng.coordinator.bgsave().wait_persisted(30)
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    threads += [threading.Thread(target=reader, args=(r,))
                for r in range(readers)]
    extra = [threading.Thread(target=barrier_loop)]
    if reshard:
        extra.append(threading.Thread(target=reshard_loop))
    for th in threads + extra:
        th.start()
    start.wait()
    time.sleep(duration_s)
    stop.set()
    for th in threads + extra:
        th.join(60.0)
        assert not th.is_alive(), "read-plane thread deadlocked"
    assert not errors, errors
    eng.coordinator.wait_all(60)
    return init, batch_log, reads


def _check_prefix_consistent_reads(init, batch_log, reads):
    """Per ROW of every read: the observed value is either the row's
    initial value or some writer batch w*1000+seq, with seq bounded below
    by the newest batch that COMPLETED before the read began and above by
    the newest batch that STARTED before the read ended — i.e. exactly a
    prefix of that row's committed writes. Any stale-routing read through
    a retired store would surface as an impossible seq or a foreign
    writer's value."""
    assert reads, "readers recorded nothing"
    for w, rows, out, t0, t1 in reads:
        log = batch_log[w]
        floor = max((s for s, _, e in log if e < t0), default=0)
        ceil = max((s for s, b, _ in log if b < t1), default=0)
        for i, row in enumerate(rows):
            if np.array_equal(out[i], init[row]):
                assert floor == 0, (
                    f"row {row}: read returned the INITIAL value after "
                    f"batch {floor} completed (read through a retired "
                    "store's stale buffers)"
                )
                continue  # prefix of length zero, pre-first-batch
            rv = np.unique(out[i])
            assert rv.size == 1, (
                f"row {row}: torn ROW in read (values {rv[:4]}...) — one "
                "row is written by one scatter, it can never be mixed"
            )
            seq = int(round(float(rv[0]))) - w * 1000
            assert 1 <= seq <= len(log), (
                f"row {row}: value {v} is no batch of writer {w} "
                "(stale routing through a retired store?)"
            )
            assert seq >= floor, (
                f"row {row}: read saw batch {seq} but batch {floor} "
                f"completed before the read began (time-travel read)"
            )
            assert seq <= ceil, (
                f"row {row}: read saw batch {seq} which only started "
                f"after the read ended"
            )


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_readers_vs_writers_barriers_and_reshards(seed):
    init, batch_log, reads = _run_read_interleaving(
        n_shards=2, writers=2, readers=3, seed=seed)
    _check_prefix_consistent_reads(init, batch_log, reads)


def test_readers_vs_writers_no_reshard_mostly_lock_free():
    """With no reshard loop the seqlock never bumps: reads must still be
    donation-safe (deleted-buffer retries) and prefix-consistent."""
    init, batch_log, reads = _run_read_interleaving(
        n_shards=2, writers=2, readers=2, seed=7, reshard=False)
    _check_prefix_consistent_reads(init, batch_log, reads)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(
        n_shards=st.integers(2, 3),
        writers=st.integers(1, 3),
        readers=st.integers(1, 4),
        seed=st.integers(0, 3),
        reshard=st.booleans(),
    )
    def test_property_reads_are_prefix_consistent(
        n_shards, writers, readers, seed, reshard
    ):
        init, batch_log, reads = _run_read_interleaving(
            n_shards=n_shards, writers=writers, readers=readers,
            seed=seed, duration_s=0.4, reshard=reshard)
        _check_prefix_consistent_reads(init, batch_log, reads)


def test_get_concurrent_bounded_retries_fall_back_to_shared():
    """Seqlock churn must not livelock: with the counter pinned ODD (a
    reshard forever mid-swap, the worst case) the fast path exhausts its
    bounded retries and the shared-stripe fallback still completes the
    read — against the stripes, which nothing holds here."""
    store = ShardedKVStore(2 * 4 * 16, row_width=8, block_rows=16, shards=2)
    gs = GateSet(2)
    rows = np.arange(8, dtype=np.int64)
    vals = np.random.rand(8, 8).astype(np.float32)
    store.set(rows, vals)
    store._seq = 1  # pinned odd: every fast-path attempt must retry
    try:
        events = []
        out = store.get_concurrent(
            rows, gate=gs, max_retries=3,
            on_read_event=lambda k, r, w: events.append((k, r, w)))
        assert np.array_equal(out, vals)
        assert events and events[0][1] == 3  # all three retries, then shared
    finally:
        store._seq = 0


# --------------------------------------------------------------------- #
# RequestServer                                                          #
# --------------------------------------------------------------------- #
def _small_engine(shards=2):
    store = ShardedKVStore(shards * 4 * 16, row_width=8, block_rows=16,
                           shards=shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=1,
                   persist_bandwidth=None, copier_duty=1.0)
    store.warmup(batch=4)
    return eng


def test_request_server_round_trip_and_stats():
    eng = _small_engine()
    with RequestServer(eng, readers=3, queue_depth=16) as srv:
        rows = np.arange(12, dtype=np.int64)
        vals = np.random.rand(12, 8).astype(np.float32)
        srv.set(rows, vals)
        assert np.array_equal(srv.get(rows), vals)
        snap = srv.flush()
        assert snap.wait_persisted(60) and snap.ok
        s = srv.stats()
        assert s["gets"] == 1.0 and s["sets"] == 1.0 and s["flushes"] == 1.0
        assert s["queue_depth_max"] >= 0.0 and s["readers"] == 3.0
    eng.coordinator.wait_all(60)


def test_request_server_open_loop_submit():
    """Open-loop clients: submit N gets without waiting, collect replies
    afterwards — every reply carries a completion timestamp."""
    eng = _small_engine()
    rows = np.arange(8, dtype=np.int64)
    vals = np.random.rand(8, 8).astype(np.float32)
    eng.store.set(rows, vals)
    with RequestServer(eng, readers=4, queue_depth=32) as srv:
        t0 = time.perf_counter()
        msgs = [srv.submit(GetRequest(rows)) for _ in range(16)]
        for m in msgs:
            rep = m.wait(timeout=30)
            assert rep.error is None
            assert rep.done_t >= t0
            assert np.array_equal(rep.value, vals)


def test_request_server_concurrent_sessions():
    """Many threads hammer get/set/flush through one server: replies all
    arrive, every read is a full row the engine could have produced."""
    eng = _small_engine()
    cap = eng.store.capacity
    errors = []
    with RequestServer(eng, readers=4, queue_depth=64) as srv:
        def session(c):
            rng = np.random.default_rng(c)
            try:
                for i in range(20):
                    a = int(rng.integers(0, cap - 4))
                    rows = np.arange(a, a + 4, dtype=np.int64)
                    if i % 3 == 0:
                        srv.set(rows, np.full((4, 8), float(c), np.float32))
                    else:
                        out = srv.get(rows)
                        assert out.shape == (4, 8)
                if c == 0:
                    srv.flush().wait_persisted(60)
            except BaseException as exc:  # pragma: no cover
                errors.append(exc)

        ths = [threading.Thread(target=session, args=(c,)) for c in range(6)]
        for th in ths:
            th.start()
        for th in ths:
            th.join(60.0)
            assert not th.is_alive()
    assert not errors, errors
    eng.coordinator.wait_all(60)


def test_request_server_serial_arm_enforces_one_worker():
    eng = _small_engine()
    with pytest.raises(ValueError):
        RequestServer(eng, readers=2, concurrent_reads=False)
    srv = RequestServer(eng, readers=1, concurrent_reads=False)
    rows = np.arange(4, dtype=np.int64)
    vals = np.random.rand(4, 8).astype(np.float32)
    srv.set(rows, vals)
    assert np.array_equal(srv.get(rows), vals)
    srv.close()


def test_request_server_error_reply_and_close():
    eng = _small_engine()
    srv = RequestServer(eng, readers=2)
    rep = srv.submit(object()).wait(timeout=30)  # unknown request type
    assert isinstance(rep.error, TypeError)
    srv.close()
    srv.close()  # idempotent
    with pytest.raises(RuntimeError):
        srv.get(np.arange(4, dtype=np.int64))


# --------------------------------------------------------------------- #
# metrics plumbing                                                       #
# --------------------------------------------------------------------- #
def test_read_metrics_reach_every_summary():
    m = SnapshotMetrics()
    m.record_read_event(3, 0.002)
    s = m.summary()
    assert s["read_retries"] == 3.0
    assert s["shared_wait_us"] == pytest.approx(2000.0)
    assert s["shared_waits"] == 1.0

    eng = _small_engine()
    snap = eng.coordinator.bgsave()
    # out-of-range shard ids clamp instead of raising (a reshard may have
    # shrunk the layout since the read routed); charges only land while
    # the epoch is in flight, so aggregate through the part directly
    eng.coordinator.note_read_event(99, 1, 0.0)
    snap.parts[0].metrics.record_read_event(2, 0.001)
    snap.wait_persisted(60)
    agg = snap.metrics.summary()
    assert agg["read_retries"] == 2.0
    assert agg["shared_wait_us"] == pytest.approx(1000.0)

    rep = eng.run(Workload(rate_qps=200.0, set_ratio=0.5), 0.3,
                  bgsave_at=(0.3,))
    summ = rep.summary()
    for key in ("read_retries", "shared_wait_us", "server_queue_depth"):
        assert key in summ
    eng.coordinator.wait_all(60)
