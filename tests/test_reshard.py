"""Dynamic shard layouts: epoch-safe split/merge resharding, the per-shard
full-vs-delta BgsavePolicy, run-aware proactive sync, and cross-layout
restore (ISSUE 4 acceptance criteria)."""
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AggregateMetrics,
    BgsavePolicy,
    PyTreeProvider,
    ShardEpochView,
    ShardLayout,
    ShardedSnapshotCoordinator,
    make_snapshotter,
    read_file_snapshot,
    read_snapshot_layout,
)
from repro.kvstore import KVEngine, ShardedKVStore, Workload


# --------------------------------------------------------------------- #
# ShardLayout                                                           #
# --------------------------------------------------------------------- #
def test_layout_split_merge_epochs_and_bounds():
    L = ShardLayout.uniform([4, 4])
    assert (L.n_shards, L.n_blocks, L.epoch) == (2, 8, 0)
    L2 = L.split(0)
    assert L2.bounds == (0, 2, 4, 8) and L2.epoch == 1
    L3 = L2.split(2, at_block=1)
    assert L3.bounds == (0, 2, 4, 5, 8) and L3.epoch == 2
    L4 = L3.merge(2, 3)
    assert L4.bounds == (0, 2, 4, 8) and L4.epoch == 3
    with pytest.raises(ValueError):
        L.merge(0, 2)  # non-adjacent
    with pytest.raises(ValueError):
        ShardLayout.uniform([1]).split(0)  # single block
    with pytest.raises(ValueError):
        L.split(0, at_block=4)  # boundary split = no-op split


def test_layout_block_translation_and_parents():
    L = ShardLayout.uniform([4, 4])
    L2 = L.split(1, at_block=3)  # bounds (0, 4, 7, 8)
    for g in range(8):
        k = L2.shard_of_block(g)
        assert L2.bounds[k] <= g < L2.bounds[k + 1]
    np.testing.assert_array_equal(
        L2.shard_of_blocks(np.arange(8)), [0, 0, 0, 0, 1, 1, 1, 2]
    )
    assert L2.parents(L) == [[0], [1], [1]]
    assert L2.unchanged_shards(L) == {0: 0}
    merged = L2.merge(0, 1)
    assert merged.parents(L2) == [[0, 1], [2]]
    assert merged.unchanged_shards(L2) == {1: 2}


def test_layout_record_round_trip():
    L = ShardLayout.uniform([2, 6, 4]).split(1)
    rec = L.to_record()
    assert rec["kind"] == "range"
    L2 = ShardLayout.from_record(rec)
    assert L2 == L


# --------------------------------------------------------------------- #
# ShardedKVStore: vectorized routing + zero-copy split/merge            #
# --------------------------------------------------------------------- #
def test_store_split_merge_preserve_content_and_routing():
    store = ShardedKVStore(capacity=4096, block_rows=256, row_width=8,
                           seed=0, shards=2)
    before = store.read_all().copy()
    store.split(0)
    assert store.n_shards == 3 and store.layout.epoch == 1
    np.testing.assert_array_equal(store.read_all(), before)
    rows = np.array([0, 300, 1024, 2050, 4095], dtype=np.int64)
    vals = np.random.rand(5, 8).astype(np.float32)
    store.set(rows, vals)
    np.testing.assert_array_equal(store.get(rows), vals)  # rows sorted
    store.merge(1, 2)
    assert store.n_shards == 2
    np.testing.assert_array_equal(store.get(rows), vals)


def test_store_routing_is_searchsorted_grouping():
    """Vectorized _route groups per shard in one pass; unsorted batches
    round-trip, and non-uniform (post-split) layouts route correctly."""
    store = ShardedKVStore(capacity=4096, block_rows=256, row_width=8,
                           seed=0, shards=4)
    store.split(3)  # non-uniform: 4,4,4,2,2 blocks
    rng = np.random.default_rng(0)
    rows = rng.permutation(store.capacity)[:64]
    vals = rng.random((64, 8)).astype(np.float32)
    store.set(rows, vals)
    got = store.get(np.sort(rows))
    np.testing.assert_array_equal(got, vals[np.argsort(rows, kind="stable")])
    groups = list(store._route(rows))
    assert sum(len(local) for _, local, _ in groups) == 64
    for k, local, pos in groups:
        lo, hi = store._row_bounds[k], store._row_bounds[k + 1]
        np.testing.assert_array_equal(rows[pos] - lo, local)
        assert ((rows[pos] >= lo) & (rows[pos] < hi)).all()


def test_store_split_validates():
    store = ShardedKVStore(capacity=512, block_rows=256, row_width=8,
                           seed=0, shards=2)  # 1 block per shard
    with pytest.raises(ValueError):
        store.split(0)
    with pytest.raises(ValueError):
        store.merge(0, 2)


# --------------------------------------------------------------------- #
# reshard landing during an in-flight coordinated snapshot              #
# --------------------------------------------------------------------- #
def _engine(shards=2, capacity=2048, block_rows=128, **kw):
    store = ShardedKVStore(capacity=capacity, block_rows=block_rows,
                           row_width=8, seed=0, shards=shards)
    kw.setdefault("copier_duty", 1.0)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, **kw)
    store.warmup(batch=4)
    return store, eng


def _write(store, eng, row, val):
    store.set(np.array([row]), np.full((1, 8), val, np.float32),
              before_write=eng._write_hook, gate=eng._gate)


@pytest.mark.parametrize("op", ["split", "merge"])
def test_reshard_mid_snapshot_point_in_time_cut(tmp_path, op):
    """A split/merge between T0 and persist-done must not corrupt the cut:
    post-reshard writes route to the in-flight epochs through the retired
    layout, so the restored bytes equal the barrier-time state."""
    store, eng = _engine(shards=2, capacity=65536, copier_duty=0.02)
    t0 = store.read_all().copy()
    d = str(tmp_path / "snap")
    snap = eng.coordinator.bgsave_to_dir(d)
    if op == "split":
        eng.split(0)
    else:
        eng.merge(0, 1)
    assert eng.coordinator.layout.epoch == 1
    # hammer blocks AFTER the reshard, while the old-layout epoch may
    # still be copying: each write must proactively sync the retired group
    for row in range(0, store.capacity, 4 * store.block_rows):
        _write(store, eng, row, -1.0)
    assert snap.wait_persisted(120)
    restored = ShardedKVStore(capacity=65536, block_rows=128, row_width=8,
                              seed=9, shards=2)
    restored.load(d)
    np.testing.assert_array_equal(restored.read_all(), t0)
    # the live store reflects the writes
    live = store.read_all()
    assert (live[:: 4 * store.block_rows] == -1.0).all()


def test_reshard_blocks_only_for_one_gate_interval(tmp_path):
    """Acceptance: a split issued while a snapshot is in flight returns
    in O(metadata) — it never waits for the snapshot window to close."""
    store, eng = _engine(shards=2, capacity=65536, copier_duty=0.02)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "s"))
    t_split = time.perf_counter()
    eng.split(0)
    split_s = time.perf_counter() - t_split
    assert snap.wait_persisted(120)
    assert split_s < 1.0  # far below any real copy/persist window


def test_snapshot_during_and_after_reshard_independent_epochs(tmp_path):
    """Back-to-back: snapshot under L0, reshard, snapshot under L1 while
    L0's epoch may still persist — both restore their own barrier state."""
    store, eng = _engine(shards=2)
    t0 = store.read_all().copy()
    s0 = eng.coordinator.bgsave_to_dir(str(tmp_path / "s0"))
    eng.split(1)
    _write(store, eng, 5, 3.0)
    t1 = store.read_all().copy()
    s1 = eng.coordinator.bgsave_to_dir(str(tmp_path / "s1"))
    _write(store, eng, 5, 4.0)
    assert s0.wait_persisted(60) and s1.wait_persisted(60)
    for d, expect, shards in (("s0", t0, 2), ("s1", t1, 3)):
        st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                            seed=7, shards=2)
        st.load(str(tmp_path / d))
        np.testing.assert_array_equal(st.read_all(), expect)
        rec = read_snapshot_layout(str(tmp_path / d))
        assert ShardLayout.from_record(rec).n_shards == shards


def test_layout_swap_serializes_with_barrier():
    """No layout swap can land between two shards' T0 stamps: a writer
    thread resharding through the gate always sees bgsave's modes decided
    against exactly one layout."""
    store, eng = _engine(shards=2, capacity=4096, block_rows=128)
    coord = eng.coordinator
    stop = threading.Event()
    errors = []

    def resharder():
        k = 0
        while not stop.is_set():
            try:
                eng.split(0)
                eng.merge(0, 1)
                k += 1
            except BaseException as exc:  # pragma: no cover
                errors.append(exc)
                return

    th = threading.Thread(target=resharder)
    th.start()
    try:
        for _ in range(10):
            expected = None
            with coord.write_gate:
                expected = store.read_all().copy()
                snap = coord.bgsave()
            trees = snap.to_trees()
            got = np.concatenate([np.concatenate(
                [np.asarray(t["blocks"][i]) for i in range(len(t["blocks"]))])
                for t in trees])
            np.testing.assert_array_equal(got, expected)
    finally:
        stop.set()
        th.join()
    assert not errors


# --------------------------------------------------------------------- #
# cross-layout restore                                                  #
# --------------------------------------------------------------------- #
def test_restore_into_different_layout_round_trips(tmp_path):
    store, eng = _engine(shards=2)
    _write(store, eng, 100, 5.0)
    t0 = store.read_all().copy()
    d = str(tmp_path / "snap")
    assert eng.coordinator.bgsave_to_dir(d).wait_persisted(60)
    for shards in (1, 2, 4):
        st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                            seed=3, shards=shards)
        st.load(d)
        np.testing.assert_array_equal(st.read_all(), t0)
    # geometry mismatch fails loudly
    small = ShardedKVStore(capacity=1024, block_rows=128, row_width=8,
                           seed=3, shards=2)
    with pytest.raises(ValueError):
        small.load(d)


# --------------------------------------------------------------------- #
# BgsavePolicy                                                          #
# --------------------------------------------------------------------- #
def test_policy_decision_rule():
    pol = BgsavePolicy(delta_threshold=0.5, full_every=3, ema_alpha=1.0)
    v = ShardEpochView(writes_since_epoch=5, has_base=False)
    assert pol.decide(0, v) == "full"
    pol.observe(0, "full", 0.1)  # ema -> 0.1
    v = ShardEpochView(writes_since_epoch=5, has_base=True)
    assert pol.decide(0, v) == "delta"
    assert pol.decide(
        0, ShardEpochView(writes_since_epoch=0, has_base=True,
                          base_persisted=True)) == "skip"
    pol.observe(0, "delta", 0.9)  # ema over threshold
    assert pol.decide(0, v) == "full"
    pol.observe(0, "full", 0.0)
    pol.observe(0, "delta", 0.0)
    pol.observe(0, "delta", 0.0)
    # two deltas since the anchor; full_every=3 forces the anchor now
    assert pol.decide(0, v) == "full"


def test_policy_remap_follows_layout():
    pol = BgsavePolicy(ema_alpha=1.0)
    pol.observe(0, "delta", 0.2)
    pol.observe(1, "delta", 0.8)
    L = ShardLayout.uniform([4, 4])
    L2 = L.split(0)
    pol.remap(L2.parents(L), L2.unchanged_shards(L))
    # split children inherit shard 0's EMA; unchanged shard 1 keeps its own
    assert pol.state(0).dirty_ema == pytest.approx(0.2)
    assert pol.state(1).dirty_ema == pytest.approx(0.2)
    assert pol.state(2).dirty_ema == pytest.approx(0.8)


def test_policy_epoch_modes_and_zero_copy_skip(tmp_path):
    """Cold shard skips (zero-copy), warm shard goes delta, and every
    epoch restores its barrier state — including skips that reference a
    previous epoch's directory."""
    store, eng = _engine(shards=2, policy=BgsavePolicy(full_every=8,
                                                       delta_threshold=0.9))
    coord = eng.coordinator
    images, modes = [], []
    for i in range(4):
        if i:
            _write(store, eng, 5, float(i))  # only shard 0 dirties
        images.append(store.read_all().copy())
        snap = coord.bgsave_to_dir(str(tmp_path / f"e{i}"))
        assert snap.wait_persisted(60)
        modes.append(snap.modes)
    assert modes[0] == ["full", "full"]
    assert all(m == ["delta", "skip"] for m in modes[1:])
    for i in range(4):
        st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                            seed=3, shards=2)
        st.load(str(tmp_path / f"e{i}"))
        np.testing.assert_array_equal(st.read_all(), images[i])
    # the skipped shard persisted zero bytes after its anchor
    assert not os.path.exists(str(tmp_path / "e2" / "shard_1"))


def test_skip_without_recorded_dir_degrades_not_crashes(tmp_path):
    """A zero-write shard whose previous epoch was sink-less (no recorded
    directory) must not be skipped into a composite manifest — there is
    nothing to reference. The decision degrades to full and the epoch
    still restores (regression: relpath(None) crash)."""
    store, eng = _engine(shards=2, policy=BgsavePolicy())
    coord = eng.coordinator
    # sink-less epoch: retained bases exist, but _last_dirs stays empty
    coord.bgsave().wait_persisted(60)
    t0 = store.read_all().copy()
    d = str(tmp_path / "first_dir")
    snap = coord.bgsave_to_dir(d, parent="bogus_parent")
    assert snap.wait_persisted(60)
    assert all(m in ("full", "delta") for m in snap.modes)  # no skips
    st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                        seed=3, shards=2)
    st.load(d)
    np.testing.assert_array_equal(st.read_all(), t0)


def test_policy_dirty_estimate_counts_distinct_blocks():
    """200 writes to ONE hot block must read as ~1/n_blocks dirty, not
    100%: with a raw write counter a write-skewed shard's EMA pins at 1.0
    and it can never reach delta mode."""
    pol = BgsavePolicy(delta_threshold=0.4, ema_alpha=0.5)
    store, eng = _engine(shards=2, policy=pol)
    coord = eng.coordinator
    coord.bgsave().wait_persisted(60)   # anchor; ema -> 0.5
    for _ in range(200):
        _write(store, eng, 3, 1.0)      # one hot block on shard 0
    s2 = coord.bgsave()
    s2.wait_persisted(60)
    assert s2.modes[0] == "full"        # ema 0.5 still over threshold
    # 8 blocks/shard: the DISTINCT-touched estimate is 1/8, so the EMA
    # drops below the threshold (a raw counter would give min(1, 200/8)=1)
    assert pol.state(0).dirty_ema < 0.4
    _write(store, eng, 3, 2.0)
    s3 = coord.bgsave()
    s3.wait_persisted(60)
    assert s3.modes[0] == "delta"


def test_sinkless_epoch_invalidates_recorded_parent_dirs(tmp_path):
    """A sink-less bgsave advances the retained base past the last
    recorded directory; a later bgsave_to_dir must NOT chain (or skip)
    against the stale dir — it degrades to full and restores the true
    barrier state (regression: stale delta chains)."""
    store, eng = _engine(shards=2, policy=BgsavePolicy())
    coord = eng.coordinator
    coord.bgsave_to_dir(str(tmp_path / "a")).wait_persisted(60)
    _write(store, eng, 5, 9.0)              # dirty shard 0, then...
    coord.bgsave().wait_persisted(60)       # ...sink-less epoch: shard 0's
    t0 = store.read_all().copy()            # base moves PAST directory "a"
    snap = coord.bgsave_to_dir(str(tmp_path / "c"))
    assert snap.wait_persisted(60)
    # shard 0 must NOT delta against the stale dir "a" (its base is the
    # sink-less epoch); shard 1 never forked, so its skip against "a" is
    # still sound — that's the zero-copy contract, not staleness
    assert snap.modes == ["full", "skip"]
    st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                        seed=3, shards=2)
    st.load(str(tmp_path / "c"))
    np.testing.assert_array_equal(st.read_all(), t0)


def test_engine_load_invalidates_skip_proof(tmp_path):
    """Restoring a checkpoint rebinds blocks without before_write; the
    next epoch must not skip against the pre-load image (regression:
    false zero-copy certification after load)."""
    store, eng = _engine(shards=2, policy=BgsavePolicy())
    coord = eng.coordinator
    t_a = store.read_all().copy()
    sa = coord.bgsave_to_dir(str(tmp_path / "a"))
    assert sa.wait_persisted(60) and sa.wait(60)
    _write(store, eng, 5, 9.0)
    sb = coord.bgsave_to_dir(str(tmp_path / "b"))
    assert sb.wait_persisted(60) and sb.wait(60)
    eng.load(str(tmp_path / "a"))           # back to image A, no writes seen
    np.testing.assert_array_equal(store.read_all(), t_a)
    snap = coord.bgsave_to_dir(str(tmp_path / "c"))
    assert snap.wait_persisted(60)
    assert snap.modes == ["full", "full"]   # bases invalidated, no skips
    st = ShardedKVStore(capacity=2048, block_rows=128, row_width=8,
                        seed=3, shards=2)
    st.load(str(tmp_path / "c"))
    np.testing.assert_array_equal(st.read_all(), t_a)


def test_engine_load_refuses_in_flight_epochs(tmp_path):
    """load() while a copy window is open would mix pre- and post-load
    bytes into the epoch's cut — it must refuse, not corrupt."""
    store, eng = _engine(shards=2, capacity=65536, copier_duty=0.02)
    coord = eng.coordinator
    sa = coord.bgsave_to_dir(str(tmp_path / "a"))
    assert sa.wait_persisted(120) and sa.wait(120)
    snap = coord.bgsave_to_dir(str(tmp_path / "b"))  # full: long copy window
    if coord.has_active_epochs():  # all but guaranteed at duty=0.02
        with pytest.raises(RuntimeError):
            eng.load(str(tmp_path / "a"))
    assert snap.wait_persisted(120) and snap.wait(120)
    eng.load(str(tmp_path / "a"))  # quiesced: fine


def test_skip_vetoed_for_durable_caller_sinks(tmp_path):
    """Plain bgsave with caller FileSinks must not skip a zero-write
    shard — nothing would record where its data lives. NullSinks (pure
    pacing) still allow zero-copy skips."""
    from repro.core import FileSink, NullSink

    store, eng = _engine(shards=2, policy=BgsavePolicy())
    coord = eng.coordinator
    coord.bgsave().wait_persisted(60)  # anchor: retained bases exist
    snap = coord.bgsave(sinks=[
        FileSink(str(tmp_path / "s0")), FileSink(str(tmp_path / "s1"))
    ])
    assert snap.wait_persisted(60)
    assert snap.modes == ["full", "full"]  # durable sinks: no skip/delta
    for k in range(2):
        assert os.path.exists(str(tmp_path / f"s{k}" / "manifest.json"))
    snap2 = coord.bgsave(sinks=[NullSink(), NullSink()])
    assert snap2.wait_persisted(60)
    assert snap2.modes == ["skip", "skip"]  # pacing sinks lose nothing
    # a policy DELTA into a bare caller sink would restore zero-filled
    # holes (no parent reference) — it degrades to full the same way
    _write(store, eng, 5, 1.0)
    snap3 = coord.bgsave(sinks=[
        FileSink(str(tmp_path / "t0")), FileSink(str(tmp_path / "t1"))
    ])
    assert snap3.wait_persisted(60)
    assert snap3.modes == ["full", "full"]
    restored = read_file_snapshot(str(tmp_path / "t0"))
    got = np.concatenate([restored[f"blocks/{b}"]
                          for b in range(len(restored))])
    np.testing.assert_array_equal(got, store.shards[0].read_all())


def test_parentless_delta_manifest_raises_on_restore(tmp_path):
    """Restore-side backstop: a delta manifest naming no parent cannot
    resolve its holes — fail loudly instead of returning zero-filled
    blocks."""
    from repro.core import FileSink

    state = {"kv": jnp.ones((64, 8), jnp.float32)}
    prov = PyTreeProvider(state)
    sn = make_snapshotter("asyncfork", prov, block_bytes=8 * 8 * 4,
                          copier_threads=1, retain_images=True)
    sn.fork().wait_persisted(30)
    sn.before_write(0, [0])
    prov.update_leaf(0, prov.leaf(0).at[0].set(2.0), delete_old=True)
    snap = sn.fork(FileSink(str(tmp_path / "d")), incremental=True)
    assert snap.wait_persisted(30)
    assert snap.metrics.inherited_blocks > 0  # real holes in the manifest
    with pytest.raises(ValueError, match="names no parent"):
        read_file_snapshot(str(tmp_path / "d"))


def test_run_actions_with_equal_fractions():
    store, eng = _engine(shards=2, capacity=4096)
    wl = Workload(rate_qps=200, set_ratio=0.5, batch=8, seed=0)
    fired = []
    rep = eng.run(wl, duration_s=0.4, bgsave_at=(0.9,),
                  actions=[(0.2, lambda: fired.append("a")),
                           (0.2, lambda: fired.append("b"))])
    assert sorted(fired) == ["a", "b"]
    assert rep.duration_s > 0


def test_aggregate_metrics_tolerates_skipped_shards():
    """Roll-ups must not KeyError on shards that skipped the epoch: their
    per-shard record is a minimal zero-copy dict."""
    state = {"kv": jnp.ones((64, 8), jnp.float32)}
    prov = PyTreeProvider(state)
    sn = make_snapshotter("blocking", prov, block_bytes=512)
    part = sn.fork()
    part.wait_persisted(10)
    m = AggregateMetrics([part, None], modes=["full", "skip"])
    s = m.summary()
    assert s["shards"] == 2.0 and s["skipped_shards"] == 1.0
    assert s["per_shard"][1] == {"mode": "skip", "zero_copy_epoch": 1.0}
    assert s["per_shard"][0]["mode"] == "full"
    assert m.histogram_us() == {}
    # all-skipped epoch: every quantity degrades to zero, not a crash
    empty = AggregateMetrics([None, None], modes=["skip", "skip"])
    s = empty.summary()
    assert s["fork_ms"] == 0.0 and s["skipped_shards"] == 2.0


def test_engine_report_merges_heterogeneous_snapshot_summaries():
    store, eng = _engine(shards=2, policy=BgsavePolicy())
    wl = Workload(rate_qps=300, set_ratio=0.2, batch=8, seed=0)
    rep = eng.run(wl, duration_s=0.5, bgsave_at=(0.2, 0.6, 0.9))
    s = rep.summary()  # must not KeyError even if epochs skipped shards
    assert s["shards"] == 2.0
    assert s["skipped_shards"] >= 0.0


# --------------------------------------------------------------------- #
# engine-level acceptance: split under load, mid-snapshot               #
# --------------------------------------------------------------------- #
def test_engine_split_under_load_mid_snapshot(tmp_path):
    store, eng = _engine(shards=2, capacity=4096, copier_duty=0.05)
    wl = Workload(rate_qps=400, set_ratio=1.0, batch=8, seed=1)
    rep = eng.run(wl, duration_s=1.5, bgsave_at=(0.2,),
                  actions=[(0.25, lambda: eng.split(0))])
    assert eng.n_shards == 3 and store.layout.epoch == 1
    s = rep.summary()
    assert s["shards"] == 3.0
    assert rep.snapshot_metrics  # the snapshot completed
    # queries continued across the reshard: events span the whole run
    assert rep.normal_lat.size + rep.snapshot_lat.size > 50


# --------------------------------------------------------------------- #
# run-aware proactive sync (satellite)                                  #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["host", "device"])
def test_before_write_stages_contiguous_runs(backend):
    """A batched write spanning many contiguous blocks syncs them as runs
    (one interruption covering the whole touched set, every touched block
    parent-copied) and the snapshot stays byte-identical to T0. Uses a
    prepared-but-uncommitted epoch so no copier races the assertion."""
    state = {"kv": jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)}
    prov = PyTreeProvider(state)
    t0 = np.asarray(prov.leaf(0)).copy()
    sn = make_snapshotter("asyncfork", prov, block_bytes=8 * 16 * 4,
                          copier_threads=1, backend=backend)
    snap = sn.fork_prepare()
    # rows covering blocks 0..3 (one contiguous run) and 6 (a gap)
    rows = list(range(0, 32)) + list(range(48, 56))
    sn.before_write(0, rows)
    assert snap.metrics.copied_blocks_parent == 5
    assert snap.metrics.n_interruptions == 1
    old = prov.leaf(0)
    prov.update_leaf(0, old.at[np.asarray(rows)].set(-1.0), delete_old=True)
    snap.finish()
    tree = snap.to_tree()
    np.testing.assert_array_equal(np.asarray(tree["kv"]), t0)


def test_complete_leaf_uses_runs():
    state = {"kv": jnp.ones((80, 8), jnp.float32)}
    prov = PyTreeProvider(state)
    sn = make_snapshotter("asyncfork", prov, block_bytes=8 * 8 * 4,
                          copier_threads=1)
    snap = sn.fork_prepare()
    copied = snap.complete_leaf(0)
    assert copied == snap.table.n_blocks
    assert snap.table.leaf_done(0)
    assert snap.metrics.n_interruptions == 1  # one coalesced sync
    snap.finish()


# --------------------------------------------------------------------- #
# property test: reshard during snapshot == quiesced cut (hypothesis)   #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def reshard_script(draw):
        n_shards = draw(st.integers(2, 3))
        n_updates = draw(st.integers(0, 8))
        updates = [
            (draw(st.integers(0, 255)),
             draw(st.floats(-100, 100, allow_nan=False, width=32)))
            for _ in range(n_updates)
        ]
        fork_at = draw(st.integers(0, n_updates))
        reshard_after = draw(st.integers(fork_at, n_updates))
        op = draw(st.sampled_from(["split", "merge"]))
        shard = draw(st.integers(0, n_shards - 1))
        return n_shards, updates, fork_at, reshard_after, op, shard

    @settings(max_examples=20, deadline=None)
    @given(script=reshard_script())
    def test_property_reshard_mid_snapshot_equals_quiesced_cut(
        script, tmp_path_factory
    ):
        """For ANY interleaving of writes with a reshard landing during an
        in-flight coordinated snapshot, the persisted cut equals the exact
        barrier state (what a quiesced snapshot would have written), and a
        restore into the post-reshard layout round-trips."""
        n_shards, updates, fork_at, reshard_after, op, shard = script
        store = ShardedKVStore(capacity=256, block_rows=32, row_width=4,
                               seed=0, shards=n_shards)
        eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                       persist_bandwidth=None, copier_duty=0.05)
        store.warmup(batch=2)

        def apply(row, val):
            store.set(np.array([row % store.capacity]),
                      np.full((1, 4), val, np.float32),
                      before_write=eng._write_hook, gate=eng._gate)

        for row, val in updates[:fork_at]:
            apply(row, val)
        expected = store.read_all().copy()  # the quiesced cut
        d = str(tmp_path_factory.mktemp("reshard") / "snap")
        snap = eng.coordinator.bgsave_to_dir(d)
        for i, (row, val) in enumerate(updates[fork_at:]):
            if i == reshard_after - fork_at:
                _do_reshard(eng, op, shard)
            apply(row, val)
        if reshard_after >= len(updates):
            _do_reshard(eng, op, shard)
        assert snap.wait_persisted(120) and snap.wait(120)
        # restore across the layout change round-trips: into the live
        # post-reshard store (non-uniform layout) and a fresh uniform one
        store.load(d)
        np.testing.assert_array_equal(store.read_all(), expected)
        fresh = ShardedKVStore(capacity=store.capacity, block_rows=32,
                               row_width=4, seed=5, shards=1)
        fresh.load(d)
        np.testing.assert_array_equal(fresh.read_all(), expected)

    def _do_reshard(eng, op, shard):
        try:
            if op == "split":
                eng.split(min(shard, eng.n_shards - 1))
            else:
                k = min(shard, eng.n_shards - 2)
                if k >= 0:
                    eng.merge(k, k + 1)
        except ValueError:
            pass  # unsplittable single-block shard / nothing to merge
