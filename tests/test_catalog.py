"""Snapshot reads as a product: the epoch catalog, GetAt(epoch) reads,
zero-copy writable branches, and the delta-chain compactor (ISSUE 7
acceptance criteria)."""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import (
    BgsavePolicy,
    ChainCompactor,
    CompactionPolicy,
    SnapshotCatalog,
    read_file_snapshot,
    snapshot_chain_depth,
)
from repro.kvstore import (
    CowKVStore,
    GetAtRequest,
    KVEngine,
    RequestServer,
    ShardedKVStore,
)


def _engine(capacity=512, block_rows=64, row_width=4, shards=2, seed=0,
            policy=None, **kw):
    store = ShardedKVStore(capacity=capacity, block_rows=block_rows,
                           row_width=row_width, seed=seed, shards=shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=0.5, policy=policy,
                   **kw)
    store.warmup(batch=2)
    return store, eng


def _set(store, eng, rows, val):
    rows = np.asarray(rows, dtype=np.int64)
    store.set(rows, np.full((rows.size, store.row_width), val, np.float32),
              before_write=eng._write_hook, gate=eng._gate)


_DELTA_POLICY = dict(delta_threshold=2.0, full_every=99)  # force deltas


# --------------------------------------------------------------------- #
# catalog refcounts + GC                                                #
# --------------------------------------------------------------------- #
def test_catalog_refcounts_skip_aliases_and_drop_cascade(tmp_path):
    """A skip epoch refcounts the aliased dir instead of copying it; the
    dir (and the delta ancestors under it) survive until the LAST epoch
    holding them drops, then the cascade GC removes them from disk."""
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    snaps = []
    for e in range(3):
        if e:
            _set(store, eng, np.arange(0, 64, 3), float(e))  # shard 0 only
        snap = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert snap.wait_persisted(120)
        snaps.append(snap)
    assert snaps[1].modes[1] == "skip" and snaps[2].modes[1] == "skip"
    aliased = snaps[0].directory and os.path.join(str(tmp_path / "ep0"))
    s1_dir = json.load(open(tmp_path / "ep1" / "manifest.json"))
    alias_entry = s1_dir["shards"][1]
    assert alias_entry["mode"] == "skip" and alias_entry.get("aliased")
    assert alias_entry["refs"]  # explicit alias ref record
    alias_target = alias_entry["dir"]
    if not os.path.isabs(alias_target):
        alias_target = os.path.normpath(
            os.path.join(str(tmp_path / "ep1"), alias_target))
    # held by ep0 (its own entry) + ep1 + ep2 (skip aliases)
    assert cat.refcount(alias_target) == 3
    # dropping the aliasing epochs reclaims THEIR delta dirs but never
    # the alias target (ep0 still holds it)
    removed = cat.drop_epoch(snaps[2].epoch_id)
    assert os.path.realpath(alias_target) not in removed
    assert os.path.exists(alias_target)
    cat.drop_epoch(snaps[1].epoch_id)
    assert os.path.exists(alias_target)
    removed = cat.drop_epoch(snaps[0].epoch_id)
    assert any(os.path.realpath(alias_target) == p for p in removed)
    assert not os.path.exists(alias_target)
    # composite dirs are reaped once their last shard dir is gone
    assert sorted(os.listdir(tmp_path)) == []


def test_catalog_delta_parent_refs_pin_ancestors(tmp_path):
    """A delta child holds a ref on its parent dir: dropping the parent
    EPOCH leaves the parent DIR on disk until the child drops too."""
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    s0 = coord.bgsave_to_dir(str(tmp_path / "ep0"))
    assert s0.wait_persisted(120)
    _set(store, eng, np.arange(0, 512, 9), 1.0)  # dirty both shards
    s1 = coord.bgsave_to_dir(str(tmp_path / "ep1"))
    assert s1.wait_persisted(120)
    assert s1.modes == ["delta", "delta"]
    man = json.load(open(tmp_path / "ep1" / "manifest.json"))
    for entry in man["shards"]:
        assert entry["mode"] == "delta"
        assert entry["chain_depth"] == 1
        assert entry["refs"]  # names the parent dir explicitly
    assert cat.drop_epoch(s0.epoch_id) == []  # children still hold refs
    assert os.path.exists(tmp_path / "ep0")
    removed = cat.drop_epoch(s1.epoch_id)
    assert removed  # the cascade now reclaims both generations
    assert not os.path.exists(tmp_path / "ep0")
    assert not os.path.exists(tmp_path / "ep1")


# --------------------------------------------------------------------- #
# read_file_snapshot hard guards (satellite)                            #
# --------------------------------------------------------------------- #
def _mini_dir(d, parent=None, full=True):
    os.makedirs(d, exist_ok=True)
    np.arange(8, dtype=np.float32).tofile(os.path.join(d, "leaf.bin"))
    leaf = {"path": "kv", "file": "leaf.bin", "shape": [8],
            "dtype": "float32", "blocks": [[0, 8, 32]],
            "carried": [0] if full else []}
    man = {"leaves": [leaf]}
    if parent is not None:
        man["parent"] = parent
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(man, f)
    return d


def test_read_guard_cyclic_chain(tmp_path):
    a = _mini_dir(str(tmp_path / "a"), parent="b", full=False)
    _mini_dir(str(tmp_path / "b"), parent="a", full=False)
    with pytest.raises(ValueError, match="cyclic"):
        read_file_snapshot(a)
    with pytest.raises(ValueError, match="cyclic"):
        snapshot_chain_depth(a)


def test_read_guard_missing_parent(tmp_path):
    a = _mini_dir(str(tmp_path / "a"), parent="gone", full=False)
    with pytest.raises(ValueError, match="missing"):
        read_file_snapshot(a)
    with pytest.raises(ValueError, match="missing snapshot manifest"):
        snapshot_chain_depth(a)


def test_read_guard_max_depth(tmp_path):
    _mini_dir(str(tmp_path / "d0"))
    for i in range(1, 6):
        _mini_dir(str(tmp_path / f"d{i}"), parent=f"d{i-1}", full=False)
    tip = str(tmp_path / "d5")
    assert snapshot_chain_depth(tip) == 5
    flat = read_file_snapshot(tip)  # default bound is generous
    np.testing.assert_array_equal(flat["kv"],
                                  np.arange(8, dtype=np.float32))
    with pytest.raises(ValueError, match="max_depth"):
        read_file_snapshot(tip, max_depth=3)
    with pytest.raises(ValueError, match="max_depth"):
        snapshot_chain_depth(tip, max_depth=3)


# --------------------------------------------------------------------- #
# retained-base lifecycle (satellite)                                   #
# --------------------------------------------------------------------- #
def test_retained_base_across_skips_drop_and_reshard(tmp_path):
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord = eng.coordinator
    s0 = coord.bgsave_to_dir(str(tmp_path / "ep0"))
    assert s0.wait_persisted(120)
    base1 = coord.snapshotters[1].retained_base()
    assert base1 is not None
    # two consecutive skip epochs: the retained base is the SAME handle
    for e in (1, 2):
        _set(store, eng, np.arange(0, 32, 3), float(e))  # shard 0 only
        sn = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert sn.wait_persisted(120)
        assert sn.modes[1] == "skip"
        assert coord.snapshotters[1].retained_base() is base1
    # dropping the retained base degrades the next epoch to full
    coord.snapshotters[1].drop_retained()
    assert coord.snapshotters[1].retained_base() is None
    s3 = coord.bgsave_to_dir(str(tmp_path / "ep3"))
    assert s3.wait_persisted(120)
    assert s3.modes[1] == "full"
    assert coord.snapshotters[1].retained_base() is not None
    # post-reshard: the split children lose their base (fresh
    # snapshotters), the unchanged shard keeps its retained handle
    keep = coord.snapshotters[1].retained_base()
    eng.split(0)
    assert coord.snapshotters[0].retained_base() is None
    assert coord.snapshotters[1].retained_base() is None
    assert coord.snapshotters[2].retained_base() is keep
    s4 = coord.bgsave_to_dir(str(tmp_path / "ep4"))
    assert s4.wait_persisted(120)
    assert s4.modes[0] == "full" and s4.modes[1] == "full"
    assert s4.modes[2] == "skip"


# --------------------------------------------------------------------- #
# GetAt(epoch): live, evicted, and through the RequestServer            #
# --------------------------------------------------------------------- #
def test_get_at_exact_cut_live_and_from_disk(tmp_path):
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    rows = np.arange(0, 512, 7)
    snaps, truth = [], []
    for e in range(3):
        if e:
            _set(store, eng, np.arange(0, 512, 11), float(e))
        truth.append(store.get_concurrent(rows).copy())
        snap = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert snap.wait_persisted(120)
        snaps.append(snap)
    for e, snap in enumerate(snaps):  # zero-copy in-memory path
        np.testing.assert_array_equal(eng.get_at(rows, snap.epoch_id),
                                      truth[e])
    for snap in snaps:
        cat.evict_live(snap.epoch_id)
    for e, snap in enumerate(snaps):  # memmapped manifest-chain path
        np.testing.assert_array_equal(eng.get_at(rows, snap.epoch_id),
                                      truth[e])


def test_get_at_pinned_ref_and_errors(tmp_path):
    store, eng = _engine()
    rows = np.arange(0, 512, 13)
    before = store.get_concurrent(rows).copy()
    snap = eng.bgsave()
    assert snap.wait_persisted(120)
    ref = eng.catalog.pin(snap.epoch_id)
    _set(store, eng, rows, 42.0)
    np.testing.assert_array_equal(eng.get_at(rows, ref), before)
    ref.release()
    with pytest.raises(ValueError, match="released"):
        ref.shard_blocks(0)
    with pytest.raises(ValueError, match="unknown or dropped"):
        eng.get_at(rows, 999)
    # a NullSink epoch that was evicted has nowhere to read from
    eng.catalog.evict_live(snap.epoch_id)
    with pytest.raises(ValueError, match="neither"):
        eng.get_at(rows, snap.epoch_id)


def test_get_at_flows_through_request_server():
    store, eng = _engine()
    rows = np.arange(0, 512, 7)
    before = store.get_concurrent(rows).copy()
    snap = eng.bgsave()
    assert snap.wait_persisted(120)
    with RequestServer(eng, readers=3) as srv:
        _set(store, eng, rows, 5.0)
        msgs = [srv.submit(GetAtRequest(rows, snap.epoch_id))
                for _ in range(4)]
        live = srv.get(rows)
        for m in msgs:
            r = m.wait(timeout=60)
            assert r.error is None
            np.testing.assert_array_equal(r.value, before)
        np.testing.assert_array_equal(live, np.full_like(live, 5.0))
        stats = srv.stats()
        assert stats["get_ats"] == 4.0 and stats["gets"] == 1.0


# --------------------------------------------------------------------- #
# writable branches (COW)                                               #
# --------------------------------------------------------------------- #
def test_branch_diverges_without_perturbing_parent():
    store, eng = _engine()
    rows = np.arange(0, 512, 7)
    cut = store.get_concurrent(rows).copy()
    snap = eng.bgsave()
    assert snap.wait_persisted(120)
    child = eng.branch(snap.epoch_id)
    assert isinstance(child.store.shards[0], CowKVStore)
    assert child.branch_ref is not None and not child.branch_ref.released
    # the branch starts at the epoch's cut, not the parent's live state
    _set(store, eng, rows, 7.0)
    np.testing.assert_array_equal(child.store.get_concurrent(rows), cut)
    # branch writes: COW faults materialize only touched blocks ...
    _set(child.store, child, rows[:8], -3.0)
    assert sum(s.cow_faults for s in child.store.shards) >= 1
    got = child.store.get_concurrent(rows[:8])
    np.testing.assert_array_equal(got, np.full_like(got, -3.0))
    # ... and neither the parent's live state nor the epoch's image moves
    live = store.get_concurrent(rows)
    np.testing.assert_array_equal(live, np.full_like(live, 7.0))
    np.testing.assert_array_equal(eng.get_at(rows, snap.epoch_id), cut)
    # the branch can snapshot itself, into the SHARED catalog
    bsnap = child.bgsave()
    assert bsnap.wait_persisted(120)
    assert child.catalog is eng.catalog
    assert bsnap.epoch_id in eng.catalog.epochs()
    child.branch_ref.release()


def test_branch_pin_blocks_gc_until_released(tmp_path):
    store, eng = _engine()
    cat = eng.catalog
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    assert snap.wait_persisted(120)
    child = eng.branch(snap.epoch_id)
    assert cat.drop_epoch(snap.epoch_id) == []  # pinned: nothing removed
    assert os.path.exists(tmp_path / "ep0")
    rows = np.arange(0, 512, 17)
    cut = child.store.get_concurrent(rows).copy()  # still readable
    child.branch_ref.release()  # last pin: release cascades now
    assert not os.path.exists(tmp_path / "ep0")
    np.testing.assert_array_equal(child.store.get_concurrent(rows), cut)


# --------------------------------------------------------------------- #
# chain compactor                                                       #
# --------------------------------------------------------------------- #
def test_compactor_folds_deep_chain_and_frees_ancestors(tmp_path):
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    rows = np.arange(0, 512, 5)
    snaps, truth = [], []
    for e in range(5):
        if e:
            _set(store, eng, np.arange(0, 64, 3), float(e))  # shard 0 only
        truth.append(store.get_concurrent(rows).copy())
        snap = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert snap.wait_persisted(120)
        snaps.append(snap)
    tip = snaps[-1]
    assert max(tip.chain_depths) == 4
    assert tip.metrics.summary()["chain_depth_max"] == 4.0
    assert tip.metrics.summary()["aliased_dirs"] >= 1.0
    comp = ChainCompactor(cat, CompactionPolicy(max_chain=2))
    assert comp.scan_once()  # folded at least one dir
    sdir = cat._records[tip.epoch_id].shard_dirs[0]
    assert cat.dir_depth(sdir) == 0
    man = json.load(open(os.path.join(sdir, "manifest.json")))
    assert man.get("compacted") and "parent" not in man
    # every epoch still reads its exact cut through the folded chain
    for e, snap in enumerate(snaps):
        cat.evict_live(snap.epoch_id)
        np.testing.assert_array_equal(eng.get_at(rows, snap.epoch_id),
                                      truth[e])
    # drop everything: the compacted dirs' parent refs are gone, so the
    # cascade reclaims the whole tree
    for snap in snaps:
        cat.drop_epoch(snap.epoch_id)
    assert sorted(os.listdir(tmp_path)) == []


def test_compactor_background_thread(tmp_path):
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    comp = ChainCompactor(cat, CompactionPolicy(max_chain=1,
                                                interval_s=0.01))
    comp.start()
    try:
        for e in range(4):
            if e:
                _set(store, eng, np.arange(0, 64, 3), float(e))
            snap = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
            assert snap.wait_persisted(120)
        deadline = 50
        while cat.deep_dirs(1) and deadline:
            threading.Event().wait(0.05)
            deadline -= 1
    finally:
        comp.stop()
    assert not cat.deep_dirs(1)
    assert comp.compacted


# --------------------------------------------------------------------- #
# engine report plumbing                                                #
# --------------------------------------------------------------------- #
def test_engine_report_surfaces_chain_depth_and_aliases(tmp_path):
    from repro.kvstore import Workload
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord = eng.coordinator
    s0 = coord.bgsave_to_dir(str(tmp_path / "ep0"))
    assert s0.wait_persisted(120)
    _set(store, eng, np.arange(0, 64, 3), 1.0)
    s1 = coord.bgsave_to_dir(str(tmp_path / "ep1"))
    assert s1.wait_persisted(120)
    summ = s1.metrics.summary()
    assert summ["chain_depth_max"] == 1.0 and summ["aliased_dirs"] == 1.0
    assert summ["per_shard"][0]["chain_depth"] == 1.0
    eng._snaps = [s0, s1]
    wl = Workload(rate_qps=300.0, batch=2, set_ratio=0.5, seed=1)
    rep = eng.run(wl, duration_s=0.2, bgsave_at=())
    rep.snapshot_metrics = [s.metrics.summary() for s in (s0, s1)]
    roll = rep.summary()
    assert roll["chain_depth_max"] == 1.0 and roll["aliased_dirs"] == 1.0


# --------------------------------------------------------------------- #
# property-style end-to-end (the PR's acceptance scenario)              #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs the test extra
    HAVE_HYPOTHESIS = False


def _epoch_product_scenario(tmp_path, write_rows, write_vals):
    """>=3 epochs under live writes (one skip epoch, one mid-stream
    reshard), exact GetAt cuts through the RequestServer for every epoch,
    a branch diverging without perturbing its parent, and the compactor
    folding the delta chain + GC'ing the aliased dir at the last drop."""
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    coord, cat = eng.coordinator, eng.catalog
    probe = np.arange(0, 512, 7)
    snaps, truth = [], []

    def epoch(e):
        truth.append(store.get_concurrent(probe).copy())
        snap = coord.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert snap.wait_persisted(120)
        snaps.append(snap)

    epoch(0)                                    # full
    _set(store, eng, write_rows[0], write_vals[0])   # shard 0 rows only
    epoch(1)                                    # delta + SKIP on shard 1
    assert "skip" in snaps[1].modes
    eng.split(0)                                # mid-stream reshard
    _set(store, eng, write_rows[1], write_vals[1])
    epoch(2)                                    # post-reshard epoch
    _set(store, eng, write_rows[2], write_vals[2])
    epoch(3)
    assert snaps[2].layout.n_shards == 3

    with RequestServer(eng, readers=2) as srv:
        for e, snap in enumerate(snaps):        # exact point-in-time cuts
            np.testing.assert_array_equal(
                srv.get_at(probe, snap.epoch_id), truth[e])

    child = eng.branch(snaps[1].epoch_id)       # fork at the skip epoch
    np.testing.assert_array_equal(child.store.get_concurrent(probe),
                                  truth[1])
    _set(child.store, child, probe[:6], -9.0)
    _set(store, eng, probe[:6], 77.0)           # parent writes after fork
    got = child.store.get_concurrent(probe[:6])
    np.testing.assert_array_equal(got, np.full_like(got, -9.0))
    np.testing.assert_array_equal(eng.get_at(probe, snaps[1].epoch_id),
                                  truth[1])     # image never moves

    # deepen shard 0's chain past max_chain, then fold it
    for e in range(4, 7):
        _set(store, eng, write_rows[0][:4], float(e))
        epoch(e)
    comp = ChainCompactor(cat, CompactionPolicy(max_chain=2))
    comp.scan_once()
    assert not cat.deep_dirs(2)
    for e, snap in enumerate(snaps):            # folds preserve every cut
        cat.evict_live(snap.epoch_id)
        np.testing.assert_array_equal(eng.get_at(probe, snap.epoch_id),
                                      truth[e])
    # the aliased dir survives the aliasing epochs' drops, then GC's
    alias = cat._records[snaps[1].epoch_id].shard_dirs[-1]
    child.branch_ref.release()
    for snap in snaps:
        cat.drop_epoch(snap.epoch_id)
    assert not os.path.exists(alias)
    assert sorted(os.listdir(tmp_path)) == []


def test_epoch_product_end_to_end(tmp_path):
    rng = np.random.default_rng(0)
    write_rows = [np.sort(rng.choice(64, size=9, replace=False)),
                  np.sort(rng.choice(512, size=12, replace=False)),
                  np.sort(rng.choice(512, size=12, replace=False))]
    _epoch_product_scenario(tmp_path, write_rows, [1.0, 2.0, 3.0])


if HAVE_HYPOTHESIS:

    @st.composite
    def epoch_writes(draw):
        rows0 = draw(st.lists(st.integers(0, 63), min_size=1, max_size=8,
                              unique=True))
        rows1 = draw(st.lists(st.integers(0, 511), min_size=1, max_size=8,
                              unique=True))
        rows2 = draw(st.lists(st.integers(0, 511), min_size=1, max_size=8,
                              unique=True))
        vals = [draw(st.floats(-50, 50, allow_nan=False, width=32))
                for _ in range(3)]
        return ([np.array(sorted(rows0)), np.array(sorted(rows1)),
                 np.array(sorted(rows2))], vals)

    @settings(max_examples=5, deadline=None)
    @given(script=epoch_writes())
    def test_property_epoch_product(script, tmp_path_factory):
        write_rows, write_vals = script
        tmp = tmp_path_factory.mktemp("epochs")
        _epoch_product_scenario(tmp, write_rows, write_vals)
