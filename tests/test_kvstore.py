"""KV store + engine tests (the paper's parent process)."""
import numpy as np
import pytest

from repro.core import FileSink, read_file_snapshot
from repro.kvstore import KVEngine, KVStore, Workload


def test_set_get_round_trip():
    store = KVStore(capacity=4096, row_width=8, block_rows=256, seed=0)
    rows = np.array([0, 5, 300, 4095], dtype=np.int64)
    vals = np.random.rand(4, 8).astype(np.float32)
    store.set(rows, vals)
    got = store.get(rows)
    order = np.argsort(rows)  # get() returns block-grouped order
    np.testing.assert_allclose(got, vals[order], rtol=0, atol=0)


def test_set_donates_only_touched_block():
    store = KVStore(capacity=1024, block_rows=256, row_width=8)
    untouched_before = store.provider.leaf(3)
    store.set(np.array([0, 1]), np.zeros((2, 8), np.float32))
    assert store.provider.leaf(3) is untouched_before  # other blocks alive


def test_before_write_hook_called_per_block_with_rows():
    """The hook gets (leaf_id, leaf-local rows) so multi-block leaves sync
    row→block-precise instead of whole-leaf (DESIGN §2)."""
    store = KVStore(capacity=1024, block_rows=256, row_width=8)
    seen = []
    store.set(
        np.array([0, 256, 700]),
        np.zeros((3, 8), np.float32),
        before_write=lambda leaf_id, rows: seen.append((leaf_id, rows.tolist())),
    )
    assert seen == [(0, [0]), (1, [0]), (2, [188])]


def test_capacity_rounds_to_block_multiple():
    store = KVStore(capacity=1000, block_rows=256, row_width=8)
    assert store.capacity == 1024 and store.n_blocks == 4


def test_workload_event_stream_reproducible():
    wl = Workload(rate_qps=500, set_ratio=0.5, batch=8, seed=3)
    a = wl.events(4096, 0.5)
    b = wl.events(4096, 0.5)
    assert len(a) == len(b) > 0
    assert all(x.t == y.t and x.op == y.op and np.array_equal(x.rows, y.rows)
               for x, y in zip(a, b))
    assert {e.op for e in a} == {"set", "get"}


@pytest.mark.parametrize("pattern", ["uniform", "gaussian", "zipf"])
def test_workload_patterns_in_range(pattern):
    wl = Workload(rate_qps=500, pattern=pattern, batch=8, seed=1)
    for ev in wl.events(4096, 0.2):
        assert ev.rows.min() >= 0 and ev.rows.max() < 4096


@pytest.mark.parametrize("mode", ["blocking", "cow", "asyncfork"])
def test_engine_snapshot_consistency_end_to_end(mode, tmp_path):
    """BGSAVE during live traffic -> persisted file equals T0 state."""
    store = KVStore(capacity=2048, block_rows=256, row_width=16, seed=0)
    eng = KVEngine(store, mode=mode, copier_threads=2,
                   persist_bandwidth=None, copier_duty=1.0)
    store.warmup(batch=8)
    t0 = store.read_all().copy()
    sink = FileSink(str(tmp_path / mode))
    snap = eng.bgsave(sink)
    # hammer the store while the snapshot is in flight
    wl = Workload(rate_qps=1e9, set_ratio=1.0, batch=8, seed=2)
    vals = np.random.rand(8, 16).astype(np.float32)
    for ev in wl.events(store.capacity, 1e-4)[:50]:
        store.set(ev.rows, vals, before_write=eng._write_hook)
    assert snap.wait_persisted(30)
    restored = read_file_snapshot(str(tmp_path / mode))
    # leaf paths are blocks/<i>
    got = np.concatenate([restored[f"blocks/{b}"] for b in range(store.n_blocks)])
    np.testing.assert_array_equal(got, t0)
    assert store.read_all().shape == t0.shape  # engine alive and well


def test_engine_report_metrics_present():
    store = KVStore(capacity=2048, block_rows=256, row_width=16)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=1.0)
    wl = Workload(rate_qps=300, set_ratio=0.5, batch=8, seed=0)
    rep = eng.run(wl, duration_s=0.5, bgsave_at=(0.3,))
    s = rep.summary()
    for k in ("snap_p99_ms", "snap_max_ms", "normal_p99_ms", "fork_ms",
              "interruptions", "out_of_service_ms"):
        assert k in s
    assert rep.snapshot_lat.size + rep.normal_lat.size > 0
