"""Property-based test (hypothesis) for the run-write invariant:

    For ANY contiguous run partition of a leaf's blocks and ANY
    out-of-order concurrent schedule of those runs across workers,
    ``write_run`` produces bytes identical to per-block ``write_block``.

This is the safety net under the persist hot path's coalescing: runs are
a pure batching of data movement, never a change of layout.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'test' extra"
)
from hypothesis import given, settings, strategies as st

from repro.core import FileSink, read_file_snapshot
from repro.core.blocks import BlockRun, BlockTable


@st.composite
def run_schedule(draw):
    """(rows, block_rows, run lengths, shuffled run order, n_threads)."""
    rows = draw(st.sampled_from([40, 100, 128]))
    block_rows = draw(st.sampled_from([4, 8, 16]))
    n_blocks = -(-rows // block_rows)
    lengths = []
    while sum(lengths) < n_blocks:
        lengths.append(draw(st.integers(1, min(6, n_blocks - sum(lengths)))))
    order = draw(st.permutations(range(len(lengths))))
    n_threads = draw(st.integers(1, 4))
    return rows, block_rows, lengths, list(order), n_threads


@settings(max_examples=20, deadline=None)
@given(schedule=run_schedule())
def test_run_writes_byte_identical_to_per_block(tmp_path_factory, schedule):
    rows, block_rows, lengths, order, n_threads = schedule
    cols = 16
    tmp_path = tmp_path_factory.mktemp("runs_prop")
    state = {"kv": jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols)}
    table = BlockTable(state, block_bytes=block_rows * cols * 4)
    host = np.asarray(state["kv"])
    refs = table.blocks

    a = FileSink(str(tmp_path / "blocks"))
    a.open(table.leaf_handles)
    for r in refs[::-1]:  # worst-case out-of-order baseline
        a.write_block(r, host[r.start : r.stop])
    a.close()

    runs, i = [], 0
    for n in lengths:
        chunk = refs[i : i + n]
        runs.append(BlockRun(0, chunk[0].block_id, tuple(chunk)))
        i += n
    scheduled = [runs[j] for j in order]

    b = FileSink(str(tmp_path / "runs"))
    b.open(table.leaf_handles)

    def worker(worker_id):
        for run in scheduled[worker_id::n_threads]:
            b.write_run(
                run.leaf_id, run.start_block,
                [host[r.start : r.stop] for r in run.refs],
            )

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    b.close()

    with open(tmp_path / "blocks" / "leaf_0.bin", "rb") as f:
        blocks_bytes = f.read()
    with open(tmp_path / "runs" / "leaf_0.bin", "rb") as f:
        runs_bytes = f.read()
    assert blocks_bytes == runs_bytes
    np.testing.assert_array_equal(
        read_file_snapshot(str(tmp_path / "runs"))["kv"], host
    )
