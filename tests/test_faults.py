"""Fault injection, bounded retry, and composite-epoch abort unwinding
(ISSUE 8 tentpole + satellites)."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (
    BgsavePolicy,
    FaultInjector,
    RetryPolicy,
    SnapshotError,
    install_faults,
)
from repro.core import faults as faults_mod
from repro.core.catalog import ChainCompactor
from repro.core.policy import CompactionPolicy
from repro.kvstore import KVEngine, ShardedKVStore

_DELTA_POLICY = dict(delta_threshold=2.0, full_every=99)  # force deltas


def _engine(capacity=512, block_rows=64, row_width=4, shards=2, seed=0,
            policy=None, **kw):
    store = ShardedKVStore(capacity=capacity, block_rows=block_rows,
                           row_width=row_width, seed=seed, shards=shards)
    eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                   persist_bandwidth=None, copier_duty=0.5, policy=policy,
                   **kw)
    store.warmup(batch=2)
    return store, eng


def _set(store, eng, rows, val):
    rows = np.asarray(rows, dtype=np.int64)
    store.set(rows, np.full((rows.size, store.row_width), val, np.float32),
              before_write=eng._write_hook, gate=eng._gate)


@pytest.fixture(autouse=True)
def _no_leaked_injector():
    """Every test leaves the process-wide injector slot empty."""
    install_faults(None)
    yield
    install_faults(None)


# --------------------------------------------------------------------- #
# injector unit behavior                                                #
# --------------------------------------------------------------------- #
def test_injector_validates_site_and_mode():
    inj = FaultInjector()
    with pytest.raises(ValueError):
        inj.arm("not.a.site")
    with pytest.raises(ValueError):
        inj.arm("sink.write", mode="explode")


def test_injector_times_after_and_counters():
    inj = FaultInjector()
    inj.arm("sink.write", mode="raise", times=2, after=1)
    inj.fire("sink.write")  # skipped by after=1
    with pytest.raises(OSError):
        inj.fire("sink.write")
    with pytest.raises(OSError):
        inj.fire("sink.write")
    inj.fire("sink.write")  # budget of 2 spent
    assert inj.hits("sink.write") == 4
    assert inj.acted("sink.write") == 2
    assert inj.hits("sink.rename") == 0


def test_injector_delay_mode_and_custom_exc():
    inj = FaultInjector()
    inj.arm("sink.fsync", mode="delay", delay_s=0.02)
    t0 = time.perf_counter()
    inj.fire("sink.fsync")
    assert time.perf_counter() - t0 >= 0.015
    inj.arm("sink.rename", exc=RuntimeError)
    with pytest.raises(RuntimeError, match="sink.rename"):
        inj.fire("sink.rename")


def test_module_fire_prefers_explicit_over_installed():
    installed = FaultInjector()
    installed.arm("sink.write")
    explicit = FaultInjector()  # armed with nothing
    install_faults(installed)
    faults_mod.fire("sink.write", faults=explicit)  # explicit wins: no-op
    assert installed.hits("sink.write") == 0
    with pytest.raises(OSError):
        faults_mod.fire("sink.write")  # falls back to the installed one
    install_faults(None)
    faults_mod.fire("sink.write")  # nothing anywhere: no-op


# --------------------------------------------------------------------- #
# RetryPolicy                                                           #
# --------------------------------------------------------------------- #
def test_retry_policy_backoff_schedule():
    pol = RetryPolicy(max_retries=3, backoff_s=0.01, backoff_mult=2.0,
                      max_backoff_s=0.025)
    assert pol.backoff(0) == 0.01
    assert pol.backoff(1) == 0.02
    assert pol.backoff(2) == 0.025  # clamped
    assert pol.backoff(3) is None  # budget spent


def test_transient_write_fault_retried_to_success(tmp_path):
    """A once-raising persist fault is absorbed by the retry loop: the
    epoch commits, bytes are exact, and the retry is counted."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    probe = np.arange(512, dtype=np.int64)
    _set(store, eng, probe[::3], 5.0)
    before = np.array(store.get(probe), copy=True)
    inj.arm("persist.run", mode="raise", times=1)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    assert snap.wait_persisted(120.0)
    assert inj.acted("persist.run") == 1
    assert snap.metrics.summary()["persist_retries"] >= 1.0
    assert snap.metrics.summary()["persist_aborts"] == 0.0
    from repro.core import read_file_snapshot
    assert read_file_snapshot(str(tmp_path / "ep0"))  # crc-verified
    np.testing.assert_array_equal(store.get(probe), before)


def test_exhausted_retry_budget_unwinds_whole_epoch(tmp_path):
    """More consecutive faults than the retry budget: the job aborts,
    the WHOLE composite epoch unwinds — sibling shard dirs and the
    partial epoch dir removed, nothing registered in the catalog — and
    the abort is counted exactly once per failed part."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    _set(store, eng, np.arange(0, 512, 3), 7.0)
    inj.arm("persist.run", mode="raise", times=50)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    with pytest.raises(SnapshotError):
        snap.wait_persisted(120.0)
    assert snap.commit_done.is_set()
    # the epoch dir (and any sibling shard dirs inside it) is gone
    assert not os.path.exists(str(tmp_path / "ep0"))
    with pytest.raises(ValueError):
        eng.catalog.pin(snap.epoch_id)
    assert snap.metrics.summary()["persist_aborts"] >= 1.0
    assert snap.metrics.summary()["persist_retries"] >= 3.0
    # the engine recovers: the next fault-free epoch commits cleanly
    inj.disarm()
    snap2 = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep1"))
    assert snap2.wait_persisted(120.0)
    assert os.path.exists(str(tmp_path / "ep1" / "manifest.json"))


def test_durable_close_fault_aborts_cleanly(tmp_path):
    """Faults in the durable close protocol (fsync/rename are NOT inside
    the retry loop) abort the epoch with a full unwind."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    _set(store, eng, np.arange(0, 512, 5), 3.0)
    inj.arm("sink.rename", mode="raise", times=1)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    with pytest.raises(SnapshotError):
        snap.wait_persisted(120.0)
    assert not os.path.exists(str(tmp_path / "ep0"))


def test_commit_point_fault_unwinds_epoch(tmp_path):
    """A fault at the composite-manifest rename (the commit point)
    unwinds the epoch even though every shard persisted durably."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    _set(store, eng, np.arange(0, 512, 4), 2.0)
    inj.arm("bgsave.commit", mode="raise", times=1)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    with pytest.raises(SnapshotError, match="composite commit failed"):
        snap.wait_persisted(120.0)
    assert not os.path.exists(str(tmp_path / "ep0"))
    # a later epoch starts a FRESH chain (the unwound dir never became
    # a delta parent)
    inj.disarm()
    _set(store, eng, np.arange(1, 512, 4), 2.5)
    snap2 = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep1"))
    assert snap2.wait_persisted(120.0)


# --------------------------------------------------------------------- #
# compactor + GC resilience (satellites)                                #
# --------------------------------------------------------------------- #
def test_compactor_survives_scan_exceptions(tmp_path):
    """A fault inside compact_dir no longer kills the compactor thread:
    the error is counted and later scans still fold chains."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine(policy=BgsavePolicy(**_DELTA_POLICY))
    cat = eng.catalog
    for e in range(3):
        _set(store, eng, np.arange(0, 512, 2), float(e + 1))
        snap = eng.coordinator.bgsave_to_dir(str(tmp_path / f"ep{e}"))
        assert snap.wait_persisted(120.0)
    comp = ChainCompactor(cat, CompactionPolicy(max_chain=1))
    inj.arm("compactor.swap", mode="raise", times=1)
    folded_first = comp.scan_once()
    assert comp.compactor_errors == 1
    assert folded_first == [] or len(folded_first) >= 0  # thread alive
    inj.disarm()
    folded = comp.scan_once()
    assert folded  # the chain folds once the fault clears
    assert comp.compactor_errors == 1


def test_gc_fault_counts_and_leaves_orphan(tmp_path):
    """A fault during epoch-drop GC leaves the dir on disk (an orphan
    for recovery) and bumps gc_errors instead of raising."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    assert snap.wait_persisted(120.0)
    inj.arm("catalog.gc", mode="raise", times=50)
    removed = eng.catalog.drop_epoch(snap.epoch_id)
    assert removed == []
    assert eng.catalog.gc_errors >= 1
    assert os.path.exists(str(tmp_path / "ep0" / "shard_0"))


def test_drop_epoch_tolerates_enoent(tmp_path):
    """An externally-deleted shard dir must not break drop_epoch."""
    import shutil
    store, eng = _engine()
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    assert snap.wait_persisted(120.0)
    shutil.rmtree(str(tmp_path / "ep0" / "shard_1"))
    eng.catalog.drop_epoch(snap.epoch_id)  # must not raise
    assert eng.catalog.gc_errors == 0  # ENOENT is tolerated, not an error


# --------------------------------------------------------------------- #
# fault matrix under live writer traffic (satellite)                    #
# --------------------------------------------------------------------- #
_MATRIX_SITES = ("sink.write", "sink.fsync", "sink.rename", "persist.run",
                 "persist.stage", "bgsave.commit")
# inside _write_with_retry / _stage_with_retry
_RETRYABLE = ("sink.write", "persist.run", "persist.stage")


def _epoch_under_traffic(tmp_path, inj, site, times, tag):
    """One durable epoch with a concurrent writer thread; returns
    (snap, error_or_none)."""
    store, eng = _engine(shards=2)
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            _set(store, eng, np.arange(i % 7, 512, 11), float(i))
            i += 1

    th = threading.Thread(target=writer, daemon=True)
    th.start()
    try:
        inj.arm(site, mode="raise", times=times)
        snap = eng.coordinator.bgsave_to_dir(str(tmp_path / tag))
        err = None
        try:
            ok = snap.wait_persisted(120.0)
            assert ok
        except SnapshotError as exc:
            err = exc
        return snap, err
    finally:
        stop.set()
        th.join(10.0)
        inj.disarm()


@pytest.mark.parametrize("site", _MATRIX_SITES)
@pytest.mark.parametrize("times", [1, 50])
def test_fault_matrix_commit_or_clean_abort(tmp_path, site, times):
    """Every site x (raise-once, raise-past-budget) under live writes
    ends in exactly one of two states: a fully-committed epoch (manifest
    present, crc-verified readable) or a clean abort (no partial epoch
    dir, epoch not pinnable) — never a torn in-between."""
    inj = FaultInjector()
    install_faults(inj)
    tag = f"ep_{site.replace('.', '_')}_{times}"
    snap, err = _epoch_under_traffic(tmp_path, inj, site, times, tag)
    epoch_dir = str(tmp_path / tag)
    retried_ok = site in _RETRYABLE and times == 1
    if retried_ok:
        assert err is None, f"retryable single fault at {site} aborted"
    if err is None:
        assert os.path.exists(os.path.join(epoch_dir, "manifest.json"))
        from repro.core import read_file_snapshot
        assert read_file_snapshot(epoch_dir)
    else:
        assert not os.path.exists(epoch_dir)


def test_fault_matrix_abort_is_unpinnable(tmp_path):
    """Companion to the matrix: an aborted epoch id cannot be pinned."""
    inj = FaultInjector()
    install_faults(inj)
    store, eng = _engine()
    inj.arm("sink.fsync", mode="raise", times=50)
    snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep0"))
    with pytest.raises(SnapshotError):
        snap.wait_persisted(120.0)
    if snap.epoch_id is not None:
        with pytest.raises(ValueError):
            eng.catalog.pin(snap.epoch_id)


# --------------------------------------------------------------------- #
# hypothesis variant (optional dep)                                     #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # optional 'test' extra — the matrix above still runs
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(site=st.sampled_from(_MATRIX_SITES),
           times=st.integers(min_value=1, max_value=6),
           after=st.integers(min_value=0, max_value=3))
    def test_fault_matrix_property(site, times, after, tmp_path_factory):
        """Property form: any raise-fault schedule (site, budget, skip-N
        timing) yields commit-or-clean-abort, never a torn epoch dir."""
        tmp_path = tmp_path_factory.mktemp("prop")
        inj = FaultInjector()
        install_faults(inj)
        try:
            store, eng = _engine(shards=2)
            _set(store, eng, np.arange(0, 512, 9), 1.0)
            inj.arm(site, mode="raise", times=times, after=after)
            snap = eng.coordinator.bgsave_to_dir(str(tmp_path / "ep"))
            err = None
            try:
                snap.wait_persisted(120.0)
            except SnapshotError as exc:
                err = exc
            epoch_dir = str(tmp_path / "ep")
            if err is None:
                assert os.path.exists(
                    os.path.join(epoch_dir, "manifest.json"))
            else:
                assert not os.path.exists(epoch_dir)
        finally:
            install_faults(None)
