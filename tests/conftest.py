"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 devices
(in a subprocess)."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    # CI installs pytest-timeout so hung concurrency tests fail fast; keep
    # the @pytest.mark.timeout marks warning-free where the plugin is absent
    # (the marks are then inert).
    if not config.pluginmanager.hasplugin("timeout"):
        config.addinivalue_line(
            "markers",
            "timeout(seconds): fail the test if it runs longer than "
            "`seconds` (enforced by pytest-timeout when installed)",
        )


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)
    yield
