"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward/train step on CPU; shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models import build_model


def _batch(cfg, B=2, S=16, rng=0):
    tokens = jax.random.randint(jax.random.PRNGKey(rng), (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(rng + 1),
                                            (B, S, cfg.d_model))
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
        batch["extra_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), f"{arch}: NaN grad at {path}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S)
    tokens = batch["tokens"]
    if cfg.family == "audio":
        logits, cache = model.prefill(params, batch["frames"], tokens[:, :S],
                                      cache_len=32)
    else:
        logits, cache = model.prefill(params, tokens[:, :S], cache_len=32)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    pos = jnp.full((B,), S, jnp.int32)
    kwargs = {}
    if cfg.family == "vlm":
        kwargs["mrope_positions"] = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    logits2, cache2 = model.decode_step(params, cache, tokens[:, S:S + 1], pos,
                                        **kwargs)
    assert logits2.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: decode NaN"


def test_decode_matches_forward_dense():
    """Decode-with-cache must agree with teacher-forced forward logits."""
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    # full forward logits at position S-2 predict token S-1
    h, _ = model.forward(params, tokens)
    full_logits = (h @ params["lm_head"])[:, S - 2]
    # prefill on S-1 tokens, then decode token S-1 at pos S-1 gives the same
    logits_p, cache = model.prefill(params, tokens[:, : S - 1], cache_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_shape_applicability_matrix():
    """40 cells: every (arch x shape) either supported or documented-skip."""
    total, skipped = 0, []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, why = cfg.supports(shape)
            if not ok:
                assert why, f"{arch}/{shape.name}: skip without reason"
                skipped.append((arch, shape.name))
    assert total == 40
    # long_500k only runs on sub-quadratic archs: 8 skips expected
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)


def test_param_counts_match_nominal_size():
    """Full configs' analytic param counts are in the right ballpark."""
    expect = {
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "deepseek-v2-lite-16b": (13e9, 19e9),
        "deepseek-67b": (60e9, 74e9),
        "phi3-medium-14b": (12e9, 16e9),
        "mistral-large-123b": (110e9, 135e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "whisper-medium": (0.5e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
