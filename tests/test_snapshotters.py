"""Behavioural tests for the three snapshotters (paper §3, §4, §5.2)."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsyncForkSnapshotter,
    BlockingSnapshotter,
    CowSnapshotter,
    MemorySink,
    NullSink,
    PyTreeProvider,
    make_snapshotter,
)


def _state(rows=256, cols=128):
    return {
        "table": jnp.arange(rows * cols, dtype=jnp.float32).reshape(rows, cols),
        "aux": jnp.full((16, 32), 7.0, jnp.float32),
    }


def _copy_host(prov):
    import jax

    return jax.tree_util.tree_map(lambda a: np.asarray(a).copy(), prov.tree())


def _donated_update(prov, snapper, leaf_id, rows, value):
    """The engine's donated write: proactive sync -> update -> delete old."""
    snapper.before_write(leaf_id, rows)
    old = prov.leaf(leaf_id)
    new = old.at[np.asarray(rows)].set(value)
    prov.update_leaf(leaf_id, new, delete_old=True)  # donation


@pytest.mark.parametrize("mode", ["blocking", "cow", "asyncfork"])
def test_snapshot_is_point_in_time_consistent(mode):
    prov = PyTreeProvider(_state())
    snapper = make_snapshotter(mode, prov, block_bytes=4096, copier_threads=2)
    t0 = _copy_host(prov)
    snap = snapper.fork()
    for step in range(8):
        _donated_update(prov, snapper, 1, list(range(step * 4, step * 4 + 4)), -1.0)
    tree = snap.to_tree()
    np.testing.assert_array_equal(np.asarray(tree["table"]), t0["table"])
    np.testing.assert_array_equal(np.asarray(tree["aux"]), t0["aux"])
    # and the engine's live state has the new values
    assert float(prov.leaf(1)[0, 0]) == -1.0


def test_asyncfork_fork_is_metadata_only():
    """Fig 22: Async-fork's fork() must be far cheaper than default fork."""
    prov = PyTreeProvider(_state(rows=4096, cols=512))  # 8 MiB leaf
    blocking = BlockingSnapshotter(prov, block_bytes=64 << 10)
    async_ = AsyncForkSnapshotter(prov, block_bytes=64 << 10, copier_threads=2)
    s1 = blocking.fork()
    s2 = async_.fork()
    s2.wait(10)
    assert s2.metrics.fork_s < s1.metrics.fork_s / 3
    assert s2.metrics.copied_blocks_child + s2.metrics.copied_blocks_parent == s2.table.n_blocks


def test_blocking_never_interrupts_after_fork():
    prov = PyTreeProvider(_state())
    snapper = BlockingSnapshotter(prov, block_bytes=4096)
    snapper.fork()
    stall = snapper.before_write(1, range(10))
    snap = snapper.active()
    assert stall == 0.0 or all(s.metrics.n_interruptions == 0 for s in snap)


def test_cow_interrupts_for_whole_persist_window():
    """ODF model: writes stall while the (slow) persister is running."""
    prov = PyTreeProvider(_state(rows=512, cols=128))
    snapper = CowSnapshotter(prov, block_bytes=4096)
    sink = NullSink(bandwidth=2e6)  # slow disk: ~130ms persist window
    snap = snapper.fork(sink)
    time.sleep(0.01)
    _donated_update(prov, snapper, 1, range(4), -5.0)
    assert snap.metrics.n_interruptions >= 1
    snap.wait_persisted(30)
    # after the window, writes are free
    n_before = snap.metrics.n_interruptions
    _donated_update(prov, snapper, 1, range(4, 8), -6.0)
    assert snap.metrics.n_interruptions == n_before


def test_asyncfork_interrupts_only_during_copy_window():
    prov = PyTreeProvider(_state(rows=512, cols=128))
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=1)
    sink = NullSink(bandwidth=2e6)  # persist long outlives the copy window
    snap = snapper.fork(sink)
    snap.wait(10)  # copy window closed; persister still running
    assert not snap.persist_done.is_set()
    n_before = snap.metrics.n_interruptions
    _donated_update(prov, snapper, 1, range(4), -5.0)
    assert snap.metrics.n_interruptions == n_before  # no stall post-copy
    snap.wait_persisted(30)


def test_parallel_copiers_cover_all_blocks():
    prov = PyTreeProvider(_state(rows=2048, cols=256))
    for threads in (1, 2, 4, 8):
        snapper = AsyncForkSnapshotter(prov, block_bytes=16 << 10, copier_threads=threads)
        snap = snapper.fork()
        snap.wait(10)
        counts = snap.table.counts()
        assert counts["UNCOPIED"] == 0 and counts["COPYING"] == 0
        tree = snap.to_tree()
        np.testing.assert_array_equal(np.asarray(tree["table"]), np.asarray(prov.leaf(1)))


def test_consecutive_snapshots_serialize_per_leaf():
    """§5.2: a second fork proactively completes the previous child's copy."""
    prov = PyTreeProvider(_state(rows=4096, cols=512))
    snapper = AsyncForkSnapshotter(prov, block_bytes=32 << 10, copier_threads=1)
    t0 = _copy_host(prov)
    s1 = snapper.fork()
    s2 = snapper.fork()  # immediately: s1's copier can't have finished
    # s1 must be complete (every block copied) the moment fork #2 returns
    assert all(snapper.provider is prov for _ in [0])
    assert s1.table.counts()["UNCOPIED"] == 0
    _donated_update(prov, snapper, 1, range(8), -3.0)
    s1.wait(10)
    s2.wait(10)
    np.testing.assert_array_equal(np.asarray(s1.to_tree()["table"]), t0["table"])
    np.testing.assert_array_equal(np.asarray(s2.to_tree()["table"]), t0["table"])


def test_memory_sink_round_trip():
    prov = PyTreeProvider(_state())
    snapper = AsyncForkSnapshotter(prov, block_bytes=4096, copier_threads=2)
    sink = MemorySink()
    snap = snapper.fork(sink)
    snap.wait_persisted(10)
    assert sink.closed
    total = sum(b.nbytes for b in sink.blocks.values())
    assert total == snap.table.total_bytes


def test_unknown_mode_raises():
    prov = PyTreeProvider(_state())
    with pytest.raises(ValueError):
        make_snapshotter("sharedpt", prov)
