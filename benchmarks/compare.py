"""Diff a fresh benchmark --json trajectory against committed baselines.

Usage::

    python -m benchmarks.compare --new NEW.json [--cell persist_path]
        [--cell gate_contention ...] [--max-regress 0.25] [--floor 1.0]
        BASELINE.json [BASELINE2.json ...]

``--cell`` is a name prefix and may repeat — the gate then covers the
union of the named cells (no ``--cell`` gates every ratio metric).
``--floor`` additionally sets an ABSOLUTE lower bound on every gated
ratio: the effective floor is ``max(baseline * (1 - max_regress),
floor)``. Use it when the ratio has a semantic break-even — e.g.
``gate_contention``'s striped-vs-global ratios mean "striping still
wins" only while they stay above 1.0, no matter how lenient the
committed baseline happens to be.

Absolute microsecond numbers do not transfer between machines (the
committed baselines come from the dev container, CI runs on shared
runners), so the gate compares the **machine-portable ratio metrics** the
cells derive on-box — any ``key=<value>x`` field in a row's ``derived``
string (``runs_vs_per_block=8.78x``, ``speedup=2.05x``, ...). A ratio is
a within-run comparison of two configurations on the same hardware; a
>25% drop in one is an algorithmic regression, not runner noise.

Convention: the trailing ``x`` suffix is the opt-in, and it asserts
BIGGER IS BETTER. A cell deriving a ratio where bigger is worse (e.g.
``reshard_epoch``'s p99 ratio) must emit it WITHOUT the suffix so the
gate ignores it.

For each (row, ratio-key) present in both the new trajectory and at least
one baseline, the reference is the MINIMUM across baselines (the most
lenient committed run); the gate fails when
``new < reference * (1 - max_regress)``.
"""
from __future__ import annotations

import json
import re
import sys
from typing import Dict, List, Tuple

_RATIO_RE = re.compile(r"([A-Za-z0-9_/]+)=([0-9.]+)x(?:;|$)")


def ratio_metrics(rows: List[Dict]) -> Dict[Tuple[str, str], float]:
    """{(row name, ratio key): value} for every ``key=<float>x`` field."""
    out: Dict[Tuple[str, str], float] = {}
    for row in rows:
        for key, val in _RATIO_RE.findall(row.get("derived", "")):
            out[(row["name"], key)] = float(val)
    return out


def load_rows(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)["rows"]


def main(argv: List[str]) -> int:
    baselines: List[str] = []
    new_path = None
    cells: List[str] = []
    max_regress = 0.25
    abs_floor = None
    it = iter(argv)
    for a in it:
        if a == "--new":
            new_path = next(it)
        elif a.startswith("--new="):
            new_path = a.split("=", 1)[1]
        elif a == "--cell":
            cells.append(next(it))
        elif a.startswith("--cell="):
            cells.append(a.split("=", 1)[1])
        elif a == "--max-regress":
            max_regress = float(next(it))
        elif a.startswith("--max-regress="):
            max_regress = float(a.split("=", 1)[1])
        elif a == "--floor":
            abs_floor = float(next(it))
        elif a.startswith("--floor="):
            abs_floor = float(a.split("=", 1)[1])
        else:
            baselines.append(a)
    if new_path is None or not baselines:
        print(__doc__)
        return 2

    new = ratio_metrics(load_rows(new_path))
    ref: Dict[Tuple[str, str], float] = {}
    for b in baselines:
        for key, val in ratio_metrics(load_rows(b)).items():
            ref[key] = min(val, ref[key]) if key in ref else val

    failures, compared = [], 0
    for key, baseline_val in sorted(ref.items()):
        name, metric = key
        if cells and not any(name.startswith(c) for c in cells):
            continue
        if key not in new:
            print(f"MISSING  {name} [{metric}] (baseline {baseline_val:.2f}x)")
            failures.append(key)
            continue
        got = new[key]
        floor = baseline_val * (1.0 - max_regress)
        if abs_floor is not None:
            floor = max(floor, abs_floor)
        verdict = "OK" if got >= floor else "REGRESSED"
        compared += 1
        print(f"{verdict:9s}{name} [{metric}]: {got:.2f}x "
              f"(baseline {baseline_val:.2f}x, floor {floor:.2f}x)")
        if got < floor:
            failures.append(key)
    if compared == 0 and not failures:
        print(f"no comparable ratio metrics for cells {cells!r}; nothing to gate")
    if failures:
        print(f"{len(failures)} regression(s) beyond {max_regress:.0%}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
