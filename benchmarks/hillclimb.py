"""§Perf hillclimb driver: re-lower the three chosen cells with each
optimization flag set, writing results to results/dryrun_opt/<tag>/.

Usage: PYTHONPATH=src python -m benchmarks.hillclimb
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

CELLS = [
    # (arch, shape, mesh) — chosen per EXPERIMENTS.md §Perf
    ("xlstm-1.3b", "train_4k", "single"),          # worst roofline fraction
    ("deepseek-67b", "train_4k", "single"),        # most collective-bound
    ("qwen3-moe-30b-a3b", "train_4k", "single"),   # paper-representative
]

# iteration tag -> REPRO_PERF_OPT value (cumulative where it makes sense)
ITERATIONS = [
    ("it1_ssm_chunk", "ssm_chunk"),
    ("it2_batch_shard", "ssm_chunk,batch_shard"),
    ("it3_attn_flat", "attn_flat"),
    ("it4_pv_bf16", "attn_flat,pv_bf16"),
    ("it5_all", "attn_flat,pv_bf16,ssm_chunk,batch_shard"),
]


def run(cell, tag, flags, out_root="results/dryrun_opt"):
    arch, shape, mesh = cell
    out_dir = os.path.join(out_root, tag)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(path):
        return
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["REPRO_PERF_OPT"] = flags
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--json", path]
    print(f"[hillclimb] {tag}: {arch} x {shape} x {mesh} "
          f"(REPRO_PERF_OPT={flags})", flush=True)
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=2400,
                       env=env)
    if p.returncode != 0:
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "error", "stderr": p.stderr[-3000:]}, f)
        print(f"  ERROR: {p.stderr[-500:]}", flush=True)
    else:
        print("  " + (p.stdout.strip().splitlines()[-1] if p.stdout else ""),
              flush=True)


def main():
    # ssm iterations only matter for xlstm; attention ones for the others
    plan = {
        ("xlstm-1.3b", "train_4k", "single"): ["it1_ssm_chunk",
                                               "it2_batch_shard", "it5_all"],
        ("deepseek-67b", "train_4k", "single"): ["it3_attn_flat",
                                                 "it4_pv_bf16", "it5_all"],
        ("qwen3-moe-30b-a3b", "train_4k", "single"): ["it3_attn_flat",
                                                      "it4_pv_bf16", "it5_all"],
    }
    flag_of = dict(ITERATIONS)
    for cell, tags in plan.items():
        for tag in tags:
            run(cell, tag, flag_of[tag])


if __name__ == "__main__":
    main()
