"""One benchmark cell in an isolated process (jit caches, copier threads
and GIL state never leak across cells). Reads a JSON config from argv[1],
prints a JSON report on stdout."""
from __future__ import annotations

import json
import sys


def run_gate_contention(spec):
    """Multi-writer contention harness (PR 5): K writer threads hammer an
    N-shard store through the write gates while CONSECUTIVE BGSAVE fork
    barriers (paper §5.2, high-frequency snapshots) land mid-run.

    The workload is deliberately skewed — writer 0 is a HOT writer
    pounding shard 0 with large batches, the rest are quiet small-batch
    writers confined to the other shards — because that is exactly the
    shape where the global gate hurts: every epoch re-write-protects the
    hot shard, so its writes keep paying large proactive-sync stalls
    (big blocks, GIL-releasing memcpys), and under one global lock the
    QUIET shards' writers queue behind every one of them. ``striped``
    toggles per-shard gate stripes vs the single aliased global lock
    (identical code path, only lock granularity differs); the headline
    metric is the quiet writers' p99 write latency inside the snapshot
    windows vs outside them."""
    import threading
    import time

    import numpy as np

    from repro.kvstore import KVEngine, ShardedKVStore, Workload

    capacity = int(spec["size_mb"] * (1 << 20) / (4 * spec.get("row_width", 256)))
    shards = int(spec.get("shards", 2))
    writers = max(2, int(spec.get("writers", 4)))
    duration = float(spec.get("duration", 10.0))
    store = ShardedKVStore(
        capacity,
        row_width=spec.get("row_width", 256),
        block_rows=spec.get("block_rows", 4096),
        seed=0,
        shards=shards,
    )
    eng = KVEngine(
        store,
        mode=spec.get("mode", "asyncfork"),
        copier_threads=spec.get("threads", 1),
        persist_bandwidth=spec.get("persist_bw"),
        copier_duty=spec.get("duty", 1.0),
        persist_workers=spec.get("persist_workers"),
        striped_gates=bool(spec.get("striped", True)),
    )
    capacity = store.capacity  # post block-rounding
    hot_span = int(store._row_bounds[1])  # writer 0 owns all of shard 0
    hot = Workload(rate_qps=spec.get("hot_qps", 150), set_ratio=1.0,
                   batch=spec.get("hot_batch", 256),
                   clients=spec.get("clients", 50), seed=spec.get("seed", 1))
    quiet = Workload(rate_qps=spec.get("qps", 150), set_ratio=1.0,
                     batch=spec.get("batch", 16),
                     clients=spec.get("clients", 50),
                     seed=spec.get("seed", 1) + 1)
    # BLOCK-ALIGNED writer spans: batches are slot-aligned within their
    # span, so an unaligned span boundary would let batches straddle a
    # block and trigger mid-run jit compiles for the split shapes —
    # hundreds of ms of stall that has nothing to do with gating
    # quiet spans are BLOCK-granular; when there are more quiet writers
    # than quiet blocks (e.g. 7 writers over 4 blocks at 2 shards), pairs
    # of writers share a block — deliberate: same-stripe writer-vs-writer
    # contention is present in BOTH arms identically, so the
    # striped-vs-global ratio still isolates what the global gate ADDS
    # (it only deflates the ratio, never inflates it)
    br = store.block_rows
    nb = (capacity - hot_span) // br  # quiet blocks
    nq = writers - 1
    quiet_spans = []
    for w in range(nq):
        b0 = min((w * nb) // nq, nb - 1)
        b1 = min(max(b0 + 1, ((w + 1) * nb) // nq), nb)
        quiet_spans.append((hot_span + b0 * br, hot_span + b1 * br))
    streams = hot.writer_streams(capacity, duration, 1,
                                 spans=[(0, hot_span)])
    streams += quiet.writer_streams(capacity, duration, writers - 1,
                                    spans=quiet_spans)
    # warm the scatter jits for BOTH batch shapes off-clock (workload keys
    # are slot-aligned, so each query hits exactly one block and each
    # batch size is one compiled shape)
    for b in sorted({hot.batch, quiet.batch}):
        store.warmup(batch=b)
    pools = [np.random.rand(8, s[0].rows.size if s else 1, store.row_width)
             .astype(np.float32) for s in streams]
    lat = [[] for _ in range(writers)]  # (arrival, latency) per writer
    start_bar = threading.Barrier(writers + 1)
    t0_box = {}

    def writer(w):
        evs = streams[w]
        start_bar.wait()
        t0 = t0_box["t0"]
        for i, ev in enumerate(evs):
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            store.set(ev.rows, pools[w][i % 8],
                      before_write=eng._write_hook, gate=eng._gate,
                      on_gate_wait=eng._gate_wait_hook)
            lat[w].append((ev.t, (time.perf_counter() - t0) - ev.t))

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    for th in threads:
        th.start()
    t0_box["t0"] = time.perf_counter()
    start_bar.wait()
    # consecutive snapshots: a fresh barrier re-write-protects everything,
    # so the hot shard keeps generating proactive-sync stalls all run long
    first = float(spec.get("bgsave_at", 0.15))
    every = float(spec.get("bgsave_every", 0.08))
    snaps = []
    frac = first
    while frac < 0.95:
        t0 = t0_box["t0"]
        dt = frac * duration - (time.perf_counter() - t0)
        if dt > 0:
            time.sleep(dt)
        snaps.append(eng.bgsave())
        frac += every
    for th in threads:
        th.join(duration + 120)
    for s in snaps:
        s.wait_persisted(120)
    t0 = t0_box["t0"]
    spans_t = [(s.fork_start - t0, (s.t0 - t0) + s.metrics.persist_s)
               for s in snaps]

    def split(per_writer):
        inside, outside = [], []
        for per in per_writer:
            for a, l in per:
                if any(lo <= a <= hi for lo, hi in spans_t):
                    inside.append(l)
                else:
                    outside.append(l)
        return inside, outside

    def p99_ms(x):
        return float(np.percentile(np.array(x), 99) * 1e3) if x else float("nan")

    all_in, all_out = split(lat)
    quiet_in, quiet_out = split(lat[1:])
    summs = [s.metrics.summary() for s in snaps]
    return {
        "striped": bool(spec.get("striped", True)),
        "shards": shards,
        "writers": writers,
        "snapshots": len(snaps),
        "writes": sum(len(per) for per in lat),
        "writes_in_window": len(all_in),
        "write_p99_in_ms": p99_ms(all_in),
        "write_p99_out_ms": p99_ms(all_out),
        "quiet_p99_in_ms": p99_ms(quiet_in),
        "quiet_p99_out_ms": p99_ms(quiet_out),
        "quiet_max_in_ms": float(max(quiet_in) * 1e3) if quiet_in else float("nan"),
        "gate_wait_us": float(sum(s.get("gate_wait_us", 0.0) for s in summs)),
        "gate_acquires": eng.coordinator.gates.wait_summary()["gate_acquires"],
        "fork_ms": float(np.mean([s.get("fork_ms", 0.0) for s in summs])),
        "copy_window_ms": float(np.mean([s.get("copy_window_ms", 0.0) for s in summs])),
        "out_of_service_ms": float(sum(s.get("out_of_service_ms", 0.0) for s in summs)),
    }


def run_read_concurrency(spec):
    """Multi-reader serving harness (PR 6): N open-loop reader streams
    (spawn-db-gets style) submit GETs through a :class:`RequestServer`
    while a background writer donates block buffers out from under them
    and CONSECUTIVE BGSAVE fork barriers land mid-run.

    Two arms share the harness: ``concurrent=True`` serves reads on a
    worker pool through the seqlock/shared-stripe read plane, so a fork
    barrier (or the writer) stalls no one else; ``concurrent=False`` is
    the single-threaded serial arm — one worker serves EVERY request in
    queue order, the paper's single-threaded parent — so each fork stall
    and each write queues every reader behind it. The headline metric is
    reader p99 inside the snapshot windows, serial over concurrent."""
    import threading
    import time

    import numpy as np

    from repro.kvstore import (
        FlushRequest,
        GetRequest,
        KVEngine,
        RequestServer,
        SetRequest,
        ShardedKVStore,
        Workload,
    )

    capacity = int(spec["size_mb"] * (1 << 20) / (4 * spec.get("row_width", 256)))
    shards = int(spec.get("shards", 2))
    readers = max(1, int(spec.get("readers", 4)))
    concurrent = bool(spec.get("concurrent", True))
    duration = float(spec.get("duration", 8.0))
    store = ShardedKVStore(
        capacity,
        row_width=spec.get("row_width", 256),
        block_rows=spec.get("block_rows", 4096),
        seed=0,
        shards=shards,
    )
    eng = KVEngine(
        store,
        mode=spec.get("mode", "asyncfork"),
        copier_threads=spec.get("threads", 1),
        persist_bandwidth=spec.get("persist_bw"),
        copier_duty=spec.get("duty", 1.0),
        persist_workers=spec.get("persist_workers"),
    )
    capacity = store.capacity  # post block-rounding
    rd = Workload(rate_qps=spec.get("qps", 300), set_ratio=0.0,
                  batch=spec.get("batch", 16),
                  clients=spec.get("clients", 50), seed=spec.get("seed", 1))
    wr = Workload(rate_qps=spec.get("write_qps", 40), set_ratio=1.0,
                  batch=spec.get("write_batch", 4096),
                  clients=spec.get("clients", 50),
                  seed=spec.get("seed", 1) + 17)
    read_streams = rd.reader_streams(capacity, duration, readers)
    write_stream = wr.writer_streams(capacity, duration, 1)[0]
    for b in sorted({rd.batch, wr.batch}):
        store.warmup(batch=b)
    pool = np.random.rand(8, wr.batch, store.row_width).astype(np.float32)
    srv = RequestServer(
        eng,
        readers=readers if concurrent else 1,
        queue_depth=int(spec.get("queue_depth", 512)),
        concurrent_reads=concurrent,
    )
    msgs = [[] for _ in range(readers)]  # (arrival, Message) per stream
    start_bar = threading.Barrier(readers + 2)
    t0_box = {}

    def read_client(r):
        evs = read_streams[r]
        start_bar.wait()
        t0 = t0_box["t0"]
        for ev in evs:
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            # open loop: submit WITHOUT waiting; replies collected after
            msgs[r].append((ev.t, srv.submit(GetRequest(ev.rows))))

    write_msgs = []

    def write_client():
        start_bar.wait()
        t0 = t0_box["t0"]
        for i, ev in enumerate(write_stream):
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            # open loop, like the readers: the offered write load is
            # IDENTICAL in both arms. A synchronous writer would let the
            # serial arm throttle it (writes queue behind reads, landing
            # fewer donation/sync stalls), silently sparing the one
            # worker the very load the concurrent plane absorbs.
            write_msgs.append(srv.submit(SetRequest(ev.rows, pool[i % 8])))

    threads = [threading.Thread(target=read_client, args=(r,))
               for r in range(readers)]
    threads.append(threading.Thread(target=write_client))
    for th in threads:
        th.start()
    t0_box["t0"] = time.perf_counter()
    start_bar.wait()
    # consecutive BGSAVEs through the SERVER: in the serial arm the fork
    # stall lands on the one worker every reader queues behind (the
    # paper's inline fork); in the concurrent arm it occupies one worker
    # while the rest keep serving through the seqlock plane
    first = float(spec.get("bgsave_at", 0.1))
    every = float(spec.get("bgsave_every", 0.08))
    flush_msgs = []
    frac = first
    while frac < 0.95:
        t0 = t0_box["t0"]
        dt = frac * duration - (time.perf_counter() - t0)
        if dt > 0:
            time.sleep(dt)
        flush_msgs.append(srv.submit(FlushRequest()))
        frac += every
    for th in threads:
        th.join(duration + 120)
    snaps = []
    for m in flush_msgs:
        rep = m.wait(timeout=300)
        if rep.error is not None:
            raise rep.error
        snaps.append(rep.value)
    for s in snaps:
        s.wait_persisted(120)
    t0 = t0_box["t0"]
    lat = []  # (arrival, latency) across all reader streams
    for per in msgs:
        for a, m in per:
            rep = m.wait(timeout=300)
            if rep.error is not None:
                raise rep.error
            lat.append((a, (rep.done_t - t0) - a))
    for m in write_msgs:
        rep = m.wait(timeout=300)
        if rep.error is not None:
            raise rep.error
    stats = srv.stats()
    srv.close()
    spans_t = [(s.fork_start - t0, (s.t0 - t0) + s.metrics.persist_s)
               for s in snaps]
    inside = [l for a, l in lat
              if any(lo <= a <= hi for lo, hi in spans_t)]
    outside = [l for a, l in lat
               if not any(lo <= a <= hi for lo, hi in spans_t)]

    def p99_ms(x):
        return float(np.percentile(np.array(x), 99) * 1e3) if x else float("nan")

    summs = [s.metrics.summary() for s in snaps]
    return {
        "concurrent": concurrent,
        "shards": shards,
        "readers": readers,
        "snapshots": len(snaps),
        "reads": len(lat),
        "reads_in_window": len(inside),
        "read_p99_in_ms": p99_ms(inside),
        "read_p99_out_ms": p99_ms(outside),
        "read_max_in_ms": float(max(inside) * 1e3) if inside else float("nan"),
        "read_retries": float(sum(s.get("read_retries", 0.0) for s in summs)),
        "shared_wait_us": float(sum(s.get("shared_wait_us", 0.0) for s in summs)),
        "gate_wait_us": float(sum(s.get("gate_wait_us", 0.0) for s in summs)),
        "queue_depth_max": stats["queue_depth_max"],
        "queue_depth_mean": stats["queue_depth_mean"],
        "fork_ms": float(np.mean([s.get("fork_ms", 0.0) for s in summs])),
        "out_of_service_ms": float(sum(s.get("out_of_service_ms", 0.0) for s in summs)),
    }


def run_snapshot_reads(spec):
    """Snapshot-reads-as-a-product harness (PR 7): live open-loop readers
    + a background writer through a :class:`RequestServer`, with (the
    ``analytical=True`` arm) extra analyst streams issuing
    ``GetAtRequest`` point-in-time reads against a pinned epoch through
    the SAME server. GetAt resolves against the epoch's frozen images —
    no gate, no seqlock, no retries — so the live read tail should track
    the live-only baseline arm; the analysts only contend for workers.

    The same run then measures the fork cost of a writable branch
    (``KVEngine.branch``: COW wrap, O(metadata)) against an honest full
    copy of the epoch's images into fresh device blocks, and finally
    builds a delta chain ``max_chain + 2`` deep on disk and lets the
    :class:`ChainCompactor` fold it, timing the chain restore before and
    after the fold."""
    import os
    import shutil
    import tempfile
    import threading
    import time

    import numpy as np

    from repro.core import (
        BgsavePolicy,
        ChainCompactor,
        CompactionPolicy,
        read_file_snapshot,
    )
    import jax
    import jax.numpy as jnp

    from repro.kvstore import (
        FlushRequest,
        GetAtRequest,
        GetRequest,
        KVEngine,
        KVStore,
        RequestServer,
        SetRequest,
        ShardedKVStore,
        Workload,
    )

    capacity = int(spec["size_mb"] * (1 << 20) / (4 * spec.get("row_width", 256)))
    shards = int(spec.get("shards", 2))
    readers = max(1, int(spec.get("readers", 2)))
    analysts = max(1, int(spec.get("analysts", 2)))
    analytical = bool(spec.get("analytical", True))
    duration = float(spec.get("duration", 8.0))
    max_chain = max(1, int(spec.get("max_chain", 3)))
    store = ShardedKVStore(
        capacity,
        row_width=spec.get("row_width", 256),
        block_rows=spec.get("block_rows", 4096),
        seed=0,
        shards=shards,
    )
    eng = KVEngine(
        store,
        mode=spec.get("mode", "asyncfork"),
        copier_threads=spec.get("threads", 1),
        persist_bandwidth=spec.get("persist_bw"),
        copier_duty=spec.get("duty", 1.0),
        persist_workers=spec.get("persist_workers"),
        policy=BgsavePolicy(delta_threshold=2.0, full_every=99),
    )
    capacity = store.capacity  # post block-rounding
    rd = Workload(rate_qps=spec.get("qps", 300), set_ratio=0.0,
                  batch=spec.get("batch", 16),
                  clients=spec.get("clients", 50), seed=spec.get("seed", 1))
    an = Workload(rate_qps=spec.get("getat_qps", spec.get("qps", 300)),
                  set_ratio=0.0, batch=spec.get("batch", 16),
                  clients=spec.get("clients", 50),
                  seed=spec.get("seed", 1) + 7)
    wr = Workload(rate_qps=spec.get("write_qps", 40), set_ratio=1.0,
                  batch=spec.get("write_batch", 4096),
                  clients=spec.get("clients", 50),
                  seed=spec.get("seed", 1) + 17)
    read_streams = rd.reader_streams(capacity, duration, readers)
    analyst_streams = an.reader_streams(capacity, duration, analysts)
    write_stream = wr.writer_streams(capacity, duration, 1)[0]
    for b in sorted({rd.batch, wr.batch}):
        store.warmup(batch=b)
    pool = np.random.rand(8, wr.batch, store.row_width).astype(np.float32)

    # the pinned analysis epoch: taken BEFORE the serving window, retained
    # in memory (the engine's policy retains images), so every GetAt is a
    # zero-copy gather off frozen staging buffers
    epoch0 = eng.bgsave()
    epoch0.wait_persisted(120)
    ref = eng.catalog.pin(epoch0.epoch_id)

    srv = RequestServer(
        eng, readers=readers + (analysts if analytical else 0),
        queue_depth=int(spec.get("queue_depth", 512)),
    )
    n_clients = readers + 1 + (analysts if analytical else 0)
    msgs = [[] for _ in range(readers)]
    an_msgs = [[] for _ in range(analysts)]
    start_bar = threading.Barrier(n_clients + 1)
    t0_box = {}

    def read_client(r):
        evs = read_streams[r]
        start_bar.wait()
        t0 = t0_box["t0"]
        for ev in evs:
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            msgs[r].append((ev.t, srv.submit(GetRequest(ev.rows))))

    def analyst_client(r):
        evs = analyst_streams[r]
        start_bar.wait()
        t0 = t0_box["t0"]
        for ev in evs:
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            an_msgs[r].append((ev.t, srv.submit(GetAtRequest(ev.rows, ref))))

    write_msgs = []

    def write_client():
        start_bar.wait()
        t0 = t0_box["t0"]
        for i, ev in enumerate(write_stream):
            now = time.perf_counter() - t0
            if ev.t > now:
                time.sleep(ev.t - now)
            write_msgs.append(srv.submit(SetRequest(ev.rows, pool[i % 8])))

    threads = [threading.Thread(target=read_client, args=(r,))
               for r in range(readers)]
    if analytical:
        threads += [threading.Thread(target=analyst_client, args=(r,))
                    for r in range(analysts)]
    threads.append(threading.Thread(target=write_client))
    for th in threads:
        th.start()
    t0_box["t0"] = time.perf_counter()
    start_bar.wait()
    # one mid-run BGSAVE through the server so part of the window is a
    # live snapshot epoch, as in production
    dt = float(spec.get("bgsave_at", 0.3)) * duration \
        - (time.perf_counter() - t0_box["t0"])
    if dt > 0:
        time.sleep(dt)
    flush_msg = srv.submit(FlushRequest())
    for th in threads:
        th.join(duration + 120)
    rep = flush_msg.wait(timeout=300)
    if rep.error is not None:
        raise rep.error
    rep.value.wait_persisted(120)
    t0 = t0_box["t0"]

    def collect(per_stream):
        lat = []
        for per in per_stream:
            for a, m in per:
                r = m.wait(timeout=300)
                if r.error is not None:
                    raise r.error
                lat.append((r.done_t - t0) - a)
        return lat

    live_lat = collect(msgs)
    getat_lat = collect(an_msgs) if analytical else []
    for m in write_msgs:
        r = m.wait(timeout=300)
        if r.error is not None:
            raise r.error
    stats = srv.stats()
    srv.close()

    def p99_ms(x):
        return float(np.percentile(np.array(x), 99) * 1e3) if x else float("nan")

    # -- branch fork vs full copy ----------------------------------------
    t0 = time.perf_counter()
    child = eng.branch(ref)
    branch_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    copies = []
    for k in range(store.n_shards):
        blocks = [jnp.asarray(np.ascontiguousarray(b))
                  for b in ref.shard_blocks(k)]
        copies.append(KVStore.from_blocks(blocks, store.row_width,
                                          store.block_rows))
    for s in copies:
        for b in range(s.n_blocks):
            jax.block_until_ready(s.provider.leaf(b))
    ShardedKVStore.from_shards(copies, store.row_width, store.block_rows)
    copy_s = time.perf_counter() - t0
    # the branch must actually serve its cut
    probe = np.arange(0, min(1024, capacity), 7)
    assert child.store.get_concurrent(probe).shape[0] == probe.size
    child.branch_ref.release()
    ref.release()

    # -- delta-chain fold (the maintenance plane) ------------------------
    tmp = tempfile.mkdtemp(prefix="snapshot_reads_")
    cat = eng.catalog
    try:
        dirs = []
        for e in range(max_chain + 3):
            if e:
                rows = np.arange(0, store.block_rows, 37, dtype=np.int64)
                store.set(rows, pool[e % 8][: rows.size],
                          before_write=eng._write_hook, gate=eng._gate)
            snap = eng.coordinator.bgsave_to_dir(os.path.join(tmp, f"ep{e}"))
            snap.wait_persisted(120)
            dirs.append(snap)
        tip = cat._records[dirs[-1].epoch_id].shard_dirs[0]
        depth_before = cat.dir_depth(tip)
        read_file_snapshot(tip)  # warm the page cache off-clock
        t0 = time.perf_counter()
        read_file_snapshot(tip)
        chain_restore_s = time.perf_counter() - t0
        comp = ChainCompactor(cat, CompactionPolicy(max_chain=max_chain))
        t0 = time.perf_counter()
        folded = comp.scan_once()
        compact_s = time.perf_counter() - t0
        depth_after = cat.dir_depth(tip)
        t0 = time.perf_counter()
        read_file_snapshot(tip)
        flat_restore_s = time.perf_counter() - t0
    finally:
        for snap in dirs:
            cat.drop_epoch(snap.epoch_id)
        shutil.rmtree(tmp, ignore_errors=True)

    return {
        "analytical": analytical,
        "shards": shards,
        "readers": readers,
        "analysts": analysts if analytical else 0,
        "live_reads": len(live_lat),
        "live_p99_ms": p99_ms(live_lat),
        "getats": stats["get_ats"],
        "getat_p99_ms": p99_ms(getat_lat),
        "queue_depth_max": stats["queue_depth_max"],
        "branch_fork_ms": branch_s * 1e3,
        "copy_fork_ms": copy_s * 1e3,
        "max_chain": max_chain,
        "chain_depth_before": depth_before,
        "chain_depth_after": depth_after,
        "compacted_dirs": len(folded),
        "compact_ms": compact_s * 1e3,
        "chain_restore_ms": chain_restore_s * 1e3,
        "flat_restore_ms": flat_restore_s * 1e3,
    }


def run_persist_overlap(spec):
    """Overlapped persist datapath harness (PR 9): one durable BGSAVE
    epoch drained through per-shard PACED file sinks — ``write_run`` adds
    a GIL-free ``sleep(bytes / bandwidth)`` after each real pwritev, the
    :class:`NullSink` ``bandwidth=`` idiom grafted onto the durable path,
    emulating a per-shard disk stream on this single-core container.

    The two arms share everything but ``PersistPipeline(overlap=...)``:
    the serial arm stages a run, writes it, stages the next (the pre-PR-9
    datapath); the overlapped arm runs the stager lane and the per-job
    writer lane concurrently through the bounded ring, so device D2H
    staging of run N+1 hides under the paced write of run N. Device
    staging + ``copier_duty`` pinned near zero keeps the copier thread
    out of the way (its per-block launches would convoy the whole leaf
    behind whole-leaf kernel materializations) so the persist workers'
    span-batched ``stage_run`` is the lane under test.

    ``persist_workers`` defaults to 1 DELIBERATELY: with one worker per
    shard the serial arm already pipelines ACROSS jobs (shard A stages
    while shard B's paced write sleeps), which measures shard
    parallelism, not the two-lane datapath. One shared stager plus the
    per-job writer lanes is the configuration where overlap on/off
    isolates exactly the D2H<->disk pipelining this PR added.

    A background writer donates single-row updates (proactive-sync
    before_write) all through the drain; its latency tail is the
    in-window writer p99. ``compress="zlib"`` stacks the per-run frame
    encoder (crc over uncompressed views, level-1 deflate) into the
    writer lane; pacing stays on UNCOMPRESSED bytes, so the compressed
    arm measures encoder overhead at equal emulated disk time while
    ``disk_bytes`` reports the capacity win."""
    import os
    import shutil
    import tempfile
    import threading
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        FileSink,
        NullSink,
        PersistPipeline,
        PyTreeProvider,
        ShardedSnapshotCoordinator,
    )

    mb = float(spec.get("size_mb", 64))
    shards = int(spec.get("shards", 2))
    overlap = bool(spec.get("overlap", True))
    compress = spec.get("compress")
    run_blocks = int(spec.get("run_blocks", 16))
    bandwidth = float(spec.get("bandwidth_mbps", 8.0)) * 1e6
    duty = float(spec.get("duty", 0.01))
    block_bytes = int(spec.get("block_kb", 256)) << 10
    cols = int(spec.get("row_width", 256))
    repeat = max(1, int(spec.get("repeat", 2)))
    write_period = float(spec.get("write_period", 0.05))
    rows = int(mb * (1 << 20) / (cols * 4 * shards))

    class PacedFileSink(FileSink):
        # overriding write_run also exercises the pipeline's
        # wrapper-sink probe: runs must stay coalesced through the
        # subclass, not demote to per-block writes
        def write_run(self, leaf_id, start_block, arrays):
            n = int(sum(a.nbytes for a in arrays))
            super().write_run(leaf_id, start_block, arrays)
            time.sleep(n / bandwidth)

    provs = []
    for k in range(shards):
        state = {"kv": (jnp.arange(rows * cols, dtype=jnp.float32)
                        .reshape(rows, cols) + float(k))}
        jax.block_until_ready(state["kv"])
        provs.append(PyTreeProvider(state))
    pipeline = PersistPipeline(
        workers=int(spec.get("persist_workers", 1)),
        run_blocks=run_blocks, overlap=overlap,
    )
    coord = ShardedSnapshotCoordinator(
        provs, mode=spec.get("mode", "asyncfork"),
        block_bytes=block_bytes, pipeline=pipeline,
        copier_threads=int(spec.get("threads", 1)), copier_duty=duty,
        backend=spec.get("backend", "device"),
    )
    # warmup epoch: compile the staging/span kernels off-clock
    coord.bgsave(sinks=[NullSink() for _ in range(shards)]).wait_persisted(300)
    # warm the donated-write jit off-clock too
    provs[0].update_leaf(0, provs[0].leaf(0).at[0].set(0.0), delete_old=True)

    best = None
    disk_bytes = 0
    for trial in range(repeat):
        tmp = tempfile.mkdtemp(prefix="persist_overlap_")
        stop = threading.Event()
        write_lat = []

        def writer():
            sn, prov = coord.snapshotters[0], provs[0]
            i = 0
            while not stop.is_set():
                r = (i * 7 + 1) % rows
                t0 = time.perf_counter()
                sn.before_write(0, [r])
                prov.update_leaf(0, prov.leaf(0).at[r].set(float(i)),
                                 delete_old=True)
                write_lat.append(time.perf_counter() - t0)
                i += 1
                time.sleep(write_period)

        th = threading.Thread(target=writer, daemon=True)
        try:
            if write_period > 0:
                th.start()
            t0 = time.perf_counter()
            snap = coord.bgsave(sinks=[
                PacedFileSink(os.path.join(tmp, f"shard_{k}"),
                              durable=True, compress=compress)
                for k in range(shards)
            ])
            if not snap.wait_persisted(600):
                raise RuntimeError("epoch did not persist")
            wall = time.perf_counter() - t0
            stop.set()
            if write_period > 0:
                th.join(30)
            m = snap.metrics
            trial_disk = sum(
                os.path.getsize(os.path.join(root, f))
                for root, _, files in os.walk(tmp) for f in files
            )
            res = {
                "epoch_wall_s": wall,
                "persist_s": m.persist_s,
                "sink_write_s": m.sink_write_s,
                "stage_s": m.stage_s,
                "write_busy_s": m.write_busy_s,
                "overlap_frac": m.overlap_frac,
                "copied_blocks_child": m.copied_blocks_child,
                "write_p99_ms": (
                    float(np.percentile(np.array(write_lat), 99) * 1e3)
                    if write_lat else float("nan")),
                "writes_in_window": len(write_lat),
            }
            if best is None or wall < best["epoch_wall_s"]:
                best = res
                disk_bytes = trial_disk
        finally:
            stop.set()
            shutil.rmtree(tmp, ignore_errors=True)
    best.update({
        "overlap": overlap,
        "compress": compress or "none",
        "run_blocks": run_blocks,
        "shards": shards,
        "disk_bytes": disk_bytes,
        "sink_mb_per_s": mb / max(1e-9, best["sink_write_s"]),
    })
    return best


def run(spec):
    import numpy as np

    from repro.kvstore import KVEngine, KVStore, ShardedKVStore, Workload

    if spec.get("cell") == "gate_contention":
        return run_gate_contention(spec)
    if spec.get("cell") == "read_concurrency":
        return run_read_concurrency(spec)
    if spec.get("cell") == "snapshot_reads":
        return run_snapshot_reads(spec)
    if spec.get("cell") == "persist_overlap":
        return run_persist_overlap(spec)

    capacity = int(spec["size_mb"] * (1 << 20) / (4 * spec.get("row_width", 256)))
    shards = int(spec.get("shards", 1))
    store_kw = dict(
        row_width=spec.get("row_width", 256),
        block_rows=spec.get("block_rows", 256),
        seed=0,
    )
    store = (ShardedKVStore(capacity, shards=shards, **store_kw)
             if shards > 1 else KVStore(capacity, **store_kw))
    eng = KVEngine(
        store,
        mode=spec["mode"],
        copier_threads=spec.get("threads", 8),
        persist_bandwidth=spec.get("persist_bw", 50e6),
        # duty default defers to the engine's shard-aware default
        # (0.3/threads/sqrt(shards)) so 1-shard and N-shard cells compare
        # like against like; pass "duty" explicitly to pin it
        copier_duty=spec.get("duty"),
        backend=spec.get("backend", "host"),
        incremental=spec.get("incremental", False),
        persist_workers=spec.get("persist_workers"),
    )
    wl = Workload(
        rate_qps=spec.get("qps", 400),
        set_ratio=spec.get("set_ratio", 1.0),
        pattern=spec.get("pattern", "uniform"),
        batch=spec.get("batch", 16),
        clients=spec.get("clients", 50),
        seed=spec.get("seed", 1),
    )
    # optional online reshard fired inline on the serving thread at a run
    # fraction ("reshard_at"): "split" halves shard `reshard_shard`,
    # "merge" folds it into its right neighbor. The measured stall is the
    # split call itself (gate + layout swap), reported separately.
    actions = None
    reshard_stall = {}
    if spec.get("reshard_at") is not None:
        op = spec.get("reshard_op", "split")
        k = int(spec.get("reshard_shard", 0))

        def _reshard():
            import time as _t
            t0 = _t.perf_counter()
            if op == "split":
                eng.split(k)
            else:
                eng.merge(k, k + 1)
            reshard_stall["ms"] = (_t.perf_counter() - t0) * 1e3

        actions = [(float(spec["reshard_at"]), _reshard)]
    rep = eng.run(
        wl,
        duration_s=spec.get("duration", 6.0),
        bgsave_at=tuple(spec.get("bgsave_at", [0.15])),
        actions=actions,
    )
    out = rep.summary()
    out["instance_mb"] = spec["size_mb"]
    out["mode"] = spec["mode"]
    out["reshard_stall_ms"] = reshard_stall.get("ms", 0.0)
    out["final_shards"] = eng.n_shards
    # per-snapshot detail for Fig 11 histograms
    snaps = eng._snaps
    out["histograms"] = [s.metrics.histogram_us() for s in snaps]
    out["throughput_qps_50ms"] = (rep.throughput_buckets / 0.05).tolist()[:400]
    return out


def main():
    spec = json.loads(sys.argv[1])
    print(json.dumps(run(spec)))


if __name__ == "__main__":
    main()
