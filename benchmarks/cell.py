"""One benchmark cell in an isolated process (jit caches, copier threads
and GIL state never leak across cells). Reads a JSON config from argv[1],
prints a JSON report on stdout."""
from __future__ import annotations

import json
import sys


def run(spec):
    import numpy as np

    from repro.kvstore import KVEngine, KVStore, ShardedKVStore, Workload

    capacity = int(spec["size_mb"] * (1 << 20) / (4 * spec.get("row_width", 256)))
    shards = int(spec.get("shards", 1))
    store_kw = dict(
        row_width=spec.get("row_width", 256),
        block_rows=spec.get("block_rows", 256),
        seed=0,
    )
    store = (ShardedKVStore(capacity, shards=shards, **store_kw)
             if shards > 1 else KVStore(capacity, **store_kw))
    eng = KVEngine(
        store,
        mode=spec["mode"],
        copier_threads=spec.get("threads", 8),
        persist_bandwidth=spec.get("persist_bw", 50e6),
        # duty default defers to the engine's shard-aware default
        # (0.3/threads/sqrt(shards)) so 1-shard and N-shard cells compare
        # like against like; pass "duty" explicitly to pin it
        copier_duty=spec.get("duty"),
        backend=spec.get("backend", "host"),
        incremental=spec.get("incremental", False),
        persist_workers=spec.get("persist_workers"),
    )
    wl = Workload(
        rate_qps=spec.get("qps", 400),
        set_ratio=spec.get("set_ratio", 1.0),
        pattern=spec.get("pattern", "uniform"),
        batch=spec.get("batch", 16),
        clients=spec.get("clients", 50),
        seed=spec.get("seed", 1),
    )
    # optional online reshard fired inline on the serving thread at a run
    # fraction ("reshard_at"): "split" halves shard `reshard_shard`,
    # "merge" folds it into its right neighbor. The measured stall is the
    # split call itself (gate + layout swap), reported separately.
    actions = None
    reshard_stall = {}
    if spec.get("reshard_at") is not None:
        op = spec.get("reshard_op", "split")
        k = int(spec.get("reshard_shard", 0))

        def _reshard():
            import time as _t
            t0 = _t.perf_counter()
            if op == "split":
                eng.split(k)
            else:
                eng.merge(k, k + 1)
            reshard_stall["ms"] = (_t.perf_counter() - t0) * 1e3

        actions = [(float(spec["reshard_at"]), _reshard)]
    rep = eng.run(
        wl,
        duration_s=spec.get("duration", 6.0),
        bgsave_at=tuple(spec.get("bgsave_at", [0.15])),
        actions=actions,
    )
    out = rep.summary()
    out["instance_mb"] = spec["size_mb"]
    out["mode"] = spec["mode"]
    out["reshard_stall_ms"] = reshard_stall.get("ms", 0.0)
    out["final_shards"] = eng.n_shards
    # per-snapshot detail for Fig 11 histograms
    snaps = eng._snaps
    out["histograms"] = [s.metrics.histogram_us() for s in snaps]
    out["throughput_qps_50ms"] = (rep.throughput_buckets / 0.05).tolist()[:400]
    return out


def main():
    spec = json.loads(sys.argv[1])
    print(json.dumps(run(spec)))


if __name__ == "__main__":
    main()
