"""Benchmark suite — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = the headline
latency of the row in microseconds; derived = the figure's other numbers).

Instance sizes are scaled to this CPU container (32–256 MiB vs the paper's
1–64 GiB); the claims under test are the paper's *shapes*: linear fork-cost
growth, interruption counts, out-of-service time, and the DEF > ODF >
Async-fork latency ordering on snapshot queries.

Usage: ``python -m benchmarks.run [cell ...] [--full] [--json PATH]
[--copier-duty X] [--readers N] [--max-chain N] [--run-blocks N]
[--compress {none,zlib}]``.
Positional names select individual cells (e.g. ``persist_path``); with
none, the whole suite runs. ``--json`` additionally writes the collected
rows as a JSON trajectory artifact (CI uploads ``BENCH_3.json`` so future
PRs have a perf baseline). ``--copier-duty`` pins the per-shard copier
duty in the scaling cells (``shard_scaling``, ``gate_contention``) for
multi-core reruns — the single-core container default decays it
1/sqrt(shards). ``--readers`` overrides the ``read_concurrency`` cell's
reader-stream count for multi-core reruns. ``--max-chain`` overrides the
``snapshot_reads`` cell's ChainCompactor fold threshold. ``--run-blocks``
and ``--compress`` pin the ``persist_overlap`` cell's run coalescing
width and sink encoding.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.harness import run_cell

SIZES_MB = [32, 64, 128, 256]
MODES = ["blocking", "cow", "asyncfork"]
FAST = "--full" not in sys.argv
# --copier-duty=X (ROADMAP "benchmarks at scale"): pin the per-shard
# copier duty for the scaling cells instead of the engine's single-core
# 1/sqrt(N) default — on a real multi-core host pass 1.0 to validate the
# near-linear window shrink the cluster model predicts.
DUTY_OVERRIDE = None
# --readers=N: reader-stream count for the read_concurrency cell. The
# single-core default (4) already shows the serial arm's queueing; on a
# real multi-core host raise it to scale reader parallelism.
READERS_OVERRIDE = None
# --max-chain=N: delta-chain fold threshold for the snapshot_reads
# cell's ChainCompactor sub-phase (default 3, like CompactionPolicy).
MAX_CHAIN_OVERRIDE = None
# --run-blocks=N: persist-run coalescing width for the persist_overlap
# cell's headline arms (default 16; the cell also sweeps 4/64 around it).
RUN_BLOCKS_OVERRIDE = None
# --compress={none,zlib}: pin the persist_overlap cell to a single sink
# encoding instead of running both arms.
COMPRESS_OVERRIDE = None

_ROWS: list = []


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us, 1),
                  "derived": derived})


def fig3_fork_time_vs_size():
    """Fig 3: default-fork execution time grows linearly with instance
    size (the page-table/block copy dominates)."""
    for mb in SIZES_MB:
        r = run_cell({"mode": "blocking", "size_mb": mb, "duration": 5.0})
        _row(f"fig3_fork_time/{mb}MB", r["fork_ms"] * 1e3,
             f"copy_share=1.0;size_mb={mb}")


def fig22_fork_call_duration():
    """Fig 22: Async-fork and ODF return from fork() in O(metadata)."""
    for mode in MODES:
        r = run_cell({"mode": mode, "size_mb": 256, "duration": 6.0})
        _row(f"fig22_fork_call/{mode}", r["fork_ms"] * 1e3,
             f"size_mb=256")


def fig4_5_default_fork_latency():
    """Figs 4/5: p99 + max latency of normal vs snapshot queries, DEF."""
    for mb in SIZES_MB:
        r = run_cell({"mode": "blocking", "size_mb": mb, "duration": 6.0})
        _row(f"fig4_p99/blocking/{mb}MB", r["snap_p99_ms"] * 1e3,
             f"normal_p99_us={r['normal_p99_ms']*1e3:.0f}")
        _row(f"fig5_max/blocking/{mb}MB", r["snap_max_ms"] * 1e3,
             f"normal_max_us={r['normal_max_ms']*1e3:.0f}")


def fig9_10_odf_vs_asyncfork():
    """Figs 9/10: snapshot-query p99/max, ODF (cow) vs Async-fork."""
    for mb in SIZES_MB:
        rows = {}
        for mode in ("cow", "asyncfork"):
            rows[mode] = run_cell({"mode": mode, "size_mb": mb, "duration": 6.0})
        for mode in ("cow", "asyncfork"):
            r = rows[mode]
            _row(f"fig9_p99/{mode}/{mb}MB", r["snap_p99_ms"] * 1e3,
                 f"max_us={r['snap_max_ms']*1e3:.0f}")
        red = 100 * (1 - rows["asyncfork"]["snap_max_ms"] /
                     max(1e-9, rows["cow"]["snap_max_ms"]))
        _row(f"fig10_max_reduction/{mb}MB", rows["asyncfork"]["snap_max_ms"] * 1e3,
             f"vs_cow_pct={red:.1f}")


def fig11_20_interruptions():
    """Fig 11 (interruption counts) + Fig 20 (out-of-service time)."""
    for mb in ([64, 256] if FAST else SIZES_MB):
        for mode in ("cow", "asyncfork"):
            r = run_cell({"mode": mode, "size_mb": mb, "duration": 6.0})
            hist = r["histograms"][0] if r["histograms"] else {}
            _row(f"fig11_interruptions/{mode}/{mb}MB", r["interruptions"],
                 "hist=" + "|".join(f"{k}:{v}" for k, v in sorted(hist.items())))
            _row(f"fig20_out_of_service/{mode}/{mb}MB",
                 r["out_of_service_ms"] * 1e3, f"size_mb={mb}")


def fig12_read_write_patterns():
    """Fig 12: SET:GET mixes x uniform/gaussian access patterns."""
    for name, set_ratio, pattern in [
        ("1:1_uni", 0.5, "uniform"), ("1:1_gau", 0.5, "gaussian"),
        ("1:10_uni", 1 / 11, "uniform"), ("1:10_gau", 1 / 11, "gaussian"),
    ]:
        for mode in ("cow", "asyncfork"):
            r = run_cell({"mode": mode, "size_mb": 128, "duration": 6.0,
                          "set_ratio": set_ratio, "pattern": pattern})
            _row(f"fig12_patterns/{name}/{mode}", r["snap_p99_ms"] * 1e3,
                 f"max_us={r['snap_max_ms']*1e3:.0f};intr={r['interruptions']:.0f}")


def fig13_clients():
    """Fig 13: more open-loop clients -> burstier writes -> longer stalls."""
    for clients in [10, 50, 100, 500]:
        for mode in ("cow", "asyncfork"):
            r = run_cell({"mode": mode, "size_mb": 128, "duration": 6.0,
                          "clients": clients})
            _row(f"fig13_clients/{clients}/{mode}", r["snap_p99_ms"] * 1e3,
                 f"max_us={r['snap_max_ms']*1e3:.0f}")


def fig14_15_copier_threads():
    """Figs 14/15: child-side copier parallelism shortens the copy window
    and with it the interruption exposure."""
    for threads in [1, 2, 4, 8]:
        r = run_cell({"mode": "asyncfork", "size_mb": 128, "duration": 6.0,
                      "threads": threads, "duty": 0.3 / 8})
        _row(f"fig15_copy_window/threads{threads}", r["copy_window_ms"] * 1e3,
             f"snap_max_us={r['snap_max_ms']*1e3:.0f};intr={r['interruptions']:.0f}")


def fig17_19_throughput():
    """Figs 17-19: minimum 50ms-bucket throughput during the snapshot."""
    for mode in MODES:
        r = run_cell({"mode": mode, "size_mb": 128, "duration": 6.0,
                      "qps": 400})
        _row(f"fig19_min_tput/{mode}", r["min_tput_qps"],
             f"qps_floor={r['min_tput_qps']:.0f}")


def train_checkpoint_stall():
    """Framework integration: save-stall of blocking vs async-fork
    checkpointing inside a live (donating) training loop."""
    import json
    import subprocess

    code = r"""
import time, json, jax, jax.numpy as jnp, numpy as np, tempfile, os, dataclasses
from repro.configs import get_config
from repro.models import build_model
from repro.runtime.steps import make_train_step, init_train_state
from repro.checkpoint import TrainSnapshotManager
cfg = dataclasses.replace(get_config("phi3-mini-3.8b").reduced(),
                          n_layers=4, d_model=512, d_ff=1024, vocab=2048)
model = build_model(cfg)
params, opt = init_train_state(model, jax.random.PRNGKey(0))
fn = make_train_step(model)
donating = jax.jit(fn, donate_argnums=(0, 1))
nondonating = jax.jit(fn)
batch = {"tokens": np.random.randint(0, cfg.vocab, (8, 129)).astype(np.int32)}
_ = nondonating(params, opt, batch); jax.block_until_ready(_)
out = {}
with tempfile.TemporaryDirectory() as d:
    for mode in ("blocking", "asyncfork"):
        mgr = TrainSnapshotManager(os.path.join(d, mode), mode=mode, copier_threads=2)
        p = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), params)
        o = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), opt)
        times = []
        for step in range(10):
            t0 = time.perf_counter()
            if step == 3:
                mgr.save(step, p, o)
            f = nondonating if mgr.snapshot_active() else donating
            p, o, loss = f(p, o, batch)
            jax.block_until_ready(loss)
            times.append(time.perf_counter() - t0)
        mgr.wait_all()
        s = mgr.summary()
        out[mode] = {"stall_ms": s["save_stall_ms_max"],
                     "step_ms": float(np.median(times) * 1e3)}
print(json.dumps(out))
"""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    out = json.loads(p.stdout.strip().splitlines()[-1])
    for mode, r in out.items():
        _row(f"train_ckpt_stall/{mode}", r["stall_ms"] * 1e3,
             f"median_step_us={r['step_ms']*1e3:.0f}")


def kernel_snapcopy_bandwidth():
    """Micro: masked block copy kernel (interpret mode) vs oracle runtime
    + dirty-block incremental persist savings."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import dirty_blocks, masked_block_copy
    from repro.kernels.ref import snapcopy_ref

    src = jax.random.normal(jax.random.PRNGKey(0), (64, 4096), jnp.float32)
    dst = jnp.zeros_like(src)
    flags = jnp.zeros((64,), jnp.int32).at[::2].set(2)
    out, nf = masked_block_copy(src, dst, flags)  # compile
    t0 = time.perf_counter()
    for _ in range(10):
        out, nf = masked_block_copy(src, dst, flags)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / 10 * 1e6
    _row("kernel_snapcopy/64x4096xf32", us, "interpret=True;skip_half=True")

    new = src.at[3, 7].add(1.0)
    d = dirty_blocks(src, new)
    t0 = time.perf_counter()
    for _ in range(10):
        d = dirty_blocks(src, new)
    jax.block_until_ready(d)
    us = (time.perf_counter() - t0) / 10 * 1e6
    _row("kernel_dirty/64x4096xf32", us,
         f"dirty_blocks={int(d.sum())};persist_savings_pct={100*(1-float(d.mean())):.1f}")


def staging_backend_bandwidth():
    """New cell: host-numpy vs device-kernel staging bandwidth — a full
    blocking fork stages every block, so fork time == copy time."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import BlockingSnapshotter, PyTreeProvider

    mb = 16 if FAST else 64
    rows = mb * (1 << 20) // (256 * 4)
    for backend in ("host", "device"):
        state = {"kv": jnp.zeros((rows, 256), jnp.float32)}
        jax.block_until_ready(state["kv"])
        prov = PyTreeProvider(state)
        snapper = BlockingSnapshotter(prov, block_bytes=1 << 20, backend=backend)
        snapper.fork().wait(60)  # warm compile caches
        prov2 = PyTreeProvider({"kv": jnp.ones((rows, 256), jnp.float32)})
        snapper2 = BlockingSnapshotter(prov2, block_bytes=1 << 20, backend=backend)
        t0 = time.perf_counter()
        snap = snapper2.fork()
        snap.wait(60)
        dt = time.perf_counter() - t0
        mbps = mb / max(1e-9, dt)
        _row(f"staging_bw/{backend}/{mb}MB", dt * 1e6, f"mb_per_s={mbps:.0f}")


def incremental_snapshot_window():
    """New cell: full vs incremental snapshot window at 10/50/90% write
    rates — the dirty kernel marks clean blocks PERSISTED at fork, so the
    persister only pushes the written fraction through the (slow) sink."""
    import numpy as np

    from repro.core import AsyncForkSnapshotter, NullSink, PyTreeProvider

    import jax.numpy as jnp

    n_blocks, rows_per_block, cols = 64, 64, 256
    rows = n_blocks * rows_per_block
    bw = 50e6  # sink bandwidth models the paper's RDB disk
    for write_pct in (10, 50, 90):
        prov = PyTreeProvider(
            {"kv": jnp.zeros((rows, cols), jnp.float32)}
        )
        snapper = AsyncForkSnapshotter(
            prov, block_bytes=rows_per_block * cols * 4,
            copier_threads=2, retain_images=True,
        )
        # warmup epoch pair: compile the dirty-scan/adopt jits off-clock
        snapper.fork(NullSink()).wait_persisted(120)
        snapper.fork(NullSink(), incremental=True).wait_persisted(120)
        full = snapper.fork(NullSink(bandwidth=bw))
        full.wait_persisted(120)
        k = max(1, n_blocks * write_pct // 100)
        rng = np.random.default_rng(0)
        for b in rng.choice(n_blocks, size=k, replace=False):
            row = int(b) * rows_per_block
            snapper.before_write(0, [row])
            old = prov.leaf(0)
            prov.update_leaf(0, old.at[row].set(1.0), delete_old=True)
        inc = snapper.fork(NullSink(bandwidth=bw), incremental=True)
        inc.wait_persisted(120)
        speedup = full.metrics.persist_s / max(1e-9, inc.metrics.persist_s)
        _row(
            f"incremental_window/{write_pct}pct_writes",
            inc.metrics.persist_s * 1e6,
            f"full_us={full.metrics.persist_s*1e6:.0f};"
            f"inherited={inc.metrics.inherited_blocks}/{n_blocks};"
            f"speedup={speedup:.1f}x",
        )


def shard_scaling():
    """New cell: cross-shard BGSAVE at a fixed instance size — the fork
    barrier keeps the union point-in-time while each shard gets its own
    copiers and a slice of the shared persist pool, so the copy window and
    snapshot-query tail shrink as the shard count grows."""
    for shards in ([1, 2, 4] if FAST else [1, 2, 4, 8]):
        # duty=None -> the engine's shard-aware default for every shard
        # count (per-shard copier budget decaying 1/sqrt(N)), so the cells
        # compare like against like; one copier per shard and a modest
        # query rate keep GIL churn on this single-core host from
        # swamping the per-shard window gains
        r = run_cell({"mode": "asyncfork", "size_mb": 128, "duration": 6.0,
                      "qps": 100, "shards": shards, "threads": 1,
                      "duty": DUTY_OVERRIDE,
                      "persist_workers": max(2, shards)})
        _row(f"shard_scaling/{shards}shards", r["copy_window_ms"] * 1e3,
             f"snap_p99_us={r['snap_p99_ms']*1e3:.0f};"
             f"snap_max_us={r['snap_max_ms']*1e3:.0f};"
             f"min_tput={r['min_tput_qps']:.0f}")


def reshard_epoch():
    """New cell: a split landing while a coordinated BGSAVE is in flight
    under load. The layout swap is O(metadata) under the write gate, so
    the copy window and the snapshot-query tail should track the no-reshard
    baseline; ``reshard_stall_ms`` is the split call itself."""
    base = {"mode": "asyncfork", "size_mb": 64, "duration": 6.0, "qps": 100,
            "shards": 2, "threads": 1, "duty": None, "persist_workers": 2,
            "bgsave_at": [0.25]}
    r0 = run_cell(base)
    r1 = run_cell({**base, "reshard_at": 0.3, "reshard_op": "split",
                   "reshard_shard": 0})
    _row("reshard_epoch/baseline", r0["copy_window_ms"] * 1e3,
         f"snap_p99_us={r0['snap_p99_ms']*1e3:.0f};"
         f"oos_us={r0['out_of_service_ms']*1e3:.0f};"
         f"min_tput={r0['min_tput_qps']:.0f}")
    _row("reshard_epoch/split_mid_snapshot", r1["copy_window_ms"] * 1e3,
         f"snap_p99_us={r1['snap_p99_ms']*1e3:.0f};"
         f"oos_us={r1['out_of_service_ms']*1e3:.0f};"
         f"min_tput={r1['min_tput_qps']:.0f};"
         f"reshard_stall_us={r1['reshard_stall_ms']*1e3:.0f};"
         f"final_shards={r1['final_shards']}")
    # NOT the `=<v>x` format: that suffix opts a metric into the
    # compare.py regression gate, which assumes bigger-is-better — this
    # is a p99 ratio where bigger is WORSE
    _row("reshard_epoch/p99_ratio", 0.0,
         f"split_over_baseline_p99="
         f"{r1['snap_p99_ms'] / max(1e-9, r0['snap_p99_ms']):.2f}")


def gate_contention():
    """New cell (PR 5): K writer threads × N shards through the write
    gates, consecutive BGSAVE barriers landing mid-run. One HOT writer
    pounds shard 0 with whole-block batches (every epoch re-write-protects
    its blocks, so it keeps paying large proactive-sync stalls inside its
    gate-held commits); seven QUIET small-batch writers live on the other
    shards. The striped arm takes one gate stripe per touched shard; the
    global arm aliases every stripe to one lock (PR-2 behavior) — so the
    quiet writers' p99 inside the snapshot windows isolates exactly the
    cross-shard serialization the global gate added. The gated ratio is
    global-over-striped quiet p99 in-window (bigger = striping wins)."""
    for shards in ([2, 4] if FAST else [2, 4, 8]):
        arms = {}
        for striped in (False, True):
            # size scales with the shard count so per-shard geometry is
            # fixed (16 MiB, four 4 MiB blocks per shard): each added
            # shard adds an independent stripe, not a smaller shard
            arms[striped] = run_cell({
                "cell": "gate_contention", "size_mb": 16 * shards,
                "duration": 8.0,
                "shards": shards, "writers": 8, "threads": 1,
                "duty": DUTY_OVERRIDE if DUTY_OVERRIDE is not None else 0.05,
                "hot_qps": 15, "hot_batch": 8192, "qps": 140, "batch": 16,
                "persist_bw": 3e7, "bgsave_at": 0.1, "bgsave_every": 0.08,
                "striped": striped,
            })
        s, g = arms[True], arms[False]
        ratio = g["quiet_p99_in_ms"] / max(1e-9, s["quiet_p99_in_ms"])
        all_ratio = g["write_p99_in_ms"] / max(1e-9, s["write_p99_in_ms"])
        wait_ratio = g["gate_wait_us"] / max(1e-9, s["gate_wait_us"])
        _row(f"gate_contention/{shards}shards", s["quiet_p99_in_ms"] * 1e3,
             f"global_quiet_p99_in_us={g['quiet_p99_in_ms']*1e3:.0f};"
             f"striped_quiet_p99_out_us={s['quiet_p99_out_ms']*1e3:.0f};"
             f"global_quiet_p99_out_us={g['quiet_p99_out_ms']*1e3:.0f};"
             f"all_p99_ratio={all_ratio:.2f};"
             f"striped_gate_wait_us={s['gate_wait_us']:.0f};"
             f"global_gate_wait_us={g['gate_wait_us']:.0f};"
             f"snapshots={s['snapshots']};"
             f"writes_in_window={s['writes_in_window']};"
             f"gate_wait_reduction={wait_ratio:.2f}x;"
             f"striped_vs_global_p99={ratio:.2f}x")


def read_concurrency():
    """New cell (PR 6): N open-loop reader streams + a background writer
    through the RequestServer, consecutive BGSAVE barriers landing
    mid-run. The serial arm funnels every request through ONE worker (the
    paper's single-threaded parent: each fork stall and each donated
    write queues all readers behind it); the concurrent arm serves reads
    on a worker pool through the seqlock/shared-stripe read plane, so
    only the flush-carrying worker stalls. The gated ratio is serial-
    over-concurrent reader p99 inside the snapshot windows (bigger =
    the concurrent plane wins)."""
    readers = READERS_OVERRIDE if READERS_OVERRIDE is not None else 4
    base = {
        "cell": "read_concurrency", "size_mb": 32, "duration": 8.0,
        "shards": 2, "readers": readers, "threads": 1,
        "duty": DUTY_OVERRIDE if DUTY_OVERRIDE is not None else 0.05,
        "qps": 300, "batch": 16, "write_qps": 40, "write_batch": 4096,
        "persist_bw": 3e7, "bgsave_at": 0.1, "bgsave_every": 0.08,
    }
    arms = {}
    for concurrent in (False, True):
        arms[concurrent] = run_cell({**base, "concurrent": concurrent})
    c, s = arms[True], arms[False]
    ratio = s["read_p99_in_ms"] / max(1e-9, c["read_p99_in_ms"])
    out_ratio = s["read_p99_out_ms"] / max(1e-9, c["read_p99_out_ms"])
    _row(f"read_concurrency/{readers}readers", c["read_p99_in_ms"] * 1e3,
         f"serial_p99_in_us={s['read_p99_in_ms']*1e3:.0f};"
         f"concurrent_p99_out_us={c['read_p99_out_ms']*1e3:.0f};"
         f"serial_p99_out_us={s['read_p99_out_ms']*1e3:.0f};"
         f"concurrent_max_in_us={c['read_max_in_ms']*1e3:.0f};"
         f"read_retries={c['read_retries']:.0f};"
         f"shared_wait_us={c['shared_wait_us']:.0f};"
         f"queue_depth_max={c['queue_depth_max']:.0f};"
         f"serial_queue_depth_max={s['queue_depth_max']:.0f};"
         f"snapshots={c['snapshots']};"
         f"reads_in_window={c['reads_in_window']};"
         f"out_p99_ratio={out_ratio:.2f};"
         f"serial_vs_concurrent_p99={ratio:.2f}x")


def snapshot_reads():
    """New cell (PR 7): snapshot reads as a product. Live reader streams
    + a background writer through the RequestServer, with an arm adding
    analyst streams issuing GetAt(epoch) reads against a pinned epoch
    through the SAME server vs a live-only baseline — GetAt gathers off
    the epoch's frozen images (no gate/seqlock/retries), so the live
    read tail should track the baseline. The same run times a writable
    branch fork (COW wrap, O(metadata)) against an honest full copy of
    the epoch's images, and a ChainCompactor fold of a delta chain
    ``max_chain + 2`` deep. The gated ratio is full-copy-over-branch
    fork latency (bigger = the zero-copy branch wins)."""
    mc = MAX_CHAIN_OVERRIDE if MAX_CHAIN_OVERRIDE is not None else 3
    base = {
        "cell": "snapshot_reads", "size_mb": 32, "duration": 8.0,
        "shards": 2, "readers": 2, "analysts": 2, "threads": 1,
        "duty": DUTY_OVERRIDE if DUTY_OVERRIDE is not None else 0.05,
        "qps": 120, "batch": 16, "write_qps": 30, "write_batch": 4096,
        "persist_bw": 3e7, "bgsave_at": 0.3, "max_chain": mc,
    }
    arms = {}
    for analytical in (False, True):
        arms[analytical] = run_cell({**base, "analytical": analytical})
    a, b = arms[True], arms[False]
    # live-read perturbation is a bigger-is-WORSE ratio — keep it out of
    # the compare.py gate (no `x` suffix), like reshard_epoch/p99_ratio
    perturb = a["live_p99_ms"] / max(1e-9, b["live_p99_ms"])
    fork_ratio = a["copy_fork_ms"] / max(1e-9, a["branch_fork_ms"])
    restore_ratio = (a["chain_restore_ms"]
                     / max(1e-9, a["flat_restore_ms"]))
    _row(f"snapshot_reads/{base['analysts']}analysts",
         a["getat_p99_ms"] * 1e3,
         f"live_p99_us={a['live_p99_ms']*1e3:.0f};"
         f"baseline_live_p99_us={b['live_p99_ms']*1e3:.0f};"
         f"live_p99_ratio={perturb:.2f};"
         f"getats={a['getats']:.0f};"
         f"queue_depth_max={a['queue_depth_max']:.0f};"
         f"branch_fork_us={a['branch_fork_ms']*1e3:.0f};"
         f"copy_fork_us={a['copy_fork_ms']*1e3:.0f};"
         f"chain_depth={a['chain_depth_before']}->"
         f"{a['chain_depth_after']};"
         f"compacted_dirs={a['compacted_dirs']};"
         f"compact_us={a['compact_ms']*1e3:.0f};"
         f"chain_restore_us={a['chain_restore_ms']*1e3:.0f};"
         f"flat_restore_us={a['flat_restore_ms']*1e3:.0f};"
         f"restore_speedup={restore_ratio:.2f};"
         f"branch_vs_copy={fork_ratio:.2f}x")


def persist_path():
    """New cell: the zero-copy persist/restore hot path.

    (a) Sink write bandwidth, coalesced runs vs per-block writes: a fully
    staged (blocking) snapshot persists through pipelines with
    ``run_blocks=1`` (the seed's one-syscall-per-block behavior) vs
    coalesced runs; ``sink_write_s`` isolates the IO interval, so the row
    is pure sink bandwidth. (b) Restore wall-clock at 1/2/4 shards,
    sequential (``workers=1``) vs the parallel RestorePool.
    """
    import shutil
    import tempfile
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import (
        BlockingSnapshotter,
        FileSink,
        PersistPipeline,
        PyTreeProvider,
        ShardedSnapshotCoordinator,
        read_file_snapshot,
    )

    mb = 32 if FAST else 128
    cols = 256
    rows = mb * (1 << 20) // (cols * 4)
    # small blocks make per-unit overhead visible — the point of the cell
    block_bytes = 32 << 10
    bw = {}
    for run_blocks, tag in ((1, "per_block"), (64, "runs")):
        tmp = tempfile.mkdtemp(prefix="persist_path_")
        try:
            state = {"kv": jnp.arange(rows * cols, dtype=jnp.float32)
                     .reshape(rows, cols)}
            jax.block_until_ready(state["kv"])
            prov = PyTreeProvider(state)
            snapper = BlockingSnapshotter(prov, block_bytes=block_bytes)
            snapper.persist_pipeline = PersistPipeline(
                workers=2, run_blocks=run_blocks
            )
            snap = snapper.fork(FileSink(f"{tmp}/snap"))
            snap.wait_persisted(600)
            io_s = snap.metrics.sink_write_s
            bw[tag] = mb / max(1e-9, io_s)
            _row(f"persist_path/write/{tag}", io_s * 1e6,
                 f"mb_per_s={bw[tag]:.0f};run_blocks={run_blocks};"
                 f"blocks={snap.table.n_blocks}")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    _row("persist_path/write_speedup", 0.0,
         f"runs_vs_per_block={bw['runs'] / max(1e-9, bw['per_block']):.2f}x")

    leaves_per_shard = 8
    for shards in (1, 2, 4):
        tmp = tempfile.mkdtemp(prefix="restore_path_")
        try:
            leaf_rows = rows // (shards * leaves_per_shard)
            provs = [
                PyTreeProvider({
                    f"l{i}": jnp.zeros((leaf_rows, cols), jnp.float32)
                    for i in range(leaves_per_shard)
                })
                for _ in range(shards)
            ]
            coord = ShardedSnapshotCoordinator(
                provs, mode="blocking", block_bytes=1 << 20
            )
            coord.bgsave_to_dir(f"{tmp}/snap").wait_persisted(600)

            def timed(workers):
                t0 = time.perf_counter()
                read_file_snapshot(f"{tmp}/snap", workers=workers)
                return time.perf_counter() - t0

            timed(2)  # warm the page cache off-clock
            times = {
                tag: min(timed(workers) for _ in range(5))
                for workers, tag in ((1, "seq"), (4, "pool"))
            }
            _row(f"persist_path/restore/{shards}shards",
                 times["pool"] * 1e6,
                 f"seq_us={times['seq']*1e6:.0f};"
                 f"speedup={times['seq'] / max(1e-9, times['pool']):.2f}x")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)


def persist_overlap():
    """New cell (PR 9): the overlapped persist datapath. One durable
    2-shard BGSAVE epoch drains through per-shard PACED file sinks (an
    emulated disk stream: ``write_run`` adds a GIL-free
    ``sleep(bytes/bandwidth)`` after each real pwritev) with device
    staging, while a background writer donates row updates through
    proactive sync. Arms toggle ``PersistPipeline(overlap=...)`` x sink
    compression; one shared stager worker isolates the two-lane
    datapath (see ``run_persist_overlap``). The gated ratio is
    serial-over-overlapped epoch drain wall-clock on the uncompressed
    arm (bigger = the writer lane hides the D2H drain); the compressed
    arms ride along ungated — level-1 deflate is writer-lane compute,
    so its ratio compresses — plus a run_blocks sweep on the
    overlapped arm."""
    rb = RUN_BLOCKS_OVERRIDE if RUN_BLOCKS_OVERRIDE is not None else 16
    if COMPRESS_OVERRIDE is None:
        comp_arms = [None, "zlib"]
    else:
        comp_arms = [None if COMPRESS_OVERRIDE == "none" else COMPRESS_OVERRIDE]
    base = {
        "cell": "persist_overlap", "size_mb": 32, "shards": 2,
        "run_blocks": rb, "bandwidth_mbps": 8.0, "duty": 0.01,
        "block_kb": 256, "threads": 1, "mode": "asyncfork",
        "backend": "device", "persist_workers": 1, "repeat": 2,
    }
    arms = {}
    for compress in comp_arms:
        for overlap in (False, True):
            arms[(compress, overlap)] = run_cell(
                {**base, "compress": compress, "overlap": overlap})
    for compress in comp_arms:
        s, o = arms[(compress, False)], arms[(compress, True)]
        tag = compress or "raw"
        ratio = s["epoch_wall_s"] / max(1e-9, o["epoch_wall_s"])
        derived = (
            f"serial_wall_us={s['epoch_wall_s']*1e6:.0f};"
            f"sink_mb_per_s={o['sink_mb_per_s']:.1f};"
            f"serial_sink_mb_per_s={s['sink_mb_per_s']:.1f};"
            f"overlap_frac={o['overlap_frac']:.2f};"
            f"serial_overlap_frac={s['overlap_frac']:.2f};"
            f"stage_us={o['stage_s']*1e6:.0f};"
            f"write_busy_us={o['write_busy_s']*1e6:.0f};"
            f"write_p99_in_us={o['write_p99_ms']*1e3:.0f};"
            f"serial_write_p99_in_us={s['write_p99_ms']*1e3:.0f};"
            f"disk_bytes={o['disk_bytes']};"
            f"run_blocks={rb};"
        )
        if compress is None:
            derived += f"overlap_vs_serial={ratio:.2f}x"
        else:
            # encoder compute deflates this ratio — informational, so no
            # `=<v>x` suffix (which would opt it into the compare gate)
            derived += f"zlib_overlap_vs_serial={ratio:.2f}"
        _row(f"persist_overlap/{tag}", o["epoch_wall_s"] * 1e6, derived)
    # run-width sweep, overlapped + uncompressed: small runs pay more
    # kernel launches and ring handoffs per byte, large runs stage the
    # leaf in fewer, longer exclusive holds
    for rb2 in (4, 64):
        r = run_cell({**base, "run_blocks": rb2, "compress": None,
                      "overlap": True})
        _row(f"persist_overlap/run_blocks{rb2}", r["epoch_wall_s"] * 1e6,
             f"sink_mb_per_s={r['sink_mb_per_s']:.1f};"
             f"overlap_frac={r['overlap_frac']:.2f};"
             f"stage_us={r['stage_s']*1e6:.0f};"
             f"write_p99_in_us={r['write_p99_ms']*1e3:.0f}")


def faults():
    """New cell (PR 8): what crash safety costs, and what recovery costs.

    (a) Durable commit protocol (per-run crc32 into the manifest, fsync
    of data + manifest + parent dir, deferred composite rename as the
    commit point) vs ``durable=False`` on the identical epoch stream —
    the gated ratio is plain-over-durable wall-clock (bigger = cheaper
    durability). (b) ``SnapshotCatalog.from_dir`` wall-clock vs committed
    epoch count with deep crc verification on — the restart-time price of
    the recovery scan (ungated: absolute, machine-bound).
    """
    import os
    import shutil
    import tempfile
    import time

    from repro.core import SnapshotCatalog
    from repro.core.policy import BgsavePolicy
    from repro.kvstore import KVEngine, ShardedKVStore

    capacity, block_rows, width = 4096, 256, 16
    epochs = 4 if FAST else 8
    rows_all = np.arange(capacity, dtype=np.int64)

    def _mk():
        store = ShardedKVStore(capacity=capacity, block_rows=block_rows,
                               row_width=width, seed=0, shards=2)
        eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                       persist_bandwidth=None, copier_duty=1.0,
                       policy=BgsavePolicy(delta_threshold=2.0,
                                           full_every=99))
        store.warmup(batch=2)
        return store, eng

    def _save_epochs(pool, n, durable):
        store, eng = _mk()
        t0 = time.perf_counter()
        for e in range(n):
            rows = rows_all[e % 5::7]
            store.set(rows,
                      np.full((rows.size, width), float(e + 1), np.float32),
                      before_write=eng._write_hook, gate=eng._gate)
            snap = eng.coordinator.bgsave_to_dir(
                os.path.join(pool, f"ep{e}"), durable=durable
            )
            if not snap.wait_persisted(120.0):
                raise RuntimeError("bench epoch did not persist")
        return time.perf_counter() - t0

    secs = {}
    for durable, tag in ((False, "plain"), (True, "durable")):
        best = float("inf")
        for _ in range(3):
            tmp = tempfile.mkdtemp(prefix=f"faults_{tag}_")
            try:
                best = min(best, _save_epochs(tmp, epochs, durable))
            finally:
                shutil.rmtree(tmp, ignore_errors=True)
        secs[tag] = best
    ratio = secs["plain"] / max(1e-9, secs["durable"])
    _row(f"faults/durable_commit_{epochs}epochs",
         secs["durable"] / epochs * 1e6,
         f"plain_us_per_epoch={secs['plain'] / epochs * 1e6:.0f};"
         f"epochs={epochs};"
         f"durable_vs_plain={ratio:.2f}x")

    for n in (epochs, epochs * 4):
        tmp = tempfile.mkdtemp(prefix="faults_recover_")
        try:
            _save_epochs(tmp, n, True)
            best = float("inf")
            blocks = 0
            for _ in range(3):
                t0 = time.perf_counter()
                cat = SnapshotCatalog.from_dir(tmp)
                best = min(best, time.perf_counter() - t0)
                blocks = cat.last_recovery.blocks_verified
                assert len(cat.last_recovery.recovered) == n
            _row(f"faults/recovery_{n}epochs", best * 1e6,
                 f"epochs={n};blocks_verified={blocks};"
                 f"us_per_epoch={best / n * 1e6:.0f}")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)


def replication():
    """New cell (PR 10): epoch shipping to a standby pool + background
    scrubbing, against a live paced writer.

    (a) Ship a 1-full + (N-1)-sparse-delta epoch chain while a writer
    thread keeps mutating the primary table: the wire carries only each
    delta's own runs (sparse holes re-materialize via truncate), so
    ``delta_vs_full_bytes`` — logical bytes over shipped bytes — is the
    gated ratio (bigger = the carried-block diff is doing its job;
    floor 1.0 = never worse than full copies). (b) Scrub throughput:
    the deep-crc pass over every cold committed dir, same live writer
    donating load, reported as blocks/s (ungated: machine-bound), plus
    the detect → quarantine → re-fetch repair round-trip for one
    bit-flipped run."""
    import os
    import shutil
    import tempfile
    import threading
    import time

    from repro.core import EpochReplicator, EpochScrubber
    from repro.core.policy import BgsavePolicy, ScrubPolicy
    from repro.kvstore import KVEngine, ShardedKVStore

    capacity, block_rows, width = 4096, 256, 16
    epochs = 6 if FAST else 12
    nblocks = capacity // block_rows

    def _mk():
        store = ShardedKVStore(capacity=capacity, block_rows=block_rows,
                               row_width=width, seed=0, shards=2)
        eng = KVEngine(store, mode="asyncfork", copier_threads=2,
                       persist_bandwidth=None, copier_duty=1.0,
                       policy=BgsavePolicy(delta_threshold=2.0,
                                           full_every=99))
        store.warmup(batch=2)
        return store, eng

    pool = tempfile.mkdtemp(prefix="repl_pool_")
    replica = tempfile.mkdtemp(prefix="repl_standby_")
    try:
        store, eng = _mk()
        for e in range(epochs):
            if e == 0:
                rows = np.arange(capacity, dtype=np.int64)
            else:  # one dirty block per delta epoch
                lo = (e % nblocks) * block_rows
                rows = np.arange(lo, lo + block_rows, dtype=np.int64)
            store.set(rows,
                      np.full((rows.size, width), float(e + 1), np.float32),
                      before_write=eng._write_hook, gate=eng._gate)
            snap = eng.coordinator.bgsave_to_dir(os.path.join(pool, f"ep{e}"))
            if not snap.wait_persisted(120.0):
                raise RuntimeError("bench epoch did not persist")

        stop = threading.Event()
        writes = [0]

        def _writer():  # paced live load riding along ship + scrub
            k = 0
            while not stop.is_set():
                lo = (k % nblocks) * block_rows
                rows = np.arange(lo, lo + block_rows, dtype=np.int64)
                store.set(rows, np.full((rows.size, width), -1.0, np.float32),
                          before_write=eng._write_hook, gate=eng._gate)
                writes[0] += 1
                k += 1
                time.sleep(0.002)

        wt = threading.Thread(target=_writer, daemon=True)
        wt.start()
        try:
            rep = EpochReplicator(replica, catalog=eng.catalog)
            eng.attach_maintenance(replicator=rep)
            lag0 = rep.lag()
            t0 = time.perf_counter()
            shipped = rep.sync()
            ship_s = time.perf_counter() - t0
            assert shipped == lag0 == epochs and rep.lag() == 0
            m = rep.metrics.summary()

            scrub = EpochScrubber(eng.catalog, ScrubPolicy(dirs_per_scan=10_000))
            t0 = time.perf_counter()
            found = scrub.scan_once()
            scrub_s = time.perf_counter() - t0
            assert found == []
            sm = scrub.metrics.summary()

            # repair round-trip: rot one cold full run, detect + re-fetch
            sdir = os.path.join(pool, "ep0", "shard_0")
            victim = max((os.path.join(sdir, f) for f in os.listdir(sdir)
                          if f != "manifest.json"), key=os.path.getsize)
            with open(victim, "r+b") as f:
                f.seek(8)
                b = f.read(1)
                f.seek(8)
                f.write(bytes([b[0] ^ 0xFF]))
            t0 = time.perf_counter()
            found = scrub.scan_once()
            repair_s = time.perf_counter() - t0
            assert len(found) == 1 and scrub.metrics.repaired == 1
        finally:
            stop.set()
            wt.join()

        ratio = m["bytes_logical"] / max(1.0, m["bytes_shipped"])
        _row(f"replication/ship_{epochs}epochs", ship_s / epochs * 1e6,
             f"epochs={epochs};"
             f"bytes_shipped={int(m['bytes_shipped'])};"
             f"bytes_logical={int(m['bytes_logical'])};"
             f"ship_mb_per_s={m['bytes_shipped'] / 1e6 / max(1e-9, ship_s):.1f};"
             f"writer_batches_during_ship={writes[0]};"
             f"delta_vs_full_bytes={ratio:.2f}x")
        _row("replication/scrub", scrub_s * 1e6,
             f"dirs_scrubbed={int(sm['dirs_scrubbed'])};"
             f"blocks_scrubbed={int(sm['blocks_scrubbed'])};"
             f"blocks_per_s={sm['blocks_scrubbed'] / max(1e-9, scrub_s):.0f}")
        _row("replication/repair_roundtrip", repair_s * 1e6,
             f"corrupt_found={int(scrub.metrics.corrupt_found)};"
             f"repaired={int(scrub.metrics.repaired)};"
             f"quarantined={int(scrub.metrics.quarantined)}")
    finally:
        shutil.rmtree(pool, ignore_errors=True)
        shutil.rmtree(replica, ignore_errors=True)


CELLS = {
    "fig3_fork_time_vs_size": fig3_fork_time_vs_size,
    "fig22_fork_call_duration": fig22_fork_call_duration,
    "fig4_5_default_fork_latency": fig4_5_default_fork_latency,
    "fig9_10_odf_vs_asyncfork": fig9_10_odf_vs_asyncfork,
    "fig11_20_interruptions": fig11_20_interruptions,
    "fig12_read_write_patterns": fig12_read_write_patterns,
    "fig13_clients": fig13_clients,
    "fig14_15_copier_threads": fig14_15_copier_threads,
    "fig17_19_throughput": fig17_19_throughput,
    "train_checkpoint_stall": train_checkpoint_stall,
    "kernel_snapcopy_bandwidth": kernel_snapcopy_bandwidth,
    "staging_backend_bandwidth": staging_backend_bandwidth,
    "incremental_snapshot_window": incremental_snapshot_window,
    "shard_scaling": shard_scaling,
    "reshard_epoch": reshard_epoch,
    "persist_path": persist_path,
    "persist_overlap": persist_overlap,
    "gate_contention": gate_contention,
    "read_concurrency": read_concurrency,
    "snapshot_reads": snapshot_reads,
    "faults": faults,
    "replication": replication,
}


def main() -> None:
    json_path = None
    names = []
    argv = iter(sys.argv[1:])
    global DUTY_OVERRIDE, READERS_OVERRIDE, MAX_CHAIN_OVERRIDE
    global RUN_BLOCKS_OVERRIDE, COMPRESS_OVERRIDE
    for a in argv:
        if a == "--json":
            json_path = next(argv, None)
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--copier-duty":
            DUTY_OVERRIDE = float(next(argv))
        elif a.startswith("--copier-duty="):
            DUTY_OVERRIDE = float(a.split("=", 1)[1])
        elif a == "--readers":
            READERS_OVERRIDE = int(next(argv))
        elif a.startswith("--readers="):
            READERS_OVERRIDE = int(a.split("=", 1)[1])
        elif a == "--max-chain":
            MAX_CHAIN_OVERRIDE = int(next(argv))
        elif a.startswith("--max-chain="):
            MAX_CHAIN_OVERRIDE = int(a.split("=", 1)[1])
        elif a == "--run-blocks":
            RUN_BLOCKS_OVERRIDE = int(next(argv))
        elif a.startswith("--run-blocks="):
            RUN_BLOCKS_OVERRIDE = int(a.split("=", 1)[1])
        elif a == "--compress":
            COMPRESS_OVERRIDE = next(argv)
        elif a.startswith("--compress="):
            COMPRESS_OVERRIDE = a.split("=", 1)[1]
        elif not a.startswith("-"):
            names.append(a)
    unknown = [n for n in names if n not in CELLS]
    if unknown:
        raise SystemExit(f"unknown cells {unknown}; pick from {sorted(CELLS)}")
    print("name,us_per_call,derived")
    for name, fn in CELLS.items():
        if not names or name in names:
            fn()
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"rows": _ROWS}, f, indent=1)


if __name__ == "__main__":
    main()
