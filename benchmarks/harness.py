"""Subprocess driver for benchmark cells + tiny result cache."""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
from typing import Dict

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "results/bench_cache")


def run_cell(spec: Dict, timeout: int = 300) -> Dict:
    os.makedirs(CACHE_DIR, exist_ok=True)
    key = hashlib.sha1(json.dumps(spec, sort_keys=True).encode()).hexdigest()[:16]
    path = os.path.join(CACHE_DIR, key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.cell", json.dumps(spec)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if p.returncode != 0:
        raise RuntimeError(f"bench cell failed: {spec}\n{p.stderr[-2000:]}")
    out = json.loads(p.stdout.strip().splitlines()[-1])
    with open(path, "w") as f:
        json.dump(out, f)
    return out
